//! Property-based tests over the coordinator's core invariants
//! (routing/delivery, scheduling order, consistency state), using the
//! in-repo `propcheck` mini-framework.

use graphlab::consistency::{ConsistencyModel, LockTable};
use graphlab::engine::trace::{TaskTrace, TraceEvent};
use graphlab::prop_assert;
use graphlab::scheduler::set_scheduler::ExecutionPlan;
use graphlab::scheduler::{
    ApproxPriorityScheduler, FifoScheduler, MultiQueueFifo, PartitionedScheduler,
    PriorityScheduler, Scheduler, Task,
};
use graphlab::sim::{simulate_trace, SimConfig};
use graphlab::util::propcheck::forall;
use graphlab::util::Pcg32;

/// Drain a scheduler cycling virtual worker ids (covers worker-affine ones).
fn drain(s: &dyn Scheduler, workers: usize) -> Vec<Task> {
    let mut out = Vec::new();
    let mut idle = 0;
    let mut w = 0usize;
    while idle <= workers {
        match s.next_task(w) {
            Some(t) => {
                out.push(t);
                idle = 0;
            }
            None => {
                idle += 1;
                w = (w + 1) % workers.max(1);
            }
        }
    }
    out
}

/// Every scheduler delivers each distinct pending (vertex) exactly once —
/// no loss, no duplication — regardless of duplicate submissions.
#[test]
fn prop_schedulers_deliver_exactly_once() {
    forall(40, |g| {
        let n = g.usize_in(1..200);
        let submissions = g.vec_usize(1..120, 0..n);
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FifoScheduler::new(n)),
            Box::new(MultiQueueFifo::new(n, 4)),
            Box::new(PartitionedScheduler::new(n, 4)),
            Box::new(PriorityScheduler::new(n)),
            Box::new(ApproxPriorityScheduler::new(n, 4)),
        ];
        let mut expected: Vec<usize> = submissions.clone();
        expected.sort_unstable();
        expected.dedup();
        for s in &schedulers {
            for (i, &v) in submissions.iter().enumerate() {
                s.add_task(Task::with_priority(v as u32, (i % 7) as f64));
            }
            let mut got: Vec<usize> =
                drain(s.as_ref(), 4).iter().map(|t| t.vertex as usize).collect();
            got.sort_unstable();
            got.dedup();
            prop_assert!(
                got == expected,
                "{}: delivered {:?} expected {:?}",
                s.name(),
                got.len(),
                expected.len()
            );
            prop_assert!(s.is_done(), "{} not done after drain", s.name());
        }
        Ok(())
    });
}

/// The strict priority scheduler delivers in non-increasing priority order
/// when nothing is re-added mid-drain.
#[test]
fn prop_priority_order_is_monotone() {
    forall(60, |g| {
        let n = g.usize_in(1..150);
        let count = g.usize_in(1..n + 1);
        let s = PriorityScheduler::new(n);
        for v in 0..count {
            s.add_task(Task::with_priority(v as u32, g.f64_in(0.0, 100.0)));
        }
        let drained = drain(&s, 1);
        prop_assert!(
            drained.windows(2).all(|w| w[0].priority >= w[1].priority),
            "out-of-order priorities"
        );
        Ok(())
    });
}

/// Set-scheduler plans are valid topological orders: every dependency edge
/// points from a lower execution position to a higher one, and tasks of the
/// same vertex appear in set order.
#[test]
fn prop_execution_plan_is_topological() {
    forall(40, |g| {
        let n = g.usize_in(2..40);
        // random adjacency (symmetric)
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for u in 0..n {
            for v in (u + 1)..n {
                if g.bool() && g.bool() {
                    adj[u].push(v as u32);
                    adj[v].push(u as u32);
                }
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        // random sequence of sets
        let num_sets = g.usize_in(1..5);
        let sets: Vec<(Vec<u32>, u32)> = (0..num_sets)
            .map(|_| {
                let mut s: Vec<u32> =
                    (0..n as u32).filter(|_| g.bool()).collect();
                if s.is_empty() {
                    s.push(g.usize_in(0..n) as u32);
                }
                (s, 0)
            })
            .collect();
        let plan = ExecutionPlan::compile(&sets, n, |v| adj[v as usize].as_slice(), ConsistencyModel::Edge);
        // simulate a greedy execution, recording completion positions
        let mut remaining: Vec<u32> = plan.indegree.clone();
        let mut order = Vec::new();
        let mut ready: Vec<u32> =
            (0..plan.len() as u32).filter(|&t| remaining[t as usize] == 0).collect();
        while let Some(t) = ready.pop() {
            order.push(t);
            for &c in plan.children(t) {
                remaining[c as usize] -= 1;
                if remaining[c as usize] == 0 {
                    ready.push(c);
                }
            }
        }
        prop_assert!(order.len() == plan.len(), "DAG has a cycle or lost tasks");
        // same-vertex tasks execute in set order
        let pos: std::collections::HashMap<u32, usize> =
            order.iter().enumerate().map(|(i, &t)| (t, i)).collect();
        for a in 0..plan.len() {
            for b in (a + 1)..plan.len() {
                let (va, _, sa) = plan.tasks[a];
                let (vb, _, sb) = plan.tasks[b];
                if va == vb && sa < sb {
                    prop_assert!(
                        pos[&(a as u32)] < pos[&(b as u32)],
                        "vertex {va} executed out of set order"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Lock-table invariant: a full-model scope excludes every overlapping
/// scope; releasing restores availability (try-style check via threads is
/// covered in unit tests; here we check the pure ordering contract).
#[test]
fn prop_lock_scope_guard_counts() {
    forall(60, |g| {
        let n = g.usize_in(2..60);
        let table = LockTable::new(n);
        let v = g.usize_in(0..n) as u32;
        let mut nbrs: Vec<u32> = (0..n as u32).filter(|&u| u != v && g.bool()).collect();
        nbrs.sort_unstable();
        for model in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
            let guards = table.lock_scope(v, &nbrs, model);
            let want = match model {
                ConsistencyModel::Vertex => 1,
                _ => nbrs.len() + 1,
            };
            prop_assert!(guards.len() == want);
            let want_writes = match model {
                ConsistencyModel::Vertex => 1,
                ConsistencyModel::Edge => 1,
                ConsistencyModel::Full => nbrs.len() + 1,
            };
            prop_assert!(guards.writes() == want_writes);
            drop(guards);
        }
        // after all drops the whole table is free again
        let all: Vec<u32> = (0..n as u32).collect();
        let g2 = table.lock_scope(0, &all[1..], ConsistencyModel::Full);
        prop_assert!(g2.len() == n);
        Ok(())
    });
}

/// Simulator sanity over random traces: (a) every trace event executes
/// exactly once; (b) makespan is monotonically non-increasing in P;
/// (c) busy time is invariant in P.
#[test]
fn prop_simulator_conservation_and_monotonicity() {
    forall(25, |g| {
        let n = g.usize_in(2..80);
        let events: Vec<TraceEvent> = (0..g.usize_in(1..300))
            .map(|i| {
                let spawned = (0..g.usize_in(0..3))
                    .map(|_| Task::new(g.usize_in(0..n) as u32))
                    .collect();
                TraceEvent {
                    vertex: (i % n) as u32,
                    func: 0,
                    priority: 0.0,
                    cost_ns: 100 + g.usize_in(0..5000) as u64,
                    spawned,
                }
            })
            .collect();
        let trace = TaskTrace { initial: vec![], events };
        let initial: Vec<Task> = (0..n as u32).map(Task::new).collect();
        let nbrs: Vec<Vec<u32>> = vec![Vec::new(); n];
        let cfg = SimConfig {
            model: ConsistencyModel::Vertex,
            sched_overhead_ns: 50.0,
            min_task_ns: 10.0,
            ..Default::default()
        };
        let mut prev = f64::INFINITY;
        let mut busy0 = None;
        for p in [1usize, 2, 4, 16] {
            let r = simulate_trace(&trace, &initial, n, &nbrs, &cfg.clone().with_processors(p));
            prop_assert!(r.tasks <= trace.len());
            prop_assert!(
                r.makespan_ns <= prev * 1.0001,
                "P={p} regressed: {} > {}",
                r.makespan_ns,
                prev
            );
            match busy0 {
                None => busy0 = Some(r.busy_ns),
                Some(b) => prop_assert!((r.busy_ns - b).abs() < 1e-6, "busy time varies with P"),
            }
            prev = r.makespan_ns;
        }
        Ok(())
    });
}

/// Engine-level delivery invariant under concurrency: random self-requeue
/// programs execute exactly the requested number of updates per vertex.
#[test]
fn prop_threaded_engine_counts_updates_exactly() {
    use graphlab::consistency::Scope;
    use graphlab::engine::{Program, ThreadedEngine, UpdateContext, UpdateFn};
    use graphlab::graph::GraphBuilder;
    use graphlab::sdt::Sdt;

    struct BumpTo {
        target: u64,
    }
    impl UpdateFn<u64, ()> for BumpTo {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.target {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    forall(12, |g| {
        let n = g.usize_in(1..120);
        let target = g.usize_in(1..12) as u64;
        let mut rng = Pcg32::seed_from_u64(g.u32() as u64);
        let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0);
        }
        for _ in 0..n * 2 {
            let u = rng.gen_range(n as u32);
            let v = rng.gen_range(n as u32);
            if u != v {
                b.add_undirected(u, v, (), ());
            }
        }
        let mut graph = b.build();
        let sched = MultiQueueFifo::new(n, 3);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = BumpTo { target };
        let report = Program::new()
            .update_fn(&f)
            .workers(3)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, &mut graph, &sched, &sdt);
        prop_assert!(
            report.updates == n as u64 * target,
            "expected {} updates, got {}",
            n as u64 * target,
            report.updates
        );
        for v in 0..n as u32 {
            prop_assert!(*graph.vertex_data(v) == target);
        }
        Ok(())
    });
}
