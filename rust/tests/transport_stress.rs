//! Stress tests for the ghost-sync transport layer: codec round-trips for
//! every app vertex type, Channel/Shm/Socket vs Direct conservation
//! equivalence for BP and Gibbs across shard counts and staleness bounds,
//! delta coalescing on repeat-writer workloads, the bounded-staleness
//! admission semantics (`s = 0` reproduces PR 3's synchronous flush
//! accounting exactly; `s > 0` never lets a reader observe a replica more
//! than `s` versions behind), the pull request/reply path (serializing
//! backends serve every admission pull through the wire, never a direct
//! master read, and pipelining backends batch >1 pull in flight per
//! lane), SPSC shm-ring integrity under concurrent wraparound (whole
//! frames only, never torn), socket-z vs raw-socket wire-byte accounting,
//! and socket-backend backpressure on a tiny send window.

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use graphlab::apps::gibbs::{chromatic_sets, GibbsEdge, GibbsUpdate, GibbsVertex};
use graphlab::apps::mrf::{random_mrf, BpEdge, BpVertex, EdgePotential, Mrf};
use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{
    ChannelShardedEngine, Engine, Program, SequentialEngine, ShardedEngine,
    ShmShardedEngine, SocketShardedEngine, ThreadedEngine, UpdateContext, UpdateFn,
};
use graphlab::graph::{DataGraph, GraphBuilder, ShardedGraph};
use graphlab::scheduler::{
    FifoScheduler, MultiQueueFifo, PriorityScheduler, Scheduler, SetScheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::transport::{
    shm_ring, ChannelTransport, DirectTransport, GhostTransport, PullRequest,
    ShmTransport, SocketTransport, VertexCodec,
};
use graphlab::util::Pcg32;
use std::sync::Arc;

// ---- codec round-trips ---------------------------------------------------

/// Every vertex type that can ride the serializing transport must survive
/// an encode/decode round-trip bit-exactly.
#[test]
fn codec_round_trip_every_app_vertex_type() {
    // BP vertex: distributions + observation + learning stats.
    let bp = BpVertex {
        potential: vec![0.25, 0.5, 0.25],
        belief: vec![0.1, 0.7, 0.2],
        observed: 2,
        axis_stats: [0.5, -1.25, 3.0],
    };
    let mut buf = Vec::new();
    bp.encode(&mut buf);
    let back = BpVertex::decode(&buf).expect("bp decodes");
    assert_eq!(back.potential, bp.potential);
    assert_eq!(back.belief, bp.belief);
    assert_eq!(back.observed, bp.observed);
    assert_eq!(back.axis_stats, bp.axis_stats);
    assert!(BpVertex::decode(&buf[..buf.len() - 1]).is_none(), "truncation rejected");

    // Gibbs vertex: potential + sample + visit counts + color.
    let gv = GibbsVertex {
        potential: vec![1.0, 2.0],
        value: 1,
        counts: vec![17, 41],
        color: 3,
    };
    let mut buf = Vec::new();
    gv.encode(&mut buf);
    let back = GibbsVertex::decode(&buf).expect("gibbs decodes");
    assert_eq!(back.potential, gv.potential);
    assert_eq!(back.value, gv.value);
    assert_eq!(back.counts, gv.counts);
    assert_eq!(back.color, gv.color);

    // Primitive vertex types used by the stress workloads.
    let mut buf = Vec::new();
    (7u64, 99u64).encode(&mut buf);
    assert_eq!(<(u64, u64)>::decode(&buf), Some((7, 99)));
    let mut buf = Vec::new();
    123456u64.encode(&mut buf);
    assert_eq!(u64::decode(&buf), Some(123456));
    let mut buf = Vec::new();
    (-2.5f64).encode(&mut buf);
    assert_eq!(f64::decode(&buf), Some(-2.5));
}

/// Unit-level channel round-trip against real ghost tables: send versioned
/// deltas for every replicated vertex, drain every shard, and the replicas
/// must equal the masters with version == pending (nothing in flight).
#[test]
fn channel_transport_round_trips_into_ghost_tables() {
    let side = 6u32;
    let mut b = GraphBuilder::new();
    for i in 0..side * side {
        b.add_vertex(i as u64);
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    let mut g = b.build();
    let n = g.num_vertices();
    let sg = ShardedGraph::new(&mut g, 3);
    assert!(sg.num_ghosts() > 0);
    let transport = ChannelTransport::new(&sg);

    let mut sent_bytes = 0u64;
    let mut sent = 0u64;
    for v in 0..n as u32 {
        if sg.replicas_of(v).is_empty() {
            continue;
        }
        *g.vertex_data(v) = 1000 + v as u64;
        let ver = sg.bump_master(v);
        let r = transport.send(sg.owner_of(v), v, ver, &(1000 + v as u64));
        assert_eq!(r.replicas_now, 0, "channel applies at drain");
        assert!(r.bytes > 0);
        sent_bytes += r.bytes;
        sent += 1;
    }
    assert!(sent > 0);

    let mut applied = 0u64;
    let mut drained_bytes = 0u64;
    for s in 0..sg.num_shards() {
        let d = transport.drain(s);
        applied += d.applied;
        drained_bytes += d.bytes;
    }
    assert_eq!(applied as usize, sg.num_ghosts(), "every replica written once");
    assert_eq!(drained_bytes, sent_bytes, "every queued byte consumed");
    assert!(sg.ghosts_consistent(&mut g), "codec round-trip preserved the data");
    for sh in sg.shards() {
        for e in sh.ghosts() {
            assert_eq!(e.version(), e.pending_version(), "nothing left in flight");
            assert_eq!(e.version(), sg.master_version(e.global()));
        }
    }
}

// ---- BP: channel vs sequential conservation ------------------------------

fn run_bp_sequential(mrf: &mut Mrf, bound: f32) {
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    let sched = PriorityScheduler::new(n);
    for v in 0..n as u32 {
        sched.add_task(Task::with_priority(v, 1.0));
    }
    let upd = BpUpdate::new(mrf.arity, bound, Arc::new(mrf.tables.clone()));
    Program::new()
        .update_fn(&upd)
        .model(ConsistencyModel::Edge)
        .max_updates(200_000)
        .run_on(&SequentialEngine, &mut mrf.graph, &sched, &sdt);
}

/// Shared acceptance harness: a serializing-transport BP run must match
/// the sequential fixed point at k in {2, 4} with staleness in {0, 4} —
/// the byte path changes how replicas move, never what the computation
/// produces — and every admission pull must be served through the
/// transport's request/reply path (no direct master reads).
fn bp_matches_sequential_on<Eng: Engine<BpVertex, BpEdge>>(
    make: impl Fn(usize) -> Eng,
    backend: &str,
) {
    let mk = || {
        let mut rng = Pcg32::seed_from_u64(42);
        random_mrf(80, 160, 3, &mut rng)
    };
    let mut seq = mk();
    run_bp_sequential(&mut seq, 1e-6);
    let reference: Vec<Vec<f32>> =
        (0..80u32).map(|v| seq.graph.vertex_data(v).belief.clone()).collect();

    for k in [2usize, 4] {
        for staleness in [0u64, 4] {
            let mut par = mk();
            let n = par.graph.num_vertices();
            let sdt = Sdt::new();
            sdt.set(LAMBDA_KEY, [1.0f64; 3]);
            let sched = FifoScheduler::new(n);
            for v in 0..n as u32 {
                sched.add_task(Task::new(v));
            }
            let upd = BpUpdate::new(par.arity, 1e-6, Arc::new(par.tables.clone()));
            let report = Program::new()
                .update_fn(&upd)
                .workers(4)
                .model(ConsistencyModel::Full)
                .ghost_staleness(staleness)
                .ghost_batch(if staleness == 0 { 1 } else { 8 })
                .max_updates(500_000)
                .run_on(&make(k), &mut par.graph, &sched, &sdt);
            assert!(report.updates > 0, "{backend} k={k} s={staleness}");
            let c = &report.contention;
            assert_eq!(c.shards, k);
            assert!(c.deltas_sent > 0, "{backend} k={k} s={staleness}");
            assert!(
                c.bytes_shipped > 0,
                "{backend} really serialized: k={k} s={staleness}"
            );
            assert!(
                c.max_ghost_staleness <= staleness,
                "{backend} k={k}: observed lag {} exceeds bound {staleness}",
                c.max_ghost_staleness
            );
            assert_eq!(
                c.pulls_served, c.staleness_pulls,
                "{backend} k={k} s={staleness}: every pull rides request/reply"
            );
            for v in 0..n as u32 {
                let b = &par.graph.vertex_data(v).belief;
                for (x, y) in reference[v as usize].iter().zip(b.iter()) {
                    assert!(
                        (x - y).abs() < 5e-3,
                        "{backend} k={k} s={staleness} vertex {v}: seq={:?} got={b:?}",
                        reference[v as usize]
                    );
                }
            }
        }
    }
}

/// Acceptance: ChannelTransport-backed BP matches the sequential fixed
/// point at k in {2, 4} with staleness in {0, 4}.
#[test]
fn channel_bp_matches_sequential_beliefs_under_staleness() {
    bp_matches_sequential_on(ChannelShardedEngine::new, "channel");
}

/// Acceptance: SocketTransport-backed BP (every delta and pull crossing a
/// real Unix socket) matches the sequential fixed point at k in {2, 4}
/// with staleness in {0, 4}.
#[test]
fn socket_bp_matches_sequential_beliefs_under_staleness() {
    bp_matches_sequential_on(SocketShardedEngine::new, "socket");
}

/// Acceptance: ShmTransport-backed BP (deltas and pulls crossing
/// shared-memory SPSC rings) matches the sequential fixed point at k in
/// {2, 4} with staleness in {0, 4}.
#[test]
fn shm_bp_matches_sequential_beliefs_under_staleness() {
    bp_matches_sequential_on(ShmShardedEngine::new, "shm");
}

/// Acceptance: compressed-socket ("socket-z") BP — shadow-diffed varint
/// envelopes over real Unix sockets — matches the sequential fixed point
/// at k in {2, 4} with staleness in {0, 4}.
#[test]
fn socket_z_bp_matches_sequential_beliefs_under_staleness() {
    bp_matches_sequential_on(SocketShardedEngine::compressed, "socket-z");
}

// ---- Gibbs: channel conservation -----------------------------------------

fn color_graph(g: &mut DataGraph<GibbsVertex, GibbsEdge>) {
    let n = g.num_vertices();
    let sched = FifoScheduler::new(n);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let upd = ColoringUpdate;
    Program::new()
        .update_fn(&upd)
        .workers(2)
        .model(ConsistencyModel::Edge)
        .run_on(&ThreadedEngine, g, &sched, &Sdt::new());
}

/// Shared acceptance harness: serializing-transport chromatic Gibbs must
/// conserve exactly one sample per vertex per sweep at k in {2, 4} with
/// staleness in {0, 4}.
fn gibbs_conserves_sweeps_on<Eng: Engine<GibbsVertex, GibbsEdge>>(
    make: impl Fn(usize) -> Eng,
    backend: &str,
) {
    let sweeps = 300usize;
    let build = || {
        let mut b = GraphBuilder::new();
        for _ in 0..8 {
            b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
        }
        let e = GibbsEdge { potential: EdgePotential::Table(0) };
        for i in 0..7u32 {
            b.add_undirected(i, i + 1, e, e);
        }
        b.build()
    };
    let tables = vec![vec![1.5, 0.5, 0.5, 1.5]];

    for k in [2usize, 4] {
        for staleness in [0u64, 4] {
            let mut g = build();
            color_graph(&mut g);
            assert!(validate_coloring(&mut g).is_ok());
            let classes = color_classes(&mut g);
            let sets = chromatic_sets(&classes, sweeps, 0);
            let sched = SetScheduler::planned(
                &sets,
                g.num_vertices(),
                |v| g.neighbors(v),
                ConsistencyModel::Edge,
            );
            let upd = GibbsUpdate::new(2, Arc::new(tables.clone()), 4, 9);
            let report = Program::new()
                .update_fn(&upd)
                .workers(4)
                .model(ConsistencyModel::Full)
                .ghost_staleness(staleness)
                .ghost_batch(if staleness == 0 { 1 } else { 4 })
                .run_on(&make(k), &mut g, &sched, &Sdt::new());
            assert_eq!(
                report.updates,
                8 * sweeps as u64,
                "{backend} k={k} s={staleness}: sweep conservation"
            );
            let c = &report.contention;
            assert_eq!(c.shards, k);
            assert!(c.boundary_updates > 0, "a cut chain has boundary work");
            assert!(c.bytes_shipped > 0, "{backend} k={k} s={staleness}");
            assert!(
                c.max_ghost_staleness <= staleness,
                "{backend} k={k} s={staleness}"
            );
            assert_eq!(
                c.pulls_served, c.staleness_pulls,
                "{backend} k={k} s={staleness}: every pull rides request/reply"
            );
            for v in 0..8u32 {
                let total: u32 = g.vertex_data(v).counts.iter().sum();
                assert_eq!(
                    total as usize, sweeps,
                    "{backend} k={k} s={staleness} vertex {v}: one sample per sweep"
                );
            }
        }
    }
}

/// Acceptance: ChannelTransport-backed chromatic Gibbs conserves exactly
/// one sample per vertex per sweep at k in {2, 4} with staleness in
/// {0, 4}.
#[test]
fn channel_gibbs_conserves_sweeps_under_staleness() {
    gibbs_conserves_sweeps_on(ChannelShardedEngine::new, "channel");
}

/// Acceptance: SocketTransport-backed chromatic Gibbs conserves exactly
/// one sample per vertex per sweep at k in {2, 4} with staleness in
/// {0, 4}.
#[test]
fn socket_gibbs_conserves_sweeps_under_staleness() {
    gibbs_conserves_sweeps_on(SocketShardedEngine::new, "socket");
}

/// Acceptance: ShmTransport-backed chromatic Gibbs conserves exactly one
/// sample per vertex per sweep at k in {2, 4} with staleness in {0, 4}.
#[test]
fn shm_gibbs_conserves_sweeps_under_staleness() {
    gibbs_conserves_sweeps_on(ShmShardedEngine::new, "shm");
}

/// Acceptance: compressed-socket ("socket-z") chromatic Gibbs conserves
/// exactly one sample per vertex per sweep at k in {2, 4} with staleness
/// in {0, 4}.
#[test]
fn socket_z_gibbs_conserves_sweeps_under_staleness() {
    gibbs_conserves_sweeps_on(SocketShardedEngine::compressed, "socket-z");
}

// ---- compressed channel ---------------------------------------------------

/// Acceptance: the compressed channel backend ("channel-z") is still a
/// correct transport — BP matches the sequential fixed point at k in
/// {2, 4} with staleness in {0, 4}, and every pull rides request/reply.
#[test]
fn channel_compressed_bp_matches_sequential_beliefs_under_staleness() {
    bp_matches_sequential_on(ChannelShardedEngine::compressed, "channel-z");
}

/// Deterministic byte comparison: with window 1 every boundary update
/// ships immediately (no coalescing), so `deltas_sent` is exactly
/// `boundary_vertices x rounds` on both backends regardless of thread
/// interleaving; with a staleness bound far beyond the run no admission
/// pull ever fires (SelfBump only reads its own vertex, so lag is
/// harmless), leaving `bytes_shipped` pure delta-frame traffic — and the
/// compressed run must ship strictly fewer total bytes for the identical
/// delta stream (raw ships a flat 24 B per u64 delta; compressed varint
/// headers alone nearly halve that).
#[test]
fn compression_strictly_cuts_bytes_shipped_on_identical_delta_streams() {
    let n = 16usize;
    let rounds = 100u64;
    let f = SelfBump { rounds };
    let run = |compress: bool| {
        let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n as u32 - 1 {
            b.add_undirected(i, i + 1, (), ());
        }
        let mut g = b.build();
        let eng = if compress {
            ChannelShardedEngine::compressed(2)
        } else {
            ChannelShardedEngine::new(2)
        };
        let report = Program::new()
            .update_fn(&f)
            .workers(2)
            .model(ConsistencyModel::Full)
            .ghost_staleness(1_000_000)
            .ghost_batch(1)
            .run_on(&eng, &mut g, &seeded(n, 2), &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "compress={compress}: conservation");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "compress={compress} vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.staleness_pulls, 0, "huge bound leaves nothing to pull");
        assert_eq!(c.deltas_coalesced, 0, "window 1 ships every record");
        assert_eq!(c.deltas_sent, c.boundary_updates);
        report
    };
    let raw = run(false).contention;
    let z = run(true).contention;
    assert_eq!(raw.deltas_sent, z.deltas_sent, "identical synchronous delta streams");
    assert!(raw.bytes_shipped > 0 && z.bytes_shipped > 0);
    assert_eq!(raw.bytes_shipped, raw.deltas_sent * 24, "raw u64 frame is a flat 24 B");
    assert!(
        z.bytes_shipped < raw.bytes_shipped,
        "compression must strictly cut the wire bytes: {} vs {}",
        z.bytes_shipped,
        raw.bytes_shipped
    );
}

/// Converging BP ships strictly fewer wire bytes per delta compressed
/// than raw at the same correct fixed point: every raw BpVertex frame at
/// k=3 is a flat `16 + payload` bytes, while even a compressed
/// raw-fallback frame replaces the 16-byte header with varints, and
/// late-convergence diffs collapse further. BP's delta count varies with
/// scheduling interleaving, so the comparison is normalized per delta
/// after subtracting pull traffic (pull frames are fixed-size and stay
/// raw on both backends); the strict total-bytes assertion lives in the
/// deterministic test above.
#[test]
fn compression_cuts_bytes_per_delta_on_converging_bp() {
    let mk = || {
        let mut rng = Pcg32::seed_from_u64(42);
        random_mrf(80, 160, 3, &mut rng)
    };
    let mut seq = mk();
    run_bp_sequential(&mut seq, 1e-6);
    let reference: Vec<Vec<f32>> =
        (0..80u32).map(|v| seq.graph.vertex_data(v).belief.clone()).collect();
    // Every BpVertex at fixed arity encodes to the same length, so raw
    // delta frames and pull replies are fixed-size.
    let payload_len = {
        let mut probe = mk();
        let mut buf = Vec::new();
        probe.graph.vertex_data_ref(0).encode(&mut buf);
        buf.len() as u64
    };
    let raw_frame = 16 + payload_len;
    let pull_cost = PullRequest::WIRE_LEN as u64 + raw_frame;

    let run = |compress: bool| {
        let mut par = mk();
        let n = par.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [1.0f64; 3]);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let upd = BpUpdate::new(par.arity, 1e-6, Arc::new(par.tables.clone()));
        let eng = if compress {
            ChannelShardedEngine::compressed(2)
        } else {
            ChannelShardedEngine::new(2)
        };
        let report = Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Full)
            .ghost_staleness(0)
            .ghost_batch(1)
            .max_updates(500_000)
            .run_on(&eng, &mut par.graph, &sched, &sdt);
        for v in 0..n as u32 {
            let b = &par.graph.vertex_data(v).belief;
            for (x, y) in reference[v as usize].iter().zip(b.iter()) {
                assert!(
                    (x - y).abs() < 5e-3,
                    "compress={compress} vertex {v}: wrong fixed point"
                );
            }
        }
        let c = report.contention;
        assert!(c.deltas_sent > 0 && c.bytes_shipped > 0);
        // Delta-frame-only bytes: every served pull cost exactly
        // `request + reply` on both backends (pull lanes stay raw).
        let frame_bytes = c.bytes_shipped - c.pulls_served * pull_cost;
        (frame_bytes, c.deltas_sent)
    };
    let (raw_bytes, raw_deltas) = run(false);
    let (z_bytes, z_deltas) = run(true);
    // At k=2 every boundary vertex has exactly one replica, so raw frame
    // accounting is exact — this pins the pull-cost subtraction too.
    assert_eq!(raw_bytes, raw_deltas * raw_frame, "raw BP frame is flat {raw_frame} B");
    let raw_per_delta = raw_bytes as f64 / raw_deltas as f64;
    let z_per_delta = z_bytes as f64 / z_deltas as f64;
    assert!(
        z_per_delta < raw_per_delta,
        "compressed BP must ship fewer bytes per delta: {z_per_delta:.1} vs {raw_per_delta:.1}"
    );
}

// ---- delta batching / coalescing -----------------------------------------

struct SelfBump {
    rounds: u64,
}
impl UpdateFn<u64, ()> for SelfBump {
    fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
        *scope.vertex_mut() += 1;
        if *scope.vertex() < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

/// A path graph cut in two has one boundary vertex per shard that every
/// sync window sees repeatedly: with a window of 16 the batcher must
/// coalesce most of its writes into far fewer deltas than the synchronous
/// (window 1) run ships. Every record is accounted: sent + coalesced =
/// boundary updates.
#[test]
fn coalescing_reduces_deltas_sent_on_repeat_writers() {
    let n = 16usize;
    let rounds = 100u64;
    let build = || {
        let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n as u32 - 1 {
            b.add_undirected(i, i + 1, (), ());
        }
        b.build()
    };
    let f = SelfBump { rounds };
    let run = |window: usize| {
        let mut g = build();
        let sched = MultiQueueFifo::new(n, 2);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let report = Program::new()
            .update_fn(&f)
            .workers(2)
            .model(ConsistencyModel::Full)
            .ghost_staleness(8)
            .ghost_batch(window)
            .run_on(&ShardedEngine::new(2), &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "window {window}: conservation");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "window {window} vertex {v}");
        }
        report
    };

    let sync = run(1);
    let sc = &sync.contention;
    assert_eq!(sc.deltas_sent, sc.boundary_updates, "window 1 ships every record");
    assert_eq!(sc.deltas_coalesced, 0);

    let batched = run(16);
    let bc = &batched.contention;
    assert_eq!(
        bc.deltas_sent + bc.deltas_coalesced,
        bc.boundary_updates,
        "every boundary record either ships or coalesces"
    );
    assert!(bc.deltas_coalesced > 0, "window 16 must coalesce repeat writes: {bc:?}");
    assert!(
        bc.deltas_sent * 2 < sc.deltas_sent,
        "batching must at least halve the delta count: {} vs {}",
        bc.deltas_sent,
        sc.deltas_sent
    );
}

// ---- bounded staleness ----------------------------------------------------

fn grid(side: u32) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..side * side {
        b.add_vertex(0u64);
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    b.build()
}

/// `s = 0` with the default window reproduces PR 3's synchronous flush
/// accounting exactly: one delta per boundary update, one replica write
/// per replica per update, no pulls, no observable lag.
#[test]
fn staleness_zero_matches_synchronous_flush_semantics() {
    let side = 8u32;
    let rounds = 25u64;
    let k = 2;
    let mut g = grid(side);
    let n = g.num_vertices();
    let probe = ShardedGraph::new(&mut g, k);
    let boundary_vertices: u64 =
        (0..n as u32).filter(|&v| probe.is_boundary(v)).count() as u64;
    let total_replicas: u64 =
        (0..n as u32).map(|v| probe.replicas_of(v).len() as u64).sum();
    assert!(boundary_vertices > 0);

    let f = SelfBump { rounds };
    let report = Program::new()
        .update_fn(&f)
        .model(ConsistencyModel::Full)
        .workers(4)
        .ghost_staleness(0)
        .ghost_batch(1)
        .run_on(&ShardedEngine::new(k), &mut g, &seeded(n, 4), &Sdt::new());
    assert_eq!(report.updates, n as u64 * rounds);
    let c = &report.contention;
    assert_eq!(c.boundary_updates, boundary_vertices * rounds);
    assert_eq!(c.ghost_syncs, total_replicas * rounds, "PR 3 exact flush accounting");
    assert_eq!(c.deltas_sent, boundary_vertices * rounds);
    assert_eq!(c.deltas_coalesced, 0);
    assert_eq!(c.staleness_pulls, 0, "synchronous flush leaves nothing to pull");
    assert_eq!(c.max_ghost_staleness, 0, "no reader ever saw a stale replica");
}

fn seeded(n: usize, workers: usize) -> MultiQueueFifo {
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

/// `s > 0` with a lazy flush window: readers may observe lag, but never
/// more than `s` versions — the admission check pulls anything worse — and
/// with a window far larger than the run, pulls are the only thing keeping
/// readers fresh, so they must actually fire.
#[test]
fn staleness_bound_is_enforced_and_pulls_fire() {
    let side = 16u32;
    let rounds = 1000u64;
    let f = SelfBump { rounds };
    for staleness in [1u64, 4] {
        let mut g = grid(side);
        let n = g.num_vertices();
        let report = Program::new()
            .update_fn(&f)
            .model(ConsistencyModel::Full)
            .workers(4)
            .ghost_staleness(staleness)
            // Window far beyond the run: flushes only happen on idle/exit,
            // so replica freshness rides on pull-on-demand.
            .ghost_batch(1_000_000)
            .run_on(&ShardedEngine::new(2), &mut g, &seeded(n, 4), &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "s={staleness}: conservation");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "s={staleness} vertex {v}");
        }
        let c = &report.contention;
        assert!(
            c.max_ghost_staleness <= staleness,
            "s={staleness}: reader observed lag {}",
            c.max_ghost_staleness
        );
        assert!(
            c.staleness_pulls > 0,
            "s={staleness}: lazy flushes must force admission pulls: {c:?}"
        );
        assert!(
            c.deltas_coalesced > 0,
            "s={staleness}: a huge window coalesces repeat writes: {c:?}"
        );
    }
}

// ---- socket backend: wire round-trip, pulls, backpressure, cleanup -------

/// Unit-level socket round-trip against real ghost tables: versioned
/// deltas for every replicated vertex cross real Unix-domain sockets, and
/// after a finalize barrier + drain the replicas equal the masters with
/// version == pending. Socket files live in a temp dir and vanish with
/// the transport.
#[test]
fn socket_transport_round_trips_into_ghost_tables() {
    let side = 6u32;
    let mut b = GraphBuilder::new();
    for i in 0..side * side {
        b.add_vertex(i as u64);
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    let mut g = b.build();
    let n = g.num_vertices();
    let sg = ShardedGraph::new(&mut g, 3);
    assert!(sg.num_ghosts() > 0);
    let transport = SocketTransport::new(&sg).expect("socket setup");
    let dir = transport.socket_dir().to_path_buf();
    assert!(dir.exists(), "socket files live in a per-run temp dir");

    let mut sent_bytes = 0u64;
    for v in 0..n as u32 {
        if sg.replicas_of(v).is_empty() {
            continue;
        }
        *g.vertex_data(v) = 1000 + v as u64;
        let ver = sg.bump_master(v);
        let r = transport.send(sg.owner_of(v), v, ver, &(1000 + v as u64));
        assert_eq!(r.replicas_now, 0, "socket applies at drain");
        assert!(r.bytes > 0);
        sent_bytes += r.bytes;
    }
    assert!(sent_bytes > 0);

    transport.finalize();
    let mut applied = 0u64;
    let mut drained_bytes = 0u64;
    for s in 0..sg.num_shards() {
        let d = transport.drain(s);
        applied += d.applied;
        drained_bytes += d.bytes;
    }
    assert_eq!(applied as usize, sg.num_ghosts(), "every replica written once");
    assert_eq!(drained_bytes, sent_bytes, "every shipped byte consumed");
    assert!(sg.ghosts_consistent(&mut g), "payloads round-tripped the kernel");
    for sh in sg.shards() {
        for e in sh.ghosts() {
            assert_eq!(e.version(), e.pending_version(), "nothing left in flight");
        }
    }
    drop(transport);
    assert!(!dir.exists(), "socket files cleaned up on drop");
}

/// Unit-level pull round-trip on every backend: the request/reply path
/// must refresh a lagging replica to the served version, and only the
/// serializing backends report the pull as wire-served.
#[test]
fn pull_round_trip_serves_through_request_reply_on_serializing_backends() {
    let run = |backend: &str| {
        let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
        for i in 0..8 {
            b.add_vertex(i as u64);
        }
        for i in 0..7u32 {
            b.add_undirected(i, i + 1, (), ());
        }
        let mut g = b.build();
        let sg = ShardedGraph::new(&mut g, 2);
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        sg.bump_master(v);
        sg.bump_master(v);
        sg.bump_master(v);
        assert_eq!(entry.version(), 0, "replica starts 3 versions behind");

        let socket_t;
        let channel_t;
        let direct_t;
        let transport: &dyn GhostTransport<u64> = match backend {
            "socket" => {
                socket_t = SocketTransport::new(&sg).expect("socket setup");
                &socket_t
            }
            "channel" => {
                channel_t = ChannelTransport::new(&sg);
                &channel_t
            }
            _ => {
                direct_t = DirectTransport::new(&sg);
                &direct_t
            }
        };
        let served_value = 4242u64;
        let req = PullRequest { vertex: v, min_version: sg.master_version(v) };
        let receipt = transport.pull(dst as usize, req, &|u| {
            assert_eq!(u, v, "service asked for the requested vertex");
            (&served_value, sg.master_version(u))
        });
        assert!(receipt.applied, "{backend}: lagging replica must refresh");
        assert_eq!(entry.read(), 4242, "{backend}: served data landed");
        assert_eq!(entry.version(), 3, "{backend}: served version landed");
        let serializing = backend != "direct";
        assert_eq!(receipt.served, serializing, "{backend}: wire-served flag");
        assert_eq!(
            receipt.bytes > PullRequest::WIRE_LEN as u64,
            serializing,
            "{backend}: request + reply bytes counted"
        );
    };
    run("direct");
    run("channel");
    run("socket");
}

/// Engine-level pull-path acceptance: with a never-closing sync window,
/// staleness pulls are the only freshness mechanism. On serializing
/// backends every one of them must be served through the transport
/// request/reply path (`pulls_served == staleness_pulls > 0` — direct
/// master reads are exactly their difference, asserted zero); the direct
/// backend reports the same pulls with zero wire-served.
#[test]
fn socket_and_channel_pulls_never_read_master_directly() {
    let side = 12u32;
    let rounds = 200u64;
    let f = SelfBump { rounds };
    let run = |backend: &'static str| {
        let mut g = grid(side);
        let n = g.num_vertices();
        let program = Program::new()
            .update_fn(&f)
            .model(ConsistencyModel::Full)
            .workers(4)
            .shards(2)
            .ghost_staleness(2)
            // Window far beyond the run: freshness rides on pulls alone.
            .ghost_batch(1_000_000)
            .transport(backend);
        let report = program.run(&mut g, &seeded(n, 4), &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "{backend}: conservation");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "{backend} vertex {v}");
        }
        report
    };

    for backend in ["channel", "socket"] {
        let c = run(backend).contention;
        assert!(c.staleness_pulls > 0, "{backend}: lazy flushes force pulls");
        assert_eq!(
            c.pulls_served, c.staleness_pulls,
            "{backend}: zero direct master reads at admission"
        );
        assert!(c.max_ghost_staleness <= 2, "{backend}: bound still enforced");
    }
    let c = run("direct").contention;
    assert!(c.staleness_pulls > 0);
    assert_eq!(c.pulls_served, 0, "direct backend pulls are in-place reads");
}

/// A one-byte send window forces every send after the first to stall
/// until the reader thread lands the in-flight frame: backpressure is
/// counted, yet every delta still arrives (newest version wins).
#[test]
fn socket_backpressure_blocks_flush_and_counts_stalls() {
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for i in 0..8 {
        b.add_vertex(i as u64);
    }
    for i in 0..7u32 {
        b.add_undirected(i, i + 1, (), ());
    }
    let mut g = b.build();
    let sg = ShardedGraph::new(&mut g, 2);
    let t = SocketTransport::with_send_buffer(&sg, 1).expect("socket setup");
    let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
    let owner = sg.owner_of(v);
    let (dst, gi) = sg.replicas_of(v)[0];
    let rounds = 200u64;
    for round in 1..=rounds {
        let ver = sg.bump_master(v);
        t.send(owner, v, ver, &(round * 10));
    }
    assert!(
        t.backpressure_stalls() > 0,
        "a 1-byte window must stall the sender"
    );
    t.finalize();
    let applied = t.drain(dst as usize).applied;
    assert!(applied >= 1, "at least the newest delta applies");
    let entry = sg.shard(dst as usize).ghost(gi as usize);
    assert_eq!(entry.version(), rounds, "the newest version won");
    assert_eq!(entry.read(), rounds * 10);
}

// ---- shm backend: ring integrity, pipelining, socket-z wire bytes --------

/// Concurrent SPSC torn-frame/wraparound stress: a producer thread pushes
/// 20k self-describing frames of rotating sizes through a 256-byte ring —
/// every frame boundary wraps the ring at some point — while the consumer
/// pops concurrently. Whole-frame publication means the consumer must see
/// every frame exactly once, in order, with every payload byte intact:
/// a torn header, torn payload, or resurfaced stale byte fails loudly.
#[test]
fn shm_ring_never_yields_torn_frames_across_wraparound() {
    let frames = 20_000u32;
    let (mut tx, mut rx) = shm_ring(256);
    assert!(tx.capacity() >= 256, "capacity rounds up, never down");
    let producer = std::thread::spawn(move || {
        for seq in 0..frames {
            // Sizes 1..=53 are coprime with the power-of-two capacity, so
            // frames straddle the wrap point at every possible offset.
            let len = (seq % 53 + 1) as usize;
            let mut frame = Vec::with_capacity(8 + len);
            frame.extend_from_slice(&seq.to_le_bytes());
            frame.extend_from_slice(&(len as u32).to_le_bytes());
            frame.resize(8 + len, seq as u8);
            while !tx.try_push(&frame) {
                // Ring full: the concurrent consumer frees space.
                std::thread::yield_now();
            }
        }
    });
    let mut buf = Vec::new();
    let mut seen = 0u32;
    while seen < frames {
        buf.clear();
        if rx.pop_all(&mut buf) == 0 {
            std::thread::yield_now();
            continue;
        }
        let mut at = 0usize;
        while at < buf.len() {
            assert!(buf.len() - at >= 8, "header never torn");
            let seq = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
            let len =
                u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap()) as usize;
            assert_eq!(seq, seen, "frames arrive exactly once, in order");
            assert_eq!(len, (seq % 53 + 1) as usize, "length survived the wire");
            assert!(buf.len() - at - 8 >= len, "payload never torn");
            assert!(
                buf[at + 8..at + 8 + len].iter().all(|&b| b == seq as u8),
                "payload bytes are the published ones (frame {seq})"
            );
            at += 8 + len;
            seen += 1;
        }
    }
    producer.join().unwrap();
}

/// A star cut: vertex 0 (shard 0 under the contiguous block partition)
/// adjacent to four shard-1 vertices, so shard 0 holds four ghosts of the
/// same remote owner — the shape that lets one admission batch >1 pull.
fn star_cut() -> DataGraph<u64, ()> {
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for i in 0..16 {
        b.add_vertex(i as u64);
    }
    for i in 0..4u32 {
        b.add_undirected(0, 8 + i, (), ());
    }
    b.build()
}

/// Unit-level pull pipelining: `pull_many` toward one owner must put every
/// request on the lane before collecting the replies — both pipelining
/// backends count the whole wave as pipelined, serve every request through
/// request/reply bytes, and land the served data in the ghost table.
#[test]
fn pull_many_overlaps_requests_on_shm_and_socket_lanes() {
    fn stale_wave(g: &mut DataGraph<u64, ()>, sg: &ShardedGraph<u64>) -> Vec<PullRequest> {
        let reqs: Vec<PullRequest> = (8..12u32)
            .map(|v| {
                *g.vertex_data(v) = 700 + v as u64;
                sg.bump_master(v);
                PullRequest { vertex: v, min_version: sg.master_version(v) }
            })
            .collect();
        assert!(reqs.len() > 1, "a wave needs more than one pull in flight");
        reqs
    }
    fn check_wave(
        backend: &str,
        sg: &ShardedGraph<u64>,
        reqs: &[PullRequest],
        transport: &dyn GhostTransport<u64>,
    ) {
        let served: Vec<u64> = (0..16).map(|v| 700 + v).collect();
        let receipts = transport.pull_many(0, reqs, &|u| {
            (&served[u as usize], sg.master_version(u))
        });
        assert_eq!(receipts.len(), reqs.len());
        for (i, r) in receipts.iter().enumerate() {
            assert!(r.served, "{backend} pull {i}: rides request/reply");
            assert!(r.applied, "{backend} pull {i}: lagging replica refreshed");
            assert!(
                r.bytes > PullRequest::WIRE_LEN as u64,
                "{backend} pull {i}: request + reply bytes counted"
            );
        }
        for v in 8..12u32 {
            let (dst, gi) = sg.replicas_of(v)[0];
            assert_eq!(dst, 0, "star ghosts live on shard 0");
            let e = sg.shard(0).ghost(gi as usize);
            assert_eq!(e.read(), 700 + v as u64, "{backend}: served data landed");
            assert_eq!(e.version(), 1, "{backend}: served version landed");
        }
    }

    {
        let mut g = star_cut();
        let sg = ShardedGraph::new(&mut g, 2);
        let reqs = stale_wave(&mut g, &sg);
        let t = ShmTransport::new(&sg);
        let before = t.pulls_pipelined();
        check_wave("shm", &sg, &reqs, &t);
        assert!(
            t.pulls_pipelined() - before >= reqs.len() as u64,
            "shm: the whole wave was in flight together"
        );
    }
    {
        let mut g = star_cut();
        let sg = ShardedGraph::new(&mut g, 2);
        let reqs = stale_wave(&mut g, &sg);
        let t = SocketTransport::new(&sg).expect("socket setup");
        let before = t.pulls_pipelined();
        check_wave("socket", &sg, &reqs, &t);
        assert!(
            t.pulls_pipelined() - before >= reqs.len() as u64,
            "socket: the whole wave was in flight together"
        );
    }
}

/// Engine-level pipelining acceptance: on the star cut with a
/// never-closing sync window, one admission refresh at vertex 0 batches
/// all four stale ghosts into a single `pull_many` wave — and every one
/// of those pulls must still ride the request/reply path
/// (`pulls_served == staleness_pulls`), with the bound enforced.
#[test]
fn batched_admission_pulls_keep_request_reply_accounting_on_shm_and_socket_z() {
    let rounds = 50u64;
    let f = SelfBump { rounds };
    for backend in ["shm", "socket-z"] {
        let mut g = star_cut();
        let n = g.num_vertices();
        let report = Program::new()
            .update_fn(&f)
            .model(ConsistencyModel::Full)
            .workers(4)
            .shards(2)
            .ghost_staleness(1)
            // Window far beyond the run: freshness rides on pulls alone.
            .ghost_batch(1_000_000)
            .transport(backend)
            .run(&mut g, &seeded(n, 4), &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "{backend}: conservation");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "{backend} vertex {v}");
        }
        let c = &report.contention;
        assert!(c.staleness_pulls > 0, "{backend}: lazy window forces pulls");
        assert_eq!(
            c.pulls_served, c.staleness_pulls,
            "{backend}: batched admission pulls all ride request/reply"
        );
        assert!(c.max_ghost_staleness <= 1, "{backend}: bound enforced");
    }
}

/// Deterministic socket-z byte comparison (the socket twin of the
/// channel-z test above): identical synchronous u64 delta streams with no
/// pull traffic — the raw socket ships a flat 24 B frame per delta, and
/// socket-z's varint envelope body must undercut it strictly, per delta
/// and in total.
#[test]
fn socket_z_strictly_cuts_bytes_shipped_vs_raw_socket() {
    let n = 16usize;
    let rounds = 100u64;
    let f = SelfBump { rounds };
    let run = |compress: bool| {
        let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n as u32 - 1 {
            b.add_undirected(i, i + 1, (), ());
        }
        let mut g = b.build();
        let eng = if compress {
            SocketShardedEngine::compressed(2)
        } else {
            SocketShardedEngine::new(2)
        };
        let report = Program::new()
            .update_fn(&f)
            .workers(2)
            .model(ConsistencyModel::Full)
            .ghost_staleness(1_000_000)
            .ghost_batch(1)
            .run_on(&eng, &mut g, &seeded(n, 2), &Sdt::new());
        assert_eq!(report.updates, n as u64 * rounds, "compress={compress}");
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), rounds, "compress={compress} vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.staleness_pulls, 0, "huge bound leaves nothing to pull");
        assert_eq!(c.deltas_coalesced, 0, "window 1 ships every record");
        assert_eq!(c.deltas_sent, c.boundary_updates);
        report
    };
    let raw = run(false).contention;
    let z = run(true).contention;
    assert_eq!(raw.deltas_sent, z.deltas_sent, "identical synchronous delta streams");
    assert_eq!(raw.bytes_shipped, raw.deltas_sent * 24, "raw u64 frame is a flat 24 B");
    assert!(
        z.bytes_shipped < raw.bytes_shipped,
        "socket-z must strictly cut the wire bytes: {} vs {}",
        z.bytes_shipped,
        raw.bytes_shipped
    );
    let z_per_delta = z.bytes_shipped as f64 / z.deltas_sent as f64;
    assert!(
        z_per_delta < 24.0,
        "socket-z bytes/delta {z_per_delta:.1} must undercut the 24 B raw frame"
    );
}
