//! Fault-tolerance stress tests: the sharded engine run over a
//! deterministic lossy wire ([`graphlab::transport::FaultInjector`]) must
//! still produce sequential-equivalent results — drops are healed by
//! staleness pulls, duplicates and reorders are absorbed by newest-wins
//! versioning, severed pulls are retried at admission — and a run killed
//! mid-flight by a shard abort must be recoverable from its latest
//! Chandy–Lamport snapshot (restore the masters, re-run, converge to the
//! uninterrupted fixed point).
//!
//! Assertions deliberately omitted under faults: `max_ghost_staleness <=
//! bound` (an exhausted retry budget admits a stale read by design) and
//! `pulls_served == staleness_pulls` (a severed pull is counted but never
//! served).

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use graphlab::apps::gibbs::{chromatic_sets, GibbsEdge, GibbsUpdate, GibbsVertex};
use graphlab::apps::mrf::{random_mrf, BpEdge, BpVertex, Mrf};
use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{
    ChannelShardedEngine, Engine, Program, SequentialEngine, ShardedEngine, Snapshot,
    SocketShardedEngine, StopReason, ThreadedEngine, UpdateContext, UpdateFn,
};
use graphlab::graph::{DataGraph, GraphBuilder};
use graphlab::scheduler::{
    FifoScheduler, MultiQueueFifo, PriorityScheduler, Scheduler, SetScheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::transport::FaultPlan;
use graphlab::util::Pcg32;
use std::sync::Arc;

/// The standard lossy wire for the conservation tests: drops, duplicates,
/// delays/reorders on the delta lanes plus severed staleness pulls, all
/// from one seed.
fn lossy_wire(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        drop_per_mille: 100,
        dup_per_mille: 60,
        delay_per_mille: 60,
        sever_per_mille: 200,
    }
}

// ---- BP: lossy wire vs sequential ------------------------------------------

fn run_bp_sequential(mrf: &mut Mrf, bound: f32) {
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    let sched = PriorityScheduler::new(n);
    for v in 0..n as u32 {
        sched.add_task(Task::with_priority(v, 1.0));
    }
    let upd = BpUpdate::new(mrf.arity, bound, Arc::new(mrf.tables.clone()));
    Program::new()
        .update_fn(&upd)
        .model(ConsistencyModel::Edge)
        .max_updates(200_000)
        .run_on(&SequentialEngine, &mut mrf.graph, &sched, &sdt);
}

/// Shared acceptance harness: BP over a seeded lossy wire must still reach
/// the sequential fixed point — with a tight staleness bound the admission
/// pulls heal every drop, and the retry loop rides out severed pulls. The
/// injector must actually have fired (`faults_injected > 0`) and severed
/// pulls must actually have been retried (`pull_retries > 0`), or the run
/// proved nothing.
fn bp_survives_lossy_wire_on<Eng: Engine<BpVertex, BpEdge>>(
    make: impl Fn(usize) -> Eng,
    backend: &str,
    shard_counts: &[usize],
) {
    let mk = || {
        let mut rng = Pcg32::seed_from_u64(42);
        random_mrf(80, 160, 3, &mut rng)
    };
    let mut seq = mk();
    run_bp_sequential(&mut seq, 1e-6);
    let reference: Vec<Vec<f32>> =
        (0..80u32).map(|v| seq.graph.vertex_data(v).belief.clone()).collect();

    for &k in shard_counts {
        let mut par = mk();
        let n = par.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [1.0f64; 3]);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let upd = BpUpdate::new(par.arity, 1e-6, Arc::new(par.tables.clone()));
        let report = Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Full)
            // Tight bound + lazy window: drops leave replicas lagging, so
            // pulls (the healing path) must fire constantly.
            .ghost_staleness(1)
            .ghost_batch(8)
            .fault_plan(lossy_wire(1234 + k as u64))
            .max_updates(500_000)
            .run_on(&make(k), &mut par.graph, &sched, &sdt);
        assert!(report.updates > 0, "{backend} k={k}");
        let c = &report.contention;
        assert_eq!(c.shards, k);
        assert!(c.faults_injected > 0, "{backend} k={k}: the wire must actually be lossy");
        assert!(
            c.pull_retries > 0,
            "{backend} k={k}: severed pulls must force admission retries: {c:?}"
        );
        assert!(c.staleness_pulls > 0, "{backend} k={k}: drops must force pulls");
        for v in 0..n as u32 {
            let b = &par.graph.vertex_data(v).belief;
            for (x, y) in reference[v as usize].iter().zip(b.iter()) {
                assert!(
                    (x - y).abs() < 5e-3,
                    "{backend} k={k} vertex {v}: seq={:?} got={b:?}",
                    reference[v as usize]
                );
            }
        }
    }
}

/// Acceptance: ChannelTransport-backed BP reaches the sequential fixed
/// point through a seeded drop/duplicate/delay/sever fault plan at
/// k in {2, 4}.
#[test]
fn channel_bp_matches_sequential_beliefs_over_lossy_wire() {
    bp_survives_lossy_wire_on(ChannelShardedEngine::new, "channel", &[2, 4]);
}

/// Acceptance: the same lossy wire wrapped around real Unix-socket lanes.
#[test]
fn socket_bp_matches_sequential_beliefs_over_lossy_wire() {
    bp_survives_lossy_wire_on(SocketShardedEngine::new, "socket", &[2]);
}

// ---- Gibbs: lossy wire conservation ----------------------------------------

fn color_graph(g: &mut DataGraph<GibbsVertex, GibbsEdge>) {
    let n = g.num_vertices();
    let sched = FifoScheduler::new(n);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let upd = ColoringUpdate;
    Program::new()
        .update_fn(&upd)
        .workers(2)
        .model(ConsistencyModel::Edge)
        .run_on(&ThreadedEngine, g, &sched, &Sdt::new());
}

/// Chromatic Gibbs conserves exactly one sample per vertex per sweep no
/// matter what the wire drops, duplicates, or reorders: sample counts live
/// in the master rows, and the scheduler's sweep plan is unaffected by
/// ghost traffic. The faults only perturb *which* neighbor values a
/// sampler conditions on — never how often it runs.
#[test]
fn channel_gibbs_conserves_sweeps_over_lossy_wire() {
    use graphlab::apps::mrf::EdgePotential;
    let sweeps = 300usize;
    let build = || {
        let mut b = GraphBuilder::new();
        for _ in 0..8 {
            b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
        }
        let e = GibbsEdge { potential: EdgePotential::Table(0) };
        for i in 0..7u32 {
            b.add_undirected(i, i + 1, e, e);
        }
        b.build()
    };
    let tables = vec![vec![1.5, 0.5, 0.5, 1.5]];

    for k in [2usize, 4] {
        let mut g = build();
        color_graph(&mut g);
        assert!(validate_coloring(&mut g).is_ok());
        let classes = color_classes(&mut g);
        let sets = chromatic_sets(&classes, sweeps, 0);
        let sched = SetScheduler::planned(
            &sets,
            g.num_vertices(),
            |v| g.neighbors(v),
            ConsistencyModel::Edge,
        );
        let upd = GibbsUpdate::new(2, Arc::new(tables.clone()), 4, 9);
        let report = Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Full)
            .ghost_staleness(1)
            .ghost_batch(4)
            .fault_plan(lossy_wire(777 + k as u64))
            .run_on(&ChannelShardedEngine::new(k), &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, 8 * sweeps as u64, "k={k}: sweep conservation");
        let c = &report.contention;
        assert!(c.faults_injected > 0, "k={k}: the wire must actually be lossy");
        for v in 0..8u32 {
            let total: u32 = g.vertex_data(v).counts.iter().sum();
            assert_eq!(total as usize, sweeps, "k={k} vertex {v}: one sample per sweep");
        }
    }
}

// ---- snapshots + kill-one-shard recovery -----------------------------------

struct SelfBump {
    rounds: u64,
}
impl UpdateFn<u64, ()> for SelfBump {
    fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
        *scope.vertex_mut() += 1;
        if *scope.vertex() < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

fn chain(n: usize) -> DataGraph<u64, ()> {
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n as u32 - 1 {
        b.add_undirected(i, i + 1, (), ());
    }
    b.build()
}

fn seeded(n: usize, workers: usize) -> MultiQueueFifo {
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

/// A healthy run with `snapshot_every` set captures complete epoch
/// snapshots: every one holds all master rows, epochs ascend, and the
/// counter in the report matches.
#[test]
fn snapshots_capture_every_master_row_per_epoch() {
    let n = 16usize;
    let rounds = 200u64;
    let f = SelfBump { rounds };
    let mut g = chain(n);
    let report = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(4)
        .ghost_batch(4)
        .snapshot_every(500)
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert_eq!(report.updates, n as u64 * rounds, "conservation");
    assert!(!report.snapshots.is_empty(), "a 3200-update run passes epoch 500 several times");
    assert_eq!(report.contention.snapshots_taken, report.snapshots.len() as u64);
    let mut last_epoch = 0;
    for snap in &report.snapshots {
        assert!(snap.epoch() > last_epoch, "epochs strictly ascend");
        last_epoch = snap.epoch();
        assert_eq!(snap.rows(), n as u64, "a complete snapshot holds every master row");
        let rows = snap.decode_rows::<u64>().expect("snapshot decodes");
        for (v, _version, value) in rows {
            assert!(value <= rounds, "vertex {v} row is a committed counter value");
        }
    }
}

/// The tentpole acceptance: kill one shard mid-run (its batched deltas are
/// lost, the run stops as `ShardAborted`, every thread still joins — this
/// test completing at all proves no hang), then restore the latest
/// completed snapshot and re-run. The recovered run must reach exactly the
/// sequential fixed point: every counter at `rounds`.
#[test]
fn kill_one_shard_then_restore_from_snapshot_reaches_sequential_result() {
    let n = 16usize;
    let rounds = 200u64;
    let f = SelfBump { rounds };
    let mut g = chain(n);

    let crashed = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(4)
        .ghost_batch(4)
        .snapshot_every(100)
        .abort_shard(1, 800)
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert_eq!(crashed.stop, StopReason::ShardAborted, "the abort must surface");
    assert!(
        crashed.updates < n as u64 * rounds,
        "the run died mid-flight: {} updates",
        crashed.updates
    );
    assert!(
        !crashed.snapshots.is_empty(),
        "epochs completed before the abort: {crashed:?}"
    );
    let latest = crashed.snapshots.last().unwrap();
    assert_eq!(latest.rows(), n as u64);

    // Recovery: rewind the graph to the snapshot cut (shard 0's
    // post-snapshot progress is rolled back too — the cut is global),
    // then re-run the same program without the abort.
    let restored = ShardedEngine::restore_from_snapshot(&mut g, latest);
    assert_eq!(restored, n as u64);
    for v in 0..n as u32 {
        let row = *g.vertex_data(v);
        assert!(row <= rounds, "restored row {v} = {row} is a committed value");
    }

    let recovered = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(4)
        .ghost_batch(4)
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert_ne!(recovered.stop, StopReason::ShardAborted);
    for v in 0..n as u32 {
        assert_eq!(
            *g.vertex_data(v),
            rounds,
            "vertex {v}: restart-from-snapshot reaches the sequential result"
        );
    }
}

/// Recovery still works when the wire that killed the first run stays
/// lossy for the second: restore + re-run over the same fault plan.
#[test]
fn restore_then_rerun_survives_a_still_lossy_wire() {
    let n = 16usize;
    let rounds = 200u64;
    let f = SelfBump { rounds };
    let mut g = chain(n);

    let crashed = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(2)
        .ghost_batch(4)
        .fault_plan(lossy_wire(5150))
        .snapshot_every(100)
        .abort_shard(0, 600)
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert_eq!(crashed.stop, StopReason::ShardAborted);
    assert!(crashed.contention.faults_injected > 0);
    assert!(!crashed.snapshots.is_empty());

    ShardedEngine::restore_from_snapshot(&mut g, crashed.snapshots.last().unwrap());
    let recovered = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(2)
        .ghost_batch(4)
        .fault_plan(lossy_wire(5151))
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert!(recovered.contention.faults_injected > 0, "second wire is lossy too");
    for v in 0..n as u32 {
        assert_eq!(*g.vertex_data(v), rounds, "vertex {v}: recovered over a lossy wire");
    }
}

/// Snapshots spill to `snapshot_dir` and round-trip through the file
/// format bit-exactly — the on-disk copy IS the in-report snapshot.
#[test]
fn snapshot_dir_spills_files_that_read_back_exactly() {
    let dir = std::env::temp_dir().join(format!("graphlab-fault-stress-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let n = 16usize;
    let f = SelfBump { rounds: 200 };
    let mut g = chain(n);
    let report = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(4)
        .ghost_batch(4)
        .snapshot_every(500)
        .snapshot_dir(&dir)
        .run_on(&ChannelShardedEngine::new(2), &mut g, &seeded(n, 2), &Sdt::new());
    assert!(!report.snapshots.is_empty());
    for snap in &report.snapshots {
        let path = dir.join(format!("snapshot-epoch-{}.bin", snap.epoch()));
        assert!(path.exists(), "epoch {} spilled to disk", snap.epoch());
        let read = Snapshot::read_file(&path).expect("snapshot file reads back");
        assert_eq!(&read, snap, "epoch {}: disk copy is bit-exact", snap.epoch());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
