//! Stress tests for the lock-free task-distribution layer: the Chase–Lev
//! work-stealing deque and MPMC injector under concurrent push/pop/steal
//! (no lost or duplicated tasks), owner-affinity routing in the rebased
//! schedulers, and the engine's deferral-fairness escalation on a
//! saturated Full-consistency hub.

use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{Program, SequentialEngine, ThreadedEngine, UpdateContext, UpdateFn};
use graphlab::graph::{DataGraph, GraphBuilder, PartitionMap};
use graphlab::scheduler::{
    Injector, MultiQueueFifo, Scheduler, Task, WorkStealingDeque,
};
use graphlab::sdt::Sdt;
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

/// Owner pushes/pops a bounded Chase–Lev deque while three thieves steal
/// continuously: every task must be delivered exactly once, through
/// whichever end.
#[test]
fn deque_loses_and_duplicates_nothing_under_steal_pressure() {
    let n: u32 = 100_000;
    let deque: Arc<WorkStealingDeque<Task>> = Arc::new(WorkStealingDeque::new(128));
    let seen: Arc<Vec<AtomicU8>> = Arc::new((0..n).map(|_| AtomicU8::new(0)).collect());
    let done = Arc::new(AtomicBool::new(false));

    let mut thieves = Vec::new();
    for _ in 0..3 {
        let deque = Arc::clone(&deque);
        let seen = Arc::clone(&seen);
        let done = Arc::clone(&done);
        thieves.push(std::thread::spawn(move || {
            let mut stolen = 0u64;
            loop {
                match deque.steal() {
                    Some(t) => {
                        seen[t.vertex as usize].fetch_add(1, Ordering::Relaxed);
                        stolen += 1;
                    }
                    None => {
                        if done.load(Ordering::Acquire) && deque.is_empty() {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            }
            stolen
        }));
    }

    for v in 0..n {
        let mut t = Task::new(v);
        loop {
            match deque.push(t) {
                Ok(()) => break,
                Err(back) => {
                    // full: drain one locally and retry (the engine spills
                    // to the injector here; the invariant is the same)
                    t = back;
                    if let Some(p) = deque.pop() {
                        seen[p.vertex as usize].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    while let Some(p) = deque.pop() {
        seen[p.vertex as usize].fetch_add(1, Ordering::Relaxed);
    }
    done.store(true, Ordering::Release);
    let stolen: u64 = thieves.into_iter().map(|h| h.join().unwrap()).sum();

    for (v, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "task {v} lost or duplicated");
    }
    // With a 128-slot deque and 100k pushes the thieves must actually have
    // participated — otherwise this test isn't exercising the race paths.
    assert!(stolen > 0, "steal path never taken");
}

/// Four producers × four consumers through the injector (ring + overflow):
/// exactly-once delivery of every task.
#[test]
fn injector_mpmc_exactly_once_through_overflow() {
    let producers: u32 = 4;
    let per: u32 = 50_000;
    let n = producers * per;
    // Tiny ring forces constant spills into the overflow list.
    let q: Arc<Injector<Task>> = Arc::new(Injector::new(64));
    let seen: Arc<Vec<AtomicU8>> = Arc::new((0..n).map(|_| AtomicU8::new(0)).collect());
    let produced = Arc::new(AtomicUsize::new(0));
    let consumed = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::new();
    for p in 0..producers {
        let q = Arc::clone(&q);
        let produced = Arc::clone(&produced);
        handles.push(std::thread::spawn(move || {
            for i in 0..per {
                q.push(Task::new(p * per + i));
                produced.fetch_add(1, Ordering::Release);
            }
        }));
    }
    for _ in 0..4 {
        let q = Arc::clone(&q);
        let seen = Arc::clone(&seen);
        let produced = Arc::clone(&produced);
        let consumed = Arc::clone(&consumed);
        handles.push(std::thread::spawn(move || loop {
            match q.pop() {
                Some(t) => {
                    seen[t.vertex as usize].fetch_add(1, Ordering::Relaxed);
                    consumed.fetch_add(1, Ordering::AcqRel);
                }
                None => {
                    if produced.load(Ordering::Acquire) == n as usize
                        && consumed.load(Ordering::Acquire) >= n as usize
                    {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(consumed.load(Ordering::Relaxed), n as usize);
    for (v, c) in seen.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "task {v} lost or duplicated");
    }
}

fn star(leaves: u32) -> DataGraph<(u64, u64), ()> {
    let mut b = GraphBuilder::new();
    let hub = b.add_vertex((0u64, 0u64));
    for _ in 0..leaves {
        let leaf = b.add_vertex((0u64, 0u64));
        b.add_undirected(hub, leaf, (), ());
    }
    b.build()
}

/// Leaf update under Full consistency: burn a little compute (so lock holds
/// are long enough to observably contend), then push a bump into the hub
/// through the write-locked scope.
struct BumpHub {
    rounds: u64,
}
impl UpdateFn<(u64, u64), ()> for BumpHub {
    fn update(&self, scope: &mut Scope<'_, (u64, u64), ()>, ctx: &mut UpdateContext<'_>) {
        let mut spin = scope.center() as u64;
        for i in 0..256u64 {
            spin = spin.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(spin);
        for &u in scope.neighbors() {
            scope.neighbor_mut(u).0 += 1;
        }
        let data = scope.vertex_mut();
        data.1 += 1;
        if data.1 < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

/// Deferral fairness: on a saturated Full-consistency hub, with the
/// escalation bound forced low, repeatedly conflicted tasks must take the
/// blocking path (nonzero escalations) and the run must still match the
/// sequential engine exactly — the aged tasks complete, they don't starve.
#[test]
fn aged_tasks_escalate_and_complete_on_saturated_hub() {
    let leaves = 16u32;
    let rounds = 300u64;

    let seed_leaves = |sched: &dyn Scheduler, leaves: u32| {
        for v in 1..=leaves {
            sched.add_task(Task::new(v));
        }
    };

    let f = BumpHub { rounds };
    let program = Program::new()
        .update_fn(&f)
        .model(ConsistencyModel::Full)
        // Escalate on the very first retry of a deferred task: every
        // deferral immediately exercises the fairness path.
        .escalate_after(1);

    let mut seq_g = star(leaves);
    let seq_sched = MultiQueueFifo::new(seq_g.num_vertices(), 1);
    seed_leaves(&seq_sched, leaves);
    let seq_report = program.run_on(&SequentialEngine, &mut seq_g, &seq_sched, &Sdt::new());
    assert_eq!(seq_report.updates, leaves as u64 * rounds);
    let seq_hub = seq_g.vertex_data(0).0;

    let mut thr_g = star(leaves);
    let thr_sched = MultiQueueFifo::new(thr_g.num_vertices(), 4);
    seed_leaves(&thr_sched, leaves);
    let report =
        program.workers(4).run_on(&ThreadedEngine, &mut thr_g, &thr_sched, &Sdt::new());

    assert_eq!(report.updates, seq_report.updates, "no lost or duplicated updates");
    assert_eq!(thr_g.vertex_data(0).0, seq_hub, "no lost hub increments");
    for v in 1..=leaves {
        assert_eq!(thr_g.vertex_data(v).1, rounds, "leaf {v} round count");
    }
    assert!(
        report.contention.deferrals > 0,
        "a saturated Full-consistency hub must defer: {:?}",
        report.contention
    );
    assert!(
        report.contention.escalations > 0,
        "with escalate_after=1 every retried deferral escalates: {:?}",
        report.contention
    );
}

/// Owner-affinity accounting: on an embarrassingly parallel workload with
/// the affinity-routing multiqueue scheduler, most pops should land on the
/// owning worker, and a 1-worker run must report zero steals.
#[test]
fn affinity_hits_dominate_on_partitionable_load() {
    struct SelfBump {
        rounds: u64,
    }
    impl UpdateFn<u64, ()> for SelfBump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.rounds {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }
    let n = 1024usize;
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n as u32 - 1 {
        b.add_undirected(i, i + 1, (), ());
    }
    let mut g = b.build();
    let workers = 4;
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let f = SelfBump { rounds: 50 };
    let report = Program::new()
        .update_fn(&f)
        .workers(workers)
        .model(ConsistencyModel::Vertex)
        .run_on(&ThreadedEngine, &mut g, &sched, &Sdt::new());
    assert_eq!(report.updates, n as u64 * 50);
    // The scheduler routes every task to its owner's shard; with vertex
    // consistency on a self-rescheduling load, workers drain their own
    // shards and the counter records real hits. The hit *fraction* is
    // scheduling-skew dependent (a descheduled worker's shard is drained
    // by peers as misses), so only bound the counter's invariants here —
    // the exact-hit case is pinned at 1 worker in engine_stress.
    assert!(
        report.contention.affinity_hits > 0,
        "affinity-routing scheduler produced no hits: {:?}",
        report.contention
    );
    assert!(
        report.contention.affinity_hits <= report.updates,
        "affinity hits cannot exceed executed updates: {:?}",
        report.contention
    );
    // And the scheduler's advertised owner map is the contiguous-block
    // partition the engine's affinity counter is scored against.
    let pm = PartitionMap::new(n, workers);
    for v in [0u32, (n / 2) as u32, n as u32 - 1] {
        assert_eq!(sched.owner_of(v), Some(pm.owner_of(v)));
    }
}

/// 2-worker end-to-end smoke over the whole lock-free path (CI runs this
/// under --release): conservation plus sane counter accounting.
#[test]
fn two_worker_smoke_conserves_updates() {
    struct SelfBump;
    impl UpdateFn<u64, ()> for SelfBump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < 20 {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }
    let n = 256usize;
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n as u32 - 1 {
        b.add_undirected(i, i + 1, (), ());
    }
    let mut g = b.build();
    let sched = MultiQueueFifo::new(n, 2);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let f = SelfBump;
    let report = Program::new()
        .update_fn(&f)
        .workers(2)
        .model(ConsistencyModel::Edge)
        .run_on(&ThreadedEngine, &mut g, &sched, &Sdt::new());
    assert_eq!(report.updates, n as u64 * 20);
    for v in 0..n as u32 {
        assert_eq!(*g.vertex_data(v), 20);
    }
    let c = &report.contention;
    assert!(c.retries >= c.deferrals, "every deferred task is re-dispatched");
    assert_eq!(c.per_worker_deferrals.iter().sum::<u64>(), c.deferrals);
    assert_eq!(c.per_worker_conflicts.iter().sum::<u64>(), c.conflicts);
}

/// Steal-half auto-select: with the flip threshold floored at zero, any
/// worker that steals at all flips to steal-half mid-run — the run must
/// still conserve every update and count at most one flip per worker.
/// Conversely, an infinite threshold and the explicit `steal_half`
/// override must both record zero flips.
#[test]
fn auto_steal_half_flips_conserve_and_respect_overrides() {
    let leaves = 16u32;
    let rounds = 300u64;
    let f = BumpHub { rounds };
    let run = |auto_frac: f64, explicit: bool| {
        let mut g = star(leaves);
        let sched = MultiQueueFifo::new(g.num_vertices(), 4);
        for v in 1..=leaves {
            sched.add_task(Task::new(v));
        }
        let report = Program::new()
            .update_fn(&f)
            .model(ConsistencyModel::Full)
            .workers(4)
            .steal_half(explicit)
            .steal_half_auto(auto_frac)
            .run_on(&ThreadedEngine, &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, leaves as u64 * rounds, "conservation");
        for v in 1..=leaves {
            assert_eq!(g.vertex_data(v).1, rounds, "leaf {v} round count");
        }
        report
    };

    // Floor threshold: every worker that steals flips (once).
    let eager = run(0.0, false);
    assert!(
        eager.contention.auto_steal_half_flips <= 4,
        "at most one flip per worker: {:?}",
        eager.contention
    );
    // With real steal pressure some busy worker must have crossed the
    // floored threshold (a handful of steals could in principle all come
    // from a worker that barely ran, so gate on a meaningful count).
    if eager.contention.steals >= 32 {
        assert!(
            eager.contention.auto_steal_half_flips > 0,
            "steal pressure observed but no worker flipped: {:?}",
            eager.contention
        );
    }

    // Infinite threshold: auto-select disabled.
    let never = run(f64::INFINITY, false);
    assert_eq!(never.contention.auto_steal_half_flips, 0);

    // Explicit steal-half: workers start in half mode, nothing to flip.
    let forced = run(0.0, true);
    assert_eq!(
        forced.contention.auto_steal_half_flips, 0,
        "the explicit override pre-empts the auto-flip"
    );
}
