//! Integration tests over the PJRT runtime + built artifacts.
//!
//! These require `make artifacts` to have run (they are skipped with a
//! message otherwise, so `cargo test` stays usable on a fresh checkout).

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::mrf::{grid3d, GridDims};
use graphlab::consistency::ConsistencyModel;
use graphlab::engine::{Program, SequentialEngine};
use graphlab::runtime::{bp_artifact_available, AccelGridBp, ArtifactRegistry};
use graphlab::scheduler::{PriorityScheduler, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::Pcg32;
use std::path::PathBuf;
use std::sync::Arc;

fn artifact_dir() -> Option<PathBuf> {
    let dir = graphlab::runtime::default_artifact_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts under {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
fn registry_lists_and_compiles_all_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let names = reg.names();
    assert!(names.iter().any(|n| n.starts_with("bp_batch")));
    assert!(names.iter().any(|n| n.starts_with("gabp_batch")));
    assert!(names.iter().any(|n| n.starts_with("coem_batch")));
    for name in names {
        reg.load(&name).unwrap_or_else(|e| panic!("compile {name}: {e:#}"));
    }
}

#[test]
fn bp_batch_kernel_matches_rust_math() {
    let Some(dir) = artifact_dir() else { return };
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let exe = reg.load("bp_batch_b256_k5").unwrap();
    let (b, k) = (256usize, 5usize);
    let mut rng = Pcg32::seed_from_u64(7);
    let cavity: Vec<f32> = (0..b * k).map(|_| 0.05 + rng.next_f32()).collect();
    let psi: Vec<f32> = {
        // symmetric Laplace with lambda = 0.7
        let mut p = vec![0.0f32; k * k];
        for i in 0..k {
            for j in 0..k {
                p[i * k + j] = (-(0.7f64) * (i as f64 - j as f64).abs()).exp() as f32;
            }
        }
        p
    };
    let old: Vec<f32> = (0..b * k).map(|_| 0.05 + rng.next_f32()).collect();
    let outs = exe.run_f32(&[&cavity, &psi, &old]).unwrap();
    let (msg, res) = (&outs[0], &outs[1]);
    // rust-side reference
    for row in 0..b {
        let c = &cavity[row * k..(row + 1) * k];
        let mut want = vec![0.0f32; k];
        for (j, w) in want.iter_mut().enumerate() {
            for (i, ci) in c.iter().enumerate() {
                *w += psi[i * k + j] * ci;
            }
        }
        let total: f32 = want.iter().sum();
        for w in want.iter_mut() {
            *w /= total;
        }
        let mut l1 = 0.0f32;
        for j in 0..k {
            assert!(
                (msg[row * k + j] - want[j]).abs() < 1e-5,
                "row {row} col {j}: {} vs {}",
                msg[row * k + j],
                want[j]
            );
            l1 += (want[j] - old[row * k + j]).abs();
        }
        assert!((res[row] - l1).abs() < 1e-4, "row {row} residual");
    }
}

#[test]
fn gabp_batch_kernel_matches_rust_math() {
    let Some(dir) = artifact_dir() else { return };
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let exe = reg.load("gabp_batch_b1024").unwrap();
    let b = 1024usize;
    let mut rng = Pcg32::seed_from_u64(9);
    let p_cav: Vec<f32> = (0..b).map(|_| 0.5 + 4.0 * rng.next_f32()).collect();
    let h_cav: Vec<f32> = (0..b).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
    let a: Vec<f32> = (0..b).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let outs = exe.run_f32(&[&p_cav, &h_cav, &a]).unwrap();
    for i in 0..b {
        let want_p = -(a[i] * a[i]) / p_cav[i];
        let want_h = -(a[i] * h_cav[i]) / p_cav[i];
        assert!((outs[0][i] - want_p).abs() < 1e-5 * (1.0 + want_p.abs()));
        assert!((outs[1][i] - want_h).abs() < 1e-5 * (1.0 + want_h.abs()));
    }
}

#[test]
fn coem_batch_kernel_matches_rust_math() {
    let Some(dir) = artifact_dir() else { return };
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    let exe = reg.load("coem_batch_b256_d32_k4").unwrap();
    let (b, d, k) = (256usize, 32usize, 4usize);
    let mut rng = Pcg32::seed_from_u64(11);
    let nb: Vec<f32> = (0..b * d * k).map(|_| rng.next_f32()).collect();
    let mut w: Vec<f32> = (0..b * d).map(|_| rng.next_f32() * 2.0).collect();
    // zero-out some weights to exercise padding
    for i in (0..w.len()).step_by(5) {
        w[i] = 0.0;
    }
    let outs = exe.run_f32(&[&nb, &w]).unwrap();
    for row in 0..b {
        for j in 0..k {
            let mut acc = 0.0f32;
            let mut total = 0.0f32;
            for dd in 0..d {
                acc += w[row * d + dd] * nb[(row * d + dd) * k + j];
            }
            for dd in 0..d {
                total += w[row * d + dd];
            }
            let want = acc / total.max(1e-30);
            assert!(
                (outs[0][row * k + j] - want).abs() < 1e-4,
                "row {row} col {j}"
            );
        }
    }
}

/// The headline integration: the accelerated Jacobi driver must converge to
/// the same beliefs as the pure-rust residual-scheduled engine.
#[test]
fn accel_grid_bp_matches_engine_beliefs() {
    let Some(dir) = artifact_dir() else { return };
    if !bp_artifact_available(&dir, 256, 5) {
        eprintln!("SKIP: bp_batch_b256_k5 artifact missing");
        return;
    }
    let dims = GridDims::new(6, 6, 4);
    let k = 5;
    let lambda = [0.8f64, 0.8, 1.2];
    let mk = || {
        let mut rng = Pcg32::seed_from_u64(31);
        grid3d(dims, k, |_| (0..k).map(|_| 0.1 + rng.next_f32()).collect())
    };

    // pure-rust residual BP
    let mut reference = mk();
    {
        let n = reference.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, lambda);
        let sched = PriorityScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::with_priority(v, 1.0));
        }
        let upd = BpUpdate::new(k, 1e-7, Arc::new(Vec::new()));
        Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .max_updates(400_000)
            .run_on(&SequentialEngine, &mut reference.graph, &sched, &sdt);
    }

    // accelerated Jacobi sweeps through PJRT
    let mut accel_mrf = mk();
    let mut accel = AccelGridBp::open(&dir, 256, 5).unwrap();
    let (sweeps, residual) = accel.run(&mut accel_mrf, lambda, 200, 1e-6).unwrap();
    assert!(sweeps < 200, "accelerated BP did not converge (residual {residual})");

    let mut max_diff = 0.0f32;
    for v in 0..reference.graph.num_vertices() as u32 {
        let a = reference.graph.vertex_data(v).belief.clone();
        let b = &accel_mrf.graph.vertex_data(v).belief;
        for (x, y) in a.iter().zip(b) {
            max_diff = max_diff.max((x - y).abs());
        }
    }
    assert!(max_diff < 5e-3, "beliefs diverge between engines: {max_diff}");
}
