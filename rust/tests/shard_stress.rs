//! Stress tests for the sharding subsystem: the sharded engine must
//! conserve results against the sequential engine for every consistency
//! model and shard count, ghost versions must advance monotonically,
//! boundary updates must execute exactly once, k = 1 must degenerate to
//! the threaded engine's behavior, and the BFS relabel must shrink the
//! edge cut of a scrambled graph.

use graphlab::consistency::{ConsistencyModel, LockTable, Scope};
use graphlab::engine::{
    Program, SequentialEngine, ShardedEngine, ThreadedEngine, UpdateContext, UpdateFn,
};
use graphlab::graph::{DataGraph, GraphBuilder, ShardedGraph};
use graphlab::scheduler::{MultiQueueFifo, Scheduler, Task};
use graphlab::sdt::Sdt;

/// The engine-stress workload: fold the neighborhood into the center,
/// reschedule self for a fixed number of rounds. Valid under every model;
/// the center round counter makes lost updates exactly checkable.
struct NeighborhoodFold {
    rounds: u64,
}

impl UpdateFn<(u64, u64), ()> for NeighborhoodFold {
    fn update(&self, scope: &mut Scope<'_, (u64, u64), ()>, ctx: &mut UpdateContext<'_>) {
        let mut acc = 0u64;
        for &u in scope.neighbors() {
            acc = acc.wrapping_add(scope.neighbor(u).0).rotate_left(1);
        }
        let data = scope.vertex_mut();
        data.0 += 1;
        data.1 = data.1.wrapping_add(acc);
        if data.0 < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

fn grid(side: u32) -> DataGraph<(u64, u64), ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..side * side {
        b.add_vertex((0u64, 0u64));
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    b.build()
}

fn seeded(n: usize, workers: usize) -> MultiQueueFifo {
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

/// Result conservation per consistency model and shard count: the sharded
/// run must complete every scheduled round on every vertex and report the
/// same update total as the sequential engine.
#[test]
fn all_models_and_shard_counts_match_sequential() {
    let side = 12u32;
    let rounds = 15u64;
    for model in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
        let f = NeighborhoodFold { rounds };
        let program = Program::new().update_fn(&f).model(model);

        let mut seq_g = grid(side);
        let n = seq_g.num_vertices();
        let seq_report =
            program.run_on(&SequentialEngine, &mut seq_g, &seeded(n, 1), &Sdt::new());
        assert_eq!(seq_report.updates, n as u64 * rounds);

        let program = program.workers(4);
        for k in [1usize, 2, 4] {
            let mut g = grid(side);
            let report = program.run_on(
                &ShardedEngine::new(k),
                &mut g,
                &seeded(n, 4),
                &Sdt::new(),
            );
            assert_eq!(
                report.updates, seq_report.updates,
                "update conservation ({model:?}, k={k})"
            );
            assert_eq!(
                report.per_worker.iter().sum::<u64>(),
                report.updates,
                "per-worker accounting ({model:?}, k={k})"
            );
            assert_eq!(report.contention.shards, k);
            for v in 0..n as u32 {
                assert_eq!(
                    g.vertex_data(v).0,
                    rounds,
                    "vertex {v} lost updates ({model:?}, k={k})"
                );
            }
        }
    }
}

/// Ghost versions advance monotonically under engine traffic, and after a
/// final full sync every replica equals its owner's data.
#[test]
fn ghost_versions_monotone_and_consistent_after_sync() {
    let side = 8u32;
    let mut g = grid(side);
    let n = g.num_vertices();
    let k = 4;
    let sharded = ShardedGraph::new(&mut g, k);
    assert!(sharded.num_ghosts() > 0, "4-way grid split must ghost");

    let f = NeighborhoodFold { rounds: 20 };
    let report = Program::new().update_fn(&f).model(ConsistencyModel::Full).workers(4).run_on(
        &ShardedEngine::new(k),
        &mut g,
        &seeded(n, 4),
        &Sdt::new(),
    );
    assert!(report.contention.ghost_syncs > 0);

    // The engine built its own shard view; ours observed no syncs yet.
    // Drive the sync API directly and check per-entry monotonicity.
    let locks = LockTable::new(n);
    let (first_vertices, first) = sharded.sync_all(&g, &locks);
    assert_eq!(first as usize, sharded.num_ghosts());
    let replicated =
        (0..n as u32).filter(|&v| !sharded.replicas_of(v).is_empty()).count() as u64;
    assert_eq!(first_vertices, replicated, "interior vertices skipped before locking");
    let snapshot: Vec<u64> = sharded
        .shards()
        .iter()
        .flat_map(|s| s.ghosts().iter().map(|e| e.version()))
        .collect();
    assert!(snapshot.iter().all(|&v| v >= 1));
    let (second_vertices, second) = sharded.sync_all(&g, &locks);
    assert_eq!(second, first);
    assert_eq!(second_vertices, first_vertices);
    let after: Vec<u64> = sharded
        .shards()
        .iter()
        .flat_map(|s| s.ghosts().iter().map(|e| e.version()))
        .collect();
    for (b, a) in snapshot.iter().zip(&after) {
        assert!(a > b, "version must strictly increase per sync pass");
    }
    assert!(sharded.ghosts_consistent(&mut g), "replicas match owners after sync");
}

/// Exactly-once boundary accounting: the engine's boundary/ghost counters
/// must equal what the partition structure predicts (`rounds` updates per
/// boundary vertex, one ghost write per replica per update).
#[test]
fn exactly_once_boundary_updates() {
    let side = 8u32;
    let rounds = 25u64;
    let k = 2;
    let mut g = grid(side);
    let n = g.num_vertices();
    // Structural prediction from an identically-cut shard view.
    let probe = ShardedGraph::new(&mut g, k);
    let boundary_vertices: u64 =
        (0..n as u32).filter(|&v| probe.is_boundary(v)).count() as u64;
    let total_replicas: u64 =
        (0..n as u32).map(|v| probe.replicas_of(v).len() as u64).sum();
    assert!(boundary_vertices > 0);

    let f = NeighborhoodFold { rounds };
    let report = Program::new().update_fn(&f).model(ConsistencyModel::Edge).workers(4).run_on(
        &ShardedEngine::new(k),
        &mut g,
        &seeded(n, 4),
        &Sdt::new(),
    );
    assert_eq!(report.updates, n as u64 * rounds);
    assert_eq!(
        report.contention.boundary_updates,
        boundary_vertices * rounds,
        "each boundary vertex updates exactly once per round"
    );
    assert_eq!(
        report.contention.ghost_syncs,
        total_replicas * rounds,
        "each update of a replicated vertex writes each replica exactly once"
    );
}

/// k = 1 degenerates to the threaded engine: identical results and update
/// totals, and every shard-specific counter is structurally zero.
#[test]
fn one_shard_equals_threaded_engine() {
    let side = 10u32;
    let rounds = 12u64;
    let f = NeighborhoodFold { rounds };
    let program =
        Program::new().update_fn(&f).model(ConsistencyModel::Full).workers(4);

    let mut thr_g = grid(side);
    let n = thr_g.num_vertices();
    let thr_report =
        program.run_on(&ThreadedEngine, &mut thr_g, &seeded(n, 4), &Sdt::new());

    let mut sh_g = grid(side);
    let sh_report =
        program.run_on(&ShardedEngine::new(1), &mut sh_g, &seeded(n, 4), &Sdt::new());

    assert_eq!(sh_report.updates, thr_report.updates);
    for v in 0..n as u32 {
        assert_eq!(sh_g.vertex_data(v).0, thr_g.vertex_data(v).0, "vertex {v}");
    }
    let c = &sh_report.contention;
    assert_eq!(c.shards, 1);
    assert_eq!(c.ghost_syncs, 0, "one shard has no ghosts");
    assert_eq!(c.boundary_updates, 0);
    assert_eq!(c.handoffs, 0);
    assert_eq!(c.pipelined_stalls, 0);
}

/// Acceptance: a cut graph at k >= 2 reports nonzero ghost syncs and
/// boundary updates through `RunReport::contention`.
#[test]
fn cut_graph_reports_ghost_activity() {
    let side = 8u32;
    let f = NeighborhoodFold { rounds: 10 };
    let mut g = grid(side);
    let n = g.num_vertices();
    let report = Program::new()
        .update_fn(&f)
        .model(ConsistencyModel::Full)
        .workers(4)
        .run_on(&ShardedEngine::new(4), &mut g, &seeded(n, 4), &Sdt::new());
    assert_eq!(report.contention.shards, 4);
    assert!(report.contention.ghost_syncs > 0);
    assert!(report.contention.boundary_updates > 0);
}

/// The BFS relabel aligns `PartitionMap` blocks with grid neighborhoods:
/// a scrambled-id grid has a near-random (large) edge cut, the same grid
/// relabeled breadth-first a much smaller one.
#[test]
fn bfs_order_shrinks_edge_cut() {
    let side = 16u32;
    let n = (side * side) as usize;
    // Deterministic scramble: stride permutation of the row-major ids.
    let stride = 37u32; // coprime with 256
    let perm: Vec<u32> = (0..n as u32).map(|i| (i * stride) % n as u32).collect();

    let build = |bfs: bool| -> DataGraph<u32, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n as u32 {
            b.add_vertex(i);
        }
        for y in 0..side {
            for x in 0..side {
                let v = y * side + x;
                if x + 1 < side {
                    b.add_undirected(perm[v as usize], perm[(v + 1) as usize], (), ());
                }
                if y + 1 < side {
                    b.add_undirected(perm[v as usize], perm[(v + side) as usize], (), ());
                }
            }
        }
        if bfs {
            b.bfs_order();
        }
        b.build()
    };

    let mut scrambled = build(false);
    let mut relabeled = build(true);
    let k = 8;
    let cut_scrambled = ShardedGraph::new(&mut scrambled, k).edge_cut();
    let cut_relabeled = ShardedGraph::new(&mut relabeled, k).edge_cut();
    assert!(
        cut_relabeled * 2 < cut_scrambled,
        "BFS relabel must at least halve the scrambled cut: {cut_relabeled} vs {cut_scrambled}"
    );
}

/// Steal-half smoke: a contended run with the steal-half policy enabled
/// still conserves every update.
#[test]
fn steal_half_policy_conserves_updates() {
    let side = 10u32;
    let rounds = 20u64;
    let f = NeighborhoodFold { rounds };
    let mut g = grid(side);
    let n = g.num_vertices();
    let report = Program::new()
        .update_fn(&f)
        .model(ConsistencyModel::Full)
        .workers(4)
        .steal_half(true)
        .run_on(&ThreadedEngine, &mut g, &seeded(n, 4), &Sdt::new());
    assert_eq!(report.updates, n as u64 * rounds);
    for v in 0..n as u32 {
        assert_eq!(g.vertex_data(v).0, rounds, "vertex {v}");
    }
}
