//! True multi-process stress tests: the sharded engine deployed as k real
//! OS processes (one shard each) via [`graphlab::engine::ProcessHarness`],
//! rendezvousing over Unix-domain sockets in a shared directory.
//!
//! What these tests pin down, per fleet:
//!
//! * **Conservation vs sequential** — the summed per-shard update counts
//!   equal the sequential schedule exactly (`n * rounds` for the counter,
//!   `n * sweeps` for the set-planned BP and chromatic Gibbs workloads),
//!   and the counter fleet's merged owned rows equal the sequential fixed
//!   point value-for-value.
//! * **Owner-served pulls** — `pulls_served == staleness_pulls` in every
//!   fleet: every staleness pull was answered, and since a requester
//!   process holds **no peer masters** (each process hosts exactly one
//!   shard), every served pull crossed an address-space boundary through
//!   the owner's pull-service thread. `pulls_served > 0` is additionally
//!   pinned where the workload guarantees replicas lag past the bound
//!   (every counter fleet; the bp fleets in aggregate — see the tests).
//! * **Cross-process delta accounting** — summed over the shard reports,
//!   every boundary update is accounted for as a shipped or coalesced
//!   delta, and real socket bytes moved.
//! * **Kill-9 recovery** — SIGKILL one shard mid-run, then restart a fresh
//!   fleet from the latest complete on-disk snapshot epoch and reach the
//!   sequential result exactly.
//!
//! Value equivalence is asserted only for the counter (vertex-state-only)
//! workload: edge data is not ghost-replicated across processes, so BP's
//! edge-resident messages make its cross-process runs conservation-only
//! (see `docs/ARCHITECTURE.md`, "Process topology").

use graphlab::apps::gibbs::GibbsVertex;
use graphlab::engine::{ProcessHarness, ProcessRun};
use std::path::PathBuf;
use std::time::Duration;

/// The `graphlab` binary carrying the `shard` child entrypoint; Cargo
/// builds it for integration tests and exposes the path here.
fn binary() -> &'static str {
    env!("CARGO_BIN_EXE_graphlab")
}

/// A fresh scratch directory per (test, tag): removed up front so a
/// previous crashed run's sockets, reports, or snapshots can't leak in.
fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("graphlab-proc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fleet(tag: &str, shards: usize) -> ProcessHarness {
    ProcessHarness::new(fresh_dir(tag), shards)
        .binary(binary())
        .join_timeout(Duration::from_secs(120))
}

/// The shared accounting audit: every shard finished by draining its
/// scheduler, every staleness pull was owner-served, and every boundary
/// update is accounted as a shipped or coalesced delta. Returns the
/// fleet's owner-served pull count so callers can assert pulls actually
/// crossed address spaces where the workload guarantees them (a pull
/// needs an observed replica lag *past* the bound, so short workloads
/// whose master versions never exceed `s` legitimately report zero —
/// e.g. 3-sweep BP against `s = 4`).
fn audit_conservation(run: &ProcessRun, tag: &str) -> u64 {
    assert!(run.all_finished(), "{tag}: every shard drains and reports: {:?}", run.reports);
    assert_eq!(
        run.pulls_served(),
        run.staleness_pulls(),
        "{tag}: every staleness pull is owner-served (no timeouts on a healthy wire)"
    );
    assert_eq!(
        run.deltas_sent() + run.deltas_coalesced(),
        run.boundary_updates(),
        "{tag}: every boundary update becomes a shipped or coalesced delta"
    );
    assert!(run.bytes_shipped() > 0, "{tag}: ghost traffic moved real socket bytes");
    run.pulls_served()
}

// ---- counter: exact sequential fixed point across processes ----------------

/// The counter fleet must reach the exact sequential fixed point: every
/// vertex at `rounds`, reassembled from the per-process owned rows — plus
/// exact update conservation and the full pull/delta audit, across k in
/// {2, 4} real processes and staleness bounds s in {0, 4}.
#[test]
fn counter_fleet_reaches_sequential_fixed_point() {
    let rounds = 200u64;
    let n = 32u64;
    for (k, s) in [(2usize, 0u64), (2, 4), (4, 0), (4, 4)] {
        let tag = format!("counter-k{k}-s{s}");
        let run = fleet(&tag, k)
            .workload("counter")
            .workers(2)
            .staleness(s)
            .batch(4)
            .sweeps(rounds as usize)
            .launch()
            .expect("fleet launches")
            .join()
            .expect("fleet joins");
        let pulls = audit_conservation(&run, &tag);
        // 200 rounds of sustained mutual boundary traffic: replicas
        // provably lag past any tested bound at some admission, and the
        // requester process holds no peer masters — every served pull
        // crossed an address space through the owner's pull service.
        assert!(pulls > 0, "{tag}: pulls must cross process boundaries: {:?}", run.reports);
        assert_eq!(run.updates(), n * rounds, "{tag}: exact update conservation");
        let rows = run.merged_rows::<u64>().expect("owned rows decode");
        assert_eq!(rows.len() as u64, n, "{tag}: owned ranges cover every vertex once");
        for (i, &(v, value)) in rows.iter().enumerate() {
            assert_eq!(v as usize, i, "{tag}: merged rows are the full id range");
            assert_eq!(value, rounds, "{tag} vertex {v}: sequential fixed point");
        }
    }
}

// ---- BP: cross-process conservation ----------------------------------------

/// Set-planned loopy BP across real processes conserves the plan exactly:
/// each of the `n * sweeps` plan tasks executes once, in its owner's
/// process (non-owned pops are dropped through the resident handoff, which
/// keeps the plan's DAG releasing without executing anything), and the
/// pull/delta accounting balances across the fleet.
#[test]
fn bp_fleet_conserves_plan_and_pull_accounting() {
    let sweeps = 3u64;
    let n = 80u64;
    let mut total_pulls = 0u64;
    for (k, s) in [(2usize, 0u64), (2, 4), (4, 0), (4, 4)] {
        let tag = format!("bp-k{k}-s{s}");
        let run = fleet(&tag, k)
            .workload("bp")
            .workers(2)
            .staleness(s)
            .batch(8)
            .sweeps(sweeps as usize)
            .launch()
            .expect("fleet launches")
            .join()
            .expect("fleet joins");
        total_pulls += audit_conservation(&run, &tag);
        assert_eq!(
            run.updates(),
            n * sweeps,
            "{tag}: every plan task runs exactly once across the fleet"
        );
    }
    // Masters only reach version 3 here (one bump per sweep), so the
    // s = 4 fleets can legitimately never exceed the bound — but the
    // s = 0 fleets, where any announced-but-undrained delta trips a
    // pull, must produce owner-served cross-process pulls.
    assert!(total_pulls > 0, "bp: no fleet pulled across a process boundary");
}

// ---- Gibbs: one sample per vertex per sweep, fleet-wide --------------------

/// Chromatic Gibbs across real processes conserves exactly one sample per
/// vertex per sweep: the visit counters live in the owners' master rows,
/// so the merged rows must show `sweeps` total visits at every vertex no
/// matter how the socket wire interleaved the ghost traffic.
#[test]
fn gibbs_fleet_conserves_one_sample_per_vertex_per_sweep() {
    let sweeps = 40usize;
    for (k, s) in [(2usize, 0u64), (4, 4)] {
        let tag = format!("gibbs-k{k}-s{s}");
        let run = fleet(&tag, k)
            .workload("gibbs")
            .workers(2)
            .staleness(s)
            .batch(2)
            .sweeps(sweeps)
            .launch()
            .expect("fleet launches")
            .join()
            .expect("fleet joins");
        // Chromatic plans flush + drain at every color barrier, so replica
        // lag rarely crosses even s = 0 for long — the pull accounting
        // equality in the audit is the load-bearing check here; the
        // guaranteed pulls-cross-processes property is pinned by the
        // counter and bp tests.
        audit_conservation(&run, &tag);
        assert_eq!(run.updates(), 8 * sweeps as u64, "{tag}: sweep conservation");
        let rows = run.merged_rows::<GibbsVertex>().expect("owned rows decode");
        assert_eq!(rows.len(), 8, "{tag}: owned ranges cover every vertex once");
        for (v, data) in rows {
            let total: u32 = data.counts.iter().sum();
            assert_eq!(total as usize, sweeps, "{tag} vertex {v}: one sample per sweep");
        }
    }
}

// ---- kill -9 one shard, restore the fleet from its snapshot ----------------

/// The tentpole recovery acceptance, now with a real SIGKILL: run a
/// snapshotting counter fleet, wait until a complete epoch (all k parts)
/// is on disk, `kill -9` shard 1, and let the survivors drain (their pulls
/// to the dead peer fail fast instead of hanging — this test completing at
/// all proves no hang). Then restart a **fresh** fleet on a new rendezvous
/// directory with `--restore`: every child rewinds to the same snapshot
/// cut and re-runs, and the merged result must be exactly the sequential
/// fixed point.
#[test]
fn kill_nine_one_shard_then_restored_fleet_reaches_sequential_result() {
    let rounds = 400u64;
    let n = 32u64;
    let snap_dir = fresh_dir("kill9-snapshots");

    let first = fleet("kill9-run1", 2)
        .workload("counter")
        .workers(2)
        .staleness(4)
        .batch(4)
        .sweeps(rounds as usize)
        .snapshot_every(100)
        .snapshot_dir(&snap_dir)
        .launch()
        .expect("first fleet launches");
    assert!(
        first.wait_for_snapshot(Duration::from_secs(60)),
        "a complete snapshot epoch (all shards' parts) lands on disk"
    );
    let mut first = first;
    first.kill(1).expect("SIGKILL shard 1");
    // The survivor must still drain and report; the killed shard may have
    // finished before the kill landed (then its report exists) or died
    // mid-run (then its slot is None) — both are legitimate here.
    let crashed = first.join().expect("crashed fleet joins");
    assert!(
        crashed.reports[0].is_some(),
        "the surviving shard reports despite its dead peer: {:?}",
        crashed.reports
    );

    // Recovery: a fresh rendezvous directory (the old one holds the dead
    // shard's stale endpoints), same snapshot directory, every child
    // restored from the newest complete epoch. The guarded counter makes
    // re-execution idempotent past the restored values.
    let recovered = fleet("kill9-run2", 2)
        .workload("counter")
        .workers(2)
        .staleness(4)
        .batch(4)
        .sweeps(rounds as usize)
        .snapshot_dir(&snap_dir)
        .restore(true)
        .launch()
        .expect("recovery fleet launches")
        .join()
        .expect("recovery fleet joins");
    assert!(recovered.all_finished(), "recovered fleet drains: {:?}", recovered.reports);
    let rows = recovered.merged_rows::<u64>().expect("owned rows decode");
    assert_eq!(rows.len() as u64, n);
    for (v, value) in rows {
        assert_eq!(value, rounds, "vertex {v}: restart-from-snapshot reaches sequential");
    }
    let _ = std::fs::remove_dir_all(&snap_dir);
}
