//! Cross-module integration tests: full GraphLab programs exercising the
//! data graph + consistency + schedulers + engine + sync together, plus
//! end-to-end correctness of the case-study pipelines at test scale.

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use graphlab::apps::gibbs::{chromatic_sets, GibbsUpdate};
use graphlab::apps::learn::{learning_sync, target_stats, TARGET_KEY};
use graphlab::apps::mrf::GridDims;
use graphlab::consistency::ConsistencyModel;
use graphlab::datagen::{ner, protein, retina};
use graphlab::engine::{Program, ThreadedEngine, UpdateFn};
use graphlab::scheduler::{
    FifoScheduler, MultiQueueFifo, Scheduler, SetScheduler, SplashScheduler,
    SynchronousScheduler, Task,
};
use graphlab::sdt::Sdt;
use graphlab::util::Pcg32;
use std::sync::Arc;
use std::time::Duration;

/// The full §4.1 pipeline at test scale: proxy stats -> simultaneous
/// learning+inference -> denoised output better than the noisy input.
#[test]
fn denoising_pipeline_end_to_end() {
    let dims = GridDims::new(24, 24, 12);
    let mut rng = Pcg32::seed_from_u64(42);
    let vol = retina::generate(dims, 5, 0.25, &mut rng);
    let mut mrf = retina::build_mrf(&vol, 0.8);
    let n = mrf.graph.num_vertices();

    let proxy = retina::smoothed_proxy(&vol, 1);
    let targets = target_stats(dims, &proxy);

    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    sdt.set(TARGET_KEY, targets);
    let sched = SplashScheduler::new(n, |v| mrf.graph.neighbors(v), 32, 2);
    for v in 0..n as u32 {
        sched.add_task(Task::with_priority(v, 1.0));
    }
    let mut upd = BpUpdate::new(5, 5e-4, Arc::new(Vec::new()));
    upd.learn_stats = true;
    upd.damping = 0.1;
    let sync = learning_sync(0.8, Some(Duration::from_millis(2)));
    let report = Program::new()
        .update_fn(&upd)
        .sync(sync)
        .workers(2)
        .model(ConsistencyModel::Edge)
        .max_updates(2_500_000)
        .run_on(&ThreadedEngine, &mut mrf.graph, &sched, &sdt);
    assert!(report.updates > n as u64, "must iterate");
    assert!(report.syncs_run >= 1, "background sync must run");
    let lambda = sdt.get::<[f64; 3]>(LAMBDA_KEY).unwrap();
    assert!(lambda.iter().all(|&l| l > 0.01 && l < 20.0));

    let argmax = |b: &[f32]| -> u32 {
        b.iter().enumerate().max_by(|a, c| a.1.partial_cmp(c.1).unwrap()).unwrap().0 as u32
    };
    let denoised: Vec<u32> =
        (0..n as u32).map(|v| argmax(&mrf.graph.vertex_data(v).belief)).collect();
    let before = retina::error_rate(&vol.clean, &vol.noisy);
    let after = retina::error_rate(&vol.clean, &denoised);
    assert!(
        after < before,
        "denoising must improve the error rate: {after:.3} vs {before:.3}"
    );
}

/// The full §4.2 pipeline: color in parallel, then chromatic Gibbs through
/// the planned set scheduler — marginals must stay valid distributions and
/// the sampler must execute exactly sweeps x vertices samples.
#[test]
fn chromatic_gibbs_pipeline() {
    let mut rng = Pcg32::seed_from_u64(4);
    let net = protein::generate(500, 2500, 3, &mut rng);
    let mut g = net.graph;
    let n = g.num_vertices();
    {
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
    }
    let ncolors = validate_coloring(&mut g).expect("valid coloring");
    assert!(ncolors >= 3);
    let classes = color_classes(&mut g);
    let sweeps = 20;
    let sets = chromatic_sets(&classes, sweeps, 0);
    let sched = SetScheduler::planned(&sets, n, |v| g.neighbors(v), ConsistencyModel::Edge);
    let upd = GibbsUpdate::new(3, Arc::new(net.tables.clone()), 4, 9);
    let sdt = Sdt::new();
    let report = Program::new()
        .update_fn(&upd)
        .workers(4)
        .model(ConsistencyModel::Vertex)
        .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
    assert_eq!(report.updates as usize, n * sweeps);
    for v in 0..n as u32 {
        let counts: u32 = g.vertex_data(v).counts.iter().sum();
        assert_eq!(counts as usize, sweeps, "every vertex sampled once per sweep");
    }
}

/// Synchronous (Jacobi) scheduler end-to-end: every sweep updates every
/// vertex exactly once, with a barrier between sweeps.
#[test]
fn synchronous_scheduler_runs_jacobi_sweeps() {
    use graphlab::consistency::Scope;
    use graphlab::engine::UpdateContext;

    struct CountSweep;
    impl UpdateFn<u64, ()> for CountSweep {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < 5 {
                ctx.add_task(scope.center(), 0.0);
            }
        }
    }
    let n = 256;
    let mut b = graphlab::graph::GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n - 1 {
        b.add_undirected(i as u32, i as u32 + 1, (), ());
    }
    let mut g = b.build();
    let sched = SynchronousScheduler::new(n, 50);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let f = CountSweep;
    let report = Program::new()
        .update_fn(&f)
        .workers(3)
        .model(ConsistencyModel::Vertex)
        .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
    assert_eq!(report.updates, n as u64 * 5, "5 Jacobi sweeps of n vertices");
    for v in 0..n as u32 {
        assert_eq!(*g.vertex_data(v), 5);
    }
}

/// CoEM at integration scale with the multiqueue scheduler across worker
/// counts: same fixed point regardless of parallelism (vertex consistency
/// is safe for this contraction).
#[test]
fn coem_fixed_point_stable_across_worker_counts() {
    let mut cfg = ner::NerConfig::small(0.02);
    cfg.seed_fraction = 0.3;
    let beliefs_for = |workers: usize| -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed_from_u64(8);
        let mut g = ner::generate(&cfg, &mut rng);
        let n = g.num_vertices();
        let sched = MultiQueueFifo::new(n, workers);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = graphlab::apps::coem::CoemUpdate::new(cfg.classes);
        Program::new()
            .update_fn(&upd)
            .workers(workers)
            .model(ConsistencyModel::Vertex)
            .max_updates(3_000_000)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        (0..n as u32).map(|v| g.vertex_data(v).belief.clone()).collect()
    };
    let b1 = beliefs_for(1);
    let b4 = beliefs_for(4);
    let mut max_diff = 0.0f32;
    for (x, y) in b1.iter().zip(&b4) {
        for (a, b) in x.iter().zip(y) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    assert!(max_diff < 0.02, "worker count must not change the fixed point: {max_diff}");
}
