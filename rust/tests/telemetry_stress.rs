//! Stress tests for the telemetry subsystem: event conservation against
//! the engines' own contention counters (threaded and sharded), ring
//! overflow accounting (drops are counted, never silent), the
//! disabled-mode contract (no report, no clock reads), and the acceptance
//! path — a sharded socket-backend BP run whose exported Chrome trace is
//! structurally valid (per-worker tracks, non-decreasing timestamps per
//! track, every key event category present) and whose JSONL metrics carry
//! the app-supplied convergence scalar.

use graphlab::apps::bp::{BpUpdate, LAMBDA_KEY};
use graphlab::apps::mrf::random_mrf;
use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{Program, UpdateContext, UpdateFn};
use graphlab::graph::{DataGraph, GraphBuilder};
use graphlab::scheduler::{FifoScheduler, MultiQueueFifo, Task};
use graphlab::sdt::Sdt;
use graphlab::telemetry::{EventKind, TelemetryConfig, ALL_KINDS, SPAN_OFF};
use graphlab::util::Pcg32;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

struct SelfBump {
    rounds: u64,
}
impl UpdateFn<u64, ()> for SelfBump {
    fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
        *scope.vertex_mut() += 1;
        if *scope.vertex() < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

fn ring_graph(n: usize) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n {
        b.add_undirected(i as u32, ((i + 1) % n) as u32, (), ());
    }
    b.build()
}

fn grid(side: u32) -> DataGraph<u64, ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..side * side {
        b.add_vertex(0u64);
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    b.build()
}

fn seeded_fifo(n: usize) -> FifoScheduler {
    let sched = FifoScheduler::new(n);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

fn seeded_mq(n: usize, workers: usize) -> MultiQueueFifo {
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

// ---- conservation against the engines' own counters ----------------------

/// Threaded back-end: every update is exactly one `task` span, every
/// counted deferral/escalation is exactly one matching instant — the
/// telemetry stream and the contention counters are two views of the same
/// events and may never disagree.
#[test]
fn threaded_telemetry_conserves_engine_counters() {
    let n = 64;
    let f = SelfBump { rounds: 50 };
    let mut g = ring_graph(n);
    let report = Program::new()
        .update_fn(&f)
        .workers(4)
        .model(ConsistencyModel::Full)
        .telemetry(TelemetryConfig::default())
        .run(&mut g, &seeded_fifo(n), &Sdt::new());
    assert_eq!(report.updates, n as u64 * 50, "conservation");
    let c = &report.contention;
    let tel = report.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(tel.count(EventKind::TaskExec), report.updates);
    assert_eq!(tel.count(EventKind::ScopeDefer), c.deferrals);
    assert_eq!(tel.count(EventKind::ScopeEscalate), c.escalations);
    assert_eq!(tel.tracks.len(), 5, "4 worker tracks + the engine track");
    assert!(tel.samples.len() >= 2, "an immediate and a final sample");
    assert_eq!(tel.events_dropped, 0, "default capacity holds this run");
}

/// Sharded channel back-end under a lazy flush window: every counted
/// staleness pull / pull retry is exactly one instant, flush spans carry
/// the shipped deltas, and wire send/apply events exist on both ends.
#[test]
fn sharded_telemetry_conserves_pull_and_flush_counters() {
    let side = 12u32;
    let rounds = 200u64;
    let f = SelfBump { rounds };
    let mut g = grid(side);
    let n = g.num_vertices();
    let report = Program::new()
        .update_fn(&f)
        .workers(4)
        .shards(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(2)
        .ghost_batch(1_000_000)
        .transport("channel")
        // Big enough that nothing drops: the flush-span sum below reads
        // retained events, not just counters.
        .telemetry(TelemetryConfig::default().with_ring_capacity(1 << 17))
        .run(&mut g, &seeded_mq(n, 4), &Sdt::new());
    assert_eq!(report.updates, n as u64 * rounds, "conservation");
    let c = &report.contention;
    let tel = report.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(tel.count(EventKind::TaskExec), report.updates);
    assert!(c.staleness_pulls > 0, "lazy flushes force admission pulls");
    assert_eq!(tel.count(EventKind::StalePull), c.staleness_pulls);
    assert_eq!(tel.count(EventKind::PullRetry), c.pull_retries);
    assert!(tel.count(EventKind::DeltaFlush) > 0, "flush windows are spanned");
    assert!(tel.count(EventKind::WireSend) > 0);
    assert!(tel.count(EventKind::WireApply) > 0);
    // Flush spans account every shipped delta: `a` carries the count.
    assert_eq!(tel.events_dropped, 0, "ring sized to retain the whole run");
    let flushed: u64 = tel.events_of(EventKind::DeltaFlush).iter().map(|e| e.a).sum();
    assert_eq!(flushed, c.deltas_sent, "flush spans account every delta");
}

// ---- ring overflow --------------------------------------------------------

/// A deliberately tiny ring must drop most events — but count every drop,
/// keep the per-kind counts exact (conservation still holds against the
/// update count), and retain exactly `capacity` events.
#[test]
fn ring_overflow_drops_are_counted_not_lost() {
    let n = 32;
    let f = SelfBump { rounds: 20 };
    let mut g = ring_graph(n);
    let report = Program::new()
        .update_fn(&f)
        .workers(1)
        .telemetry(TelemetryConfig::default().with_ring_capacity(8))
        .run(&mut g, &seeded_fifo(n), &Sdt::new());
    assert_eq!(report.updates, n as u64 * 20);
    let tel = report.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(
        tel.count(EventKind::TaskExec),
        report.updates,
        "per-kind counts include dropped events"
    );
    assert!(tel.events_dropped > 0, "an 8-slot ring cannot hold 640 spans");
    assert_eq!(tel.events_recorded, 8, "exactly the ring capacity retained");
    let total: u64 = ALL_KINDS.iter().map(|&k| tel.count(k)).sum();
    assert_eq!(total, tel.total_events(), "recorded + dropped == emitted");
}

// ---- disabled mode --------------------------------------------------------

/// Without a [`TelemetryConfig`] the run carries no telemetry section and
/// an unbound thread's span open is the no-clock-read sentinel — the
/// disabled path must stay one thread-local read and a branch.
#[test]
fn disabled_runs_record_nothing() {
    let n = 16;
    let f = SelfBump { rounds: 5 };
    let mut g = ring_graph(n);
    let report =
        Program::new().update_fn(&f).workers(2).run(&mut g, &seeded_fifo(n), &Sdt::new());
    assert_eq!(report.updates, n as u64 * 5);
    assert!(report.telemetry.is_none(), "no config, no telemetry section");
    assert_eq!(
        graphlab::telemetry::span_start(),
        SPAN_OFF,
        "unbound thread opens no span and reads no clock"
    );
}

// ---- acceptance: Perfetto-loadable trace + JSONL metrics ------------------

/// Leading number right after `"key":` in a single-line JSON object.
fn num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}', ']']).unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// String value right after `"key":"` in a single-line JSON object.
fn str_field<'l>(line: &'l str, key: &str) -> Option<&'l str> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    Some(&rest[..rest.find('"')?])
}

/// Acceptance: a sharded socket-backend BP run with telemetry enabled
/// must export a structurally valid Chrome trace — one named track per
/// worker plus the engine track, at least one event in every key
/// instrumented category, non-decreasing timestamps within each track —
/// and a JSONL metrics series carrying the app's convergence scalar.
#[test]
fn socket_bp_trace_export_is_perfetto_loadable() {
    let mut mrf = {
        let mut rng = Pcg32::seed_from_u64(7);
        random_mrf(80, 160, 3, &mut rng)
    };
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    sdt.set("resid", 0.75f64);
    let upd = BpUpdate::new(mrf.arity, 1e-6, Arc::new(mrf.tables.clone()));
    let trace_path = PathBuf::from("target/telemetry/stress-trace.json");
    let metrics_path = PathBuf::from("target/telemetry/stress-metrics.jsonl");
    let report = Program::new()
        .update_fn(&upd)
        .workers(4)
        .shards(2)
        .model(ConsistencyModel::Full)
        .ghost_staleness(4)
        .ghost_batch(8)
        .max_updates(500_000)
        .transport("socket")
        .telemetry(
            TelemetryConfig::default()
                // Bounded trace size; overflow is fine here (counts stay
                // exact and every category shows up early in the run).
                .with_ring_capacity(1 << 13)
                .with_sample_interval(Duration::from_millis(2))
                .with_trace_path(trace_path.clone())
                .with_metrics_path(metrics_path.clone()),
        )
        .progress_metric(|sdt: &Sdt| sdt.get_or::<f64>("resid", f64::NAN))
        .run(&mut mrf.graph, &seeded_mq(n, 4), &sdt);
    assert!(report.updates > 0);
    let tel = report.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(tel.count(EventKind::TaskExec), report.updates);
    assert_eq!(tel.count(EventKind::StalePull), report.contention.staleness_pulls);
    assert_eq!(tel.tracks.len(), 5, "4 worker tracks + the engine track");
    assert_eq!(tel.trace_path.as_deref(), Some(trace_path.as_path()));
    assert_eq!(tel.metrics_path.as_deref(), Some(metrics_path.as_path()));

    // -- Chrome trace structure --------------------------------------------
    let text = std::fs::read_to_string(&trace_path).expect("trace written");
    assert!(text.starts_with("{\"traceEvents\":[\n"), "trace_event envelope");
    assert!(text.trim_end().ends_with("]}"), "envelope closed");
    let mut track_names = Vec::new();
    let mut category_counts: HashMap<&str, u64> = HashMap::new();
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    let mut flow_starts = 0u64;
    let mut flow_ends = 0u64;
    for raw in text.lines() {
        let line = raw.trim_end_matches(',');
        // Skip the envelope lines; every event line opens with its phase.
        if !line.starts_with("{\"ph\"") {
            continue;
        }
        let ph = str_field(line, "ph").expect("every event has a phase");
        assert_eq!(num_field(line, "pid"), Some(0.0), "single process");
        match ph {
            "M" => {
                if str_field(line, "name") == Some("thread_name") {
                    // the args object holds the track label
                    let label = &line[line.find("\"args\"").unwrap()..];
                    track_names.push(str_field(label, "name").unwrap().to_string());
                }
            }
            "X" | "i" => {
                let tid = num_field(line, "tid").expect("track id") as u64;
                let ts = num_field(line, "ts").expect("timestamp");
                let prev = last_ts.entry(tid).or_insert(f64::MIN);
                assert!(
                    ts >= *prev,
                    "track {tid}: ts {ts} decreased below {prev}"
                );
                *prev = ts;
                *category_counts.entry(str_field(line, "name").unwrap()).or_insert(0) +=
                    1;
                if ph == "X" {
                    assert!(num_field(line, "dur").unwrap() > 0.0, "spans have width");
                }
            }
            "s" => flow_starts += 1,
            "f" => flow_ends += 1,
            other => panic!("unexpected phase {other:?} in {line}"),
        }
    }
    for expect in ["shard0-worker0", "shard0-worker1", "shard1-worker0", "shard1-worker1", "engine"]
    {
        assert!(
            track_names.iter().any(|t| t == expect),
            "track {expect} missing from {track_names:?}"
        );
    }
    for expect in ["task", "delta_flush", "wire_send", "wire_apply", "stale_pull"] {
        assert!(
            category_counts.get(expect).copied().unwrap_or(0) > 0,
            "no {expect} events in trace: {category_counts:?}"
        );
    }
    assert_eq!(flow_starts, flow_ends, "every delta arrow has both endpoints");

    // -- JSONL metrics ------------------------------------------------------
    let metrics = std::fs::read_to_string(&metrics_path).expect("metrics written");
    let lines: Vec<&str> = metrics.lines().collect();
    assert_eq!(lines.len(), tel.samples.len(), "one line per sample");
    assert!(lines.len() >= 2, "an immediate and a final sample");
    let mut prev_t = f64::MIN;
    let mut prev_tasks = 0.0;
    for line in &lines {
        let t = num_field(line, "t_ms").expect("sample timestamp");
        assert!(t >= prev_t, "samples in time order");
        prev_t = t;
        let tasks = num_field(line, "tasks").expect("cumulative task count");
        assert!(tasks >= prev_tasks, "task counter is cumulative");
        prev_tasks = tasks;
        assert!(line.contains("\"progress\":0.75"), "convergence scalar probed");
        assert!(line.contains("\"lag_hist\":["), "staleness distribution present");
    }
    let last = lines.last().unwrap();
    assert_eq!(
        num_field(last, "tasks"),
        Some(report.updates as f64),
        "final sample saw every task span"
    );
    assert!(
        num_field(last, "ghost_bytes").unwrap() > 0.0,
        "socket run shipped ghost bytes"
    );
}
