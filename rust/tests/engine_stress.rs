//! Multi-threaded stress tests for the non-blocking (try-lock + deferral)
//! execution core: the same program run under every consistency model must
//! lose no updates relative to the sequential engine, conserve the
//! `RunReport.updates` count, keep contention counters at zero for a single
//! worker, and — under a deliberately contended Full-consistency workload —
//! show nonzero deferrals while still matching the sequential result.

use graphlab::consistency::{ConsistencyModel, Scope};
use graphlab::engine::{Program, SequentialEngine, ThreadedEngine, UpdateContext, UpdateFn};
use graphlab::graph::{DataGraph, GraphBuilder};
use graphlab::scheduler::{FifoScheduler, MultiQueueFifo, Scheduler, Task};
use graphlab::sdt::Sdt;

/// A BP/Gibbs-shaped program that is valid under every consistency model:
/// read the neighborhood, fold it into the center vertex, reschedule self
/// for a fixed number of rounds. The center-write round counter makes "no
/// lost updates" checkable exactly: every vertex must end at `rounds`.
struct NeighborhoodFold {
    rounds: u64,
}

impl UpdateFn<(u64, u64), ()> for NeighborhoodFold {
    fn update(&self, scope: &mut Scope<'_, (u64, u64), ()>, ctx: &mut UpdateContext<'_>) {
        // simulate a belief recomputation: fold neighbor round counters
        let mut acc = 0u64;
        for &u in scope.neighbors() {
            acc = acc.wrapping_add(scope.neighbor(u).0).rotate_left(1);
        }
        let data = scope.vertex_mut();
        data.0 += 1;
        data.1 = data.1.wrapping_add(acc);
        if data.0 < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }
}

fn grid(side: u32) -> DataGraph<(u64, u64), ()> {
    let mut b = GraphBuilder::new();
    for _ in 0..side * side {
        b.add_vertex((0u64, 0u64));
    }
    for y in 0..side {
        for x in 0..side {
            let v = y * side + x;
            if x + 1 < side {
                b.add_undirected(v, v + 1, (), ());
            }
            if y + 1 < side {
                b.add_undirected(v, v + side, (), ());
            }
        }
    }
    b.build()
}

fn seeded(n: usize, workers: usize) -> MultiQueueFifo {
    let sched = MultiQueueFifo::new(n, workers);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    sched
}

/// (a)+(b): for each consistency model, the threaded run must complete every
/// scheduled round on every vertex (no lost center updates) and report the
/// same `updates` total as the sequential engine.
#[test]
fn all_models_match_sequential_update_counts() {
    let side = 16u32;
    let rounds = 25u64;
    for model in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
        let f = NeighborhoodFold { rounds };
        let program = Program::new().update_fn(&f).model(model);

        let mut seq_g = grid(side);
        let n = seq_g.num_vertices();
        let seq_report =
            program.run_on(&SequentialEngine, &mut seq_g, &seeded(n, 1), &Sdt::new());
        assert_eq!(seq_report.updates, n as u64 * rounds, "sequential baseline ({model:?})");

        let mut thr_g = grid(side);
        let thr_report = program
            .workers(4)
            .run_on(&ThreadedEngine, &mut thr_g, &seeded(n, 4), &Sdt::new());
        assert_eq!(
            thr_report.updates, seq_report.updates,
            "update conservation vs sequential ({model:?})"
        );
        assert_eq!(
            thr_report.per_worker.iter().sum::<u64>(),
            thr_report.updates,
            "per-worker accounting ({model:?})"
        );
        for v in 0..n as u32 {
            assert_eq!(
                thr_g.vertex_data(v).0,
                rounds,
                "vertex {v} lost updates under {model:?}"
            );
        }
    }
}

/// (c): with one worker and no background syncs, nothing can conflict —
/// every contention counter must be exactly zero, for every model.
#[test]
fn single_worker_contention_counters_are_zero() {
    let side = 12u32;
    for model in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
        let f = NeighborhoodFold { rounds: 10 };
        let mut g = grid(side);
        let n = g.num_vertices();
        let report = Program::new()
            .update_fn(&f)
            .model(model)
            .workers(1)
            .run_on(&ThreadedEngine, &mut g, &seeded(n, 1), &Sdt::new());
        assert_eq!(report.updates, n as u64 * 10);
        let c = &report.contention;
        assert_eq!(
            (c.conflicts, c.deferrals, c.retries, c.steals, c.escalations),
            (0, 0, 0, 0, 0),
            "1-worker run must be conflict-free under {model:?}: {c:?}"
        );
        assert_eq!(
            c.affinity_hits, report.updates,
            "at 1 worker every scheduler pop is an owner-affinity hit"
        );
    }
}

/// A hub-and-spokes graph under Full consistency: every update write-locks
/// the hub, so 4 workers must contend. The engine never parks a worker on a
/// scope lock — the conflicts must surface as nonzero deferrals in the
/// report — and the hub total must still match the sequential engine's.
#[test]
fn contended_full_consistency_defers_and_matches_sequential() {
    let leaves = 16u32;
    let rounds = 400u64;

    fn star(leaves: u32) -> DataGraph<(u64, u64), ()> {
        let mut b = GraphBuilder::new();
        let hub = b.add_vertex((0u64, 0u64));
        for _ in 0..leaves {
            let leaf = b.add_vertex((0u64, 0u64));
            b.add_undirected(hub, leaf, (), ());
        }
        b.build()
    }

    /// Leaf update under Full consistency: burn a little compute (so lock
    /// holds are long enough to observably contend), then push a bump into
    /// the hub through the write-locked scope.
    struct BumpHub {
        rounds: u64,
    }
    impl UpdateFn<(u64, u64), ()> for BumpHub {
        fn update(&self, scope: &mut Scope<'_, (u64, u64), ()>, ctx: &mut UpdateContext<'_>) {
            let mut spin = scope.center() as u64;
            for i in 0..256u64 {
                spin = spin.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(spin);
            for &u in scope.neighbors() {
                scope.neighbor_mut(u).0 += 1;
            }
            let data = scope.vertex_mut();
            data.1 += 1;
            if data.1 < self.rounds {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    let seed_leaves = |sched: &dyn Scheduler, leaves: u32| {
        for v in 1..=leaves {
            sched.add_task(Task::new(v));
        }
    };

    let f = BumpHub { rounds };
    let program = Program::new().update_fn(&f).model(ConsistencyModel::Full);

    let mut seq_g = star(leaves);
    let seq_sched = FifoScheduler::new(seq_g.num_vertices());
    seed_leaves(&seq_sched, leaves);
    let seq_report = program.run_on(&SequentialEngine, &mut seq_g, &seq_sched, &Sdt::new());
    let seq_hub = seq_g.vertex_data(0).0;
    assert_eq!(seq_report.updates, leaves as u64 * rounds);
    assert_eq!(seq_hub, leaves as u64 * rounds);

    let mut thr_g = star(leaves);
    let thr_sched = MultiQueueFifo::new(thr_g.num_vertices(), 4);
    seed_leaves(&thr_sched, leaves);
    let report = program.workers(4).run_on(&ThreadedEngine, &mut thr_g, &thr_sched, &Sdt::new());

    assert_eq!(report.updates, seq_report.updates, "total updates match sequential");
    assert_eq!(thr_g.vertex_data(0).0, seq_hub, "no lost hub increments");
    for v in 1..=leaves {
        assert_eq!(thr_g.vertex_data(v).1, rounds, "leaf {v} round count");
    }
    assert!(
        report.contention.deferrals > 0,
        "a saturated Full-consistency hub must defer, not park: {:?}",
        report.contention
    );
    assert!(report.contention.conflicts >= report.contention.deferrals);
    assert!(report.contention.retries >= report.contention.deferrals);
    assert_eq!(
        report.contention.per_worker_deferrals.iter().sum::<u64>(),
        report.contention.deferrals
    );
    assert_eq!(
        report.contention.per_worker_conflicts.iter().sum::<u64>(),
        report.contention.conflicts
    );
}
