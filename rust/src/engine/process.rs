//! True **multi-process** sharded deployment: one OS process per shard.
//!
//! [`ProcessHarness`] forks/execs N children of the `graphlab` binary
//! (the `graphlab shard` entrypoint, [`shard_child_main`]), hands every
//! child the same **rendezvous directory**, and joins them collecting one
//! [`ShardReport`] per shard. Inside each child:
//!
//! 1. The partition's data graph is rebuilt **identically** from the
//!    workload's deterministic generator (same seed in every process — the
//!    multi-process analogue of every node loading the same graph).
//! 2. The scheduler is seeded with the shard's **owned vertices only**
//!    (dynamic workloads) or the full deterministic plan (set-scheduled
//!    workloads, where the resident engine drops non-owned tasks through
//!    the handoff path, keeping DAG dependencies releasing).
//! 3. [`super::sharded::run_resident_shard`] binds the shard's
//!    [`crate::transport::SocketTransport`] endpoints under the rendezvous
//!    directory, connects to every peer, and runs the shared engine core
//!    with [`super::EngineConfig::resident_shard`] set — ghost deltas,
//!    version announcements, and owner-served staleness pulls all cross
//!    real kernel sockets between address spaces.
//! 4. The child serializes its [`super::RunReport`] counters plus its
//!    owned master rows into `report-<shard>.bin` (tmp + rename, so the
//!    parent never reads a torn file).
//!
//! The parent aggregates the per-shard reports: cross-process conservation
//! (`sum(updates)`, delta/byte accounting, `pulls_served ==
//! staleness_pulls`) and the merged owned rows are checked against a
//! sequential run in `rust/tests/process_stress.rs`.
//!
//! **What does and does not cross the wire.** Vertex data is ghost-
//! replicated and, under the Full model, written back into the
//! process-local rows at scope admission ([`crate::graph::GhostEntry`]
//! row sync) — so neighbor *vertex* reads see pulled data. Edge data is
//! **not** replicated: each process keeps its partition-time copy of
//! cut-edge data, so workloads whose state lives on edges (BP messages)
//! are exercised for *conservation* (exact update/delta/pull accounting),
//! not for cross-process value equivalence. Vertex-state workloads (the
//! counter) reach the exact sequential fixed point.

use super::snapshot::latest_complete_parts;
use super::{EngineConfig, Program, RunReport, StopReason, UpdateContext, UpdateFn};
use crate::apps::bp::{BpUpdate, LAMBDA_KEY};
use crate::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
use crate::apps::gibbs::{chromatic_sets, GibbsEdge, GibbsUpdate, GibbsVertex};
use crate::apps::mrf::{random_mrf, EdgePotential};
use crate::consistency::{ConsistencyModel, Scope};
use crate::graph::{DataGraph, GraphBuilder, PartitionMap, VertexId};
use crate::scheduler::{FifoScheduler, MultiQueueFifo, Scheduler, SetScheduler, Task};
use crate::sdt::Sdt;
use crate::transport::{put_u32, put_u64, ByteReader, GhostDelta, VertexCodec};
use crate::util::Pcg32;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Magic header of a `report-<shard>.bin` file (`"GLSR"`).
const REPORT_MAGIC: u32 = 0x474C_5352;

/// File a shard child leaves in the rendezvous directory for the parent.
fn report_name(shard: usize) -> String {
    format!("report-{shard}.bin")
}

// ---------------------------------------------------------------------------
// Preset workloads
// ---------------------------------------------------------------------------

/// The preset multi-process workloads a `graphlab shard` child can run.
///
/// Each builds its data graph from a fixed seed so every process holds an
/// identical copy, making the k-way cut (and therefore the ghost/boundary
/// sets) identical across the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// Self-rescheduling per-vertex counter on a chain: every vertex must
    /// reach exactly `sweeps` — the exact-fixed-point workload (vertex
    /// state only, so restored/recovered runs are value-checkable).
    Counter,
    /// Loopy BP on a seeded random MRF, driven by a full-sweep set plan:
    /// exercised for cross-process conservation accounting.
    Bp,
    /// Chromatic Gibbs on an 8-vertex chain: one sample per vertex per
    /// sweep, conserved no matter how the wire interleaves.
    Gibbs,
}

impl Workload {
    fn parse(s: &str) -> Option<Workload> {
        match s {
            "counter" => Some(Workload::Counter),
            "bp" => Some(Workload::Bp),
            "gibbs" => Some(Workload::Gibbs),
            _ => None,
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            Workload::Counter => "counter",
            Workload::Bp => "bp",
            Workload::Gibbs => "gibbs",
        }
    }

    /// Default sweep/round count when the caller does not override it.
    fn default_sweeps(self) -> usize {
        match self {
            Workload::Counter => 200,
            Workload::Bp => 3,
            Workload::Gibbs => 40,
        }
    }

    /// Vertices in the workload's (fixed, deterministic) data graph.
    pub fn num_vertices(self) -> usize {
        match self {
            Workload::Counter => 32,
            Workload::Bp => 80,
            Workload::Gibbs => 8,
        }
    }
}

// ---------------------------------------------------------------------------
// Child-side argument surface
// ---------------------------------------------------------------------------

/// Parsed `graphlab shard` command line (see [`shard_child_main`]).
#[derive(Debug)]
struct ShardArgs {
    dir: PathBuf,
    shard: usize,
    shards: usize,
    workload: Workload,
    workers: usize,
    staleness: u64,
    batch: usize,
    sweeps: usize,
    snapshot_every: u64,
    snapshot_dir: Option<PathBuf>,
    restore: bool,
}

impl ShardArgs {
    fn parse(args: &[String]) -> Result<ShardArgs, String> {
        let mut dir = None;
        let mut shard = None;
        let mut shards = None;
        let mut workload = None;
        let mut workers = 2usize;
        let mut staleness = 0u64;
        let mut batch = 1usize;
        let mut sweeps = 0usize;
        let mut snapshot_every = 0u64;
        let mut snapshot_dir = None;
        let mut restore = false;

        let mut it = args.iter();
        while let Some(flag) = it.next() {
            let mut val = |name: &str| {
                it.next().map(|s| s.to_owned()).ok_or_else(|| format!("{name} needs a value"))
            };
            match flag.as_str() {
                "--dir" => dir = Some(PathBuf::from(val("--dir")?)),
                "--shard" => {
                    shard = Some(val("--shard")?.parse().map_err(|e| format!("--shard: {e}"))?)
                }
                "--shards" => {
                    shards = Some(val("--shards")?.parse().map_err(|e| format!("--shards: {e}"))?)
                }
                "--workload" => {
                    let w = val("--workload")?;
                    workload =
                        Some(Workload::parse(&w).ok_or_else(|| format!("unknown workload `{w}`"))?)
                }
                "--workers" => {
                    workers = val("--workers")?.parse().map_err(|e| format!("--workers: {e}"))?
                }
                "--staleness" => {
                    staleness =
                        val("--staleness")?.parse().map_err(|e| format!("--staleness: {e}"))?
                }
                "--batch" => {
                    batch = val("--batch")?.parse().map_err(|e| format!("--batch: {e}"))?
                }
                "--sweeps" => {
                    sweeps = val("--sweeps")?.parse().map_err(|e| format!("--sweeps: {e}"))?
                }
                "--snapshot-every" => {
                    snapshot_every = val("--snapshot-every")?
                        .parse()
                        .map_err(|e| format!("--snapshot-every: {e}"))?
                }
                "--snapshot-dir" => {
                    snapshot_dir = Some(PathBuf::from(val("--snapshot-dir")?))
                }
                "--restore" => restore = true,
                other => return Err(format!("unknown flag `{other}`")),
            }
        }
        let dir = dir.ok_or("--dir is required")?;
        let shard = shard.ok_or("--shard is required")?;
        let shards: usize = shards.ok_or("--shards is required")?;
        let workload = workload.ok_or("--workload is required")?;
        if shards < 2 {
            return Err("--shards must be at least 2".into());
        }
        if shard >= shards {
            return Err(format!("--shard {shard} out of range for --shards {shards}"));
        }
        if sweeps == 0 {
            sweeps = workload.default_sweeps();
        }
        Ok(ShardArgs {
            dir,
            shard,
            shards,
            workload,
            workers,
            staleness,
            batch,
            sweeps,
            snapshot_every,
            snapshot_dir,
            restore,
        })
    }
}

/// The `graphlab shard` child entrypoint: run one resident shard of a
/// preset [`Workload`] against the rendezvous directory, write the
/// [`ShardReport`], and return the process exit code. Spawned by
/// [`ProcessHarness::launch`]; never meant to be invoked by hand (but
/// harmless if it is — it only touches the directories it is given).
pub fn shard_child_main(args: &[String]) -> i32 {
    let args = match ShardArgs::parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("graphlab shard: {e}");
            eprintln!(
                "USAGE: graphlab shard --dir <rendezvous> --shard <r> --shards <k> \
                 --workload <counter|bp|gibbs> [--workers n] [--staleness s] [--batch b] \
                 [--sweeps n] [--snapshot-every n] [--snapshot-dir p] [--restore]"
            );
            return 2;
        }
    };
    let report = match args.workload {
        Workload::Counter => run_counter_child(&args),
        Workload::Bp => run_bp_child(&args),
        Workload::Gibbs => run_gibbs_child(&args),
    };
    let path = args.dir.join(report_name(args.shard));
    if let Err(e) = report.write_file(&path) {
        eprintln!("graphlab shard {}: cannot write report: {e}", args.shard);
        return 1;
    }
    0
}

/// Self-rescheduling counter, restart-safe: a plain `+1 until rounds`
/// overshoots when re-run over restored (already advanced) rows, so both
/// the bump and the respawn are guarded by the target.
struct GuardedBump {
    rounds: u64,
}

impl UpdateFn<u64, ()> for GuardedBump {
    fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
        if *scope.vertex() < self.rounds {
            *scope.vertex_mut() += 1;
        }
        if *scope.vertex() < self.rounds {
            ctx.add_task(scope.center(), 1.0);
        }
    }

    fn name(&self) -> &'static str {
        "guarded-bump"
    }
}

fn counter_chain(n: usize) -> DataGraph<u64, ()> {
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0u64);
    }
    for i in 0..n as u32 - 1 {
        b.add_undirected(i, i + 1, (), ());
    }
    b.build()
}

/// Apply `--restore`: rewind the graph to the newest snapshot epoch for
/// which **every** shard's part is present and readable. All children pick
/// the same epoch (the choice is a pure function of the directory
/// listing), so the fleet restarts from one consistent cut.
fn restore_latest<V: VertexCodec, E>(
    args: &ShardArgs,
    graph: &mut DataGraph<V, E>,
) -> Option<u64> {
    let dir = args.snapshot_dir.as_deref()?;
    let (epoch, parts) = latest_complete_parts(dir, args.shards)?;
    for part in &parts {
        part.restore_into(graph);
    }
    Some(epoch)
}

/// Shared child tail: configure the program for resident execution and
/// enter the engine core.
fn run_resident<V, E>(
    mut prog: Program<'_, V, E>,
    args: &ShardArgs,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport
where
    V: VertexCodec + Clone + Send + Sync,
    E: Send + Sync,
{
    prog = prog
        .workers(args.workers)
        .shards(args.shards)
        .model(ConsistencyModel::Full)
        .ghost_staleness(args.staleness)
        .ghost_batch(args.batch);
    if args.snapshot_every > 0 {
        prog = prog.snapshot_every(args.snapshot_every);
        if let Some(dir) = &args.snapshot_dir {
            prog = prog.snapshot_dir(dir);
        }
    }
    prog.config.resident_shard = Some(args.shard);
    super::sharded::run_resident_shard(&prog, graph, scheduler, sdt, &args.dir, args.shard)
}

/// Encode this shard's **owned** master rows as [`GhostDelta`] frames for
/// the report file — the parent merges them into the global result.
fn encode_owned_rows<V: VertexCodec, E>(
    graph: &mut DataGraph<V, E>,
    shard: usize,
    shards: usize,
) -> Vec<u8> {
    let part = PartitionMap::new(graph.num_vertices(), shards);
    let mut buf = Vec::new();
    for v in part.range(shard) {
        GhostDelta::from_vertex(v, 0, graph.vertex_data_ref(v)).encode_into(&mut buf);
    }
    buf
}

fn run_counter_child(args: &ShardArgs) -> ShardReport {
    let n = Workload::Counter.num_vertices();
    let rounds = args.sweeps as u64;
    let mut g = counter_chain(n);
    if args.restore {
        restore_latest(args, &mut g);
    }
    // Dynamic scheduler, seeded with this shard's owned vertices only —
    // peers seed their own ranges; the counter never spawns across the cut.
    let part = PartitionMap::new(n, args.shards);
    let sched = MultiQueueFifo::new(n, args.workers.max(1));
    for v in part.range(args.shard) {
        sched.add_task(Task::new(v));
    }
    let f = GuardedBump { rounds };
    let report = run_resident(Program::new().update_fn(&f), args, &mut g, &sched, &Sdt::new());
    let rows = encode_owned_rows(&mut g, args.shard, args.shards);
    ShardReport::from_run(args.shard, &report, rows)
}

fn run_bp_child(args: &ShardArgs) -> ShardReport {
    let mut rng = Pcg32::seed_from_u64(42);
    let mut mrf = random_mrf(80, 160, 3, &mut rng);
    let n = mrf.graph.num_vertices();
    let sdt = Sdt::new();
    sdt.set(LAMBDA_KEY, [1.0f64; 3]);
    // Full-sweep set plan, identical in every process: `sweeps` passes over
    // all vertices. The set scheduler ignores BP's residual respawns, so
    // the executed task count is exact — each plan task runs once, in the
    // owner's process (non-owned pops are dropped through the resident
    // handoff, which still releases the plan's DAG dependencies).
    let sets: Vec<(Vec<u32>, crate::scheduler::FuncId)> =
        (0..args.sweeps).map(|_| ((0..n as u32).collect(), 0)).collect();
    let sched = SetScheduler::planned(&sets, n, |v| mrf.graph.neighbors(v), ConsistencyModel::Edge);
    let upd = BpUpdate::new(mrf.arity, 1e-6, Arc::new(mrf.tables.clone()));
    let report = run_resident(Program::new().update_fn(&upd), args, &mut mrf.graph, &sched, &sdt);
    let rows = encode_owned_rows(&mut mrf.graph, args.shard, args.shards);
    ShardReport::from_run(args.shard, &report, rows)
}

fn gibbs_chain() -> DataGraph<GibbsVertex, GibbsEdge> {
    let mut b = GraphBuilder::new();
    for _ in 0..8 {
        b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
    }
    let e = GibbsEdge { potential: EdgePotential::Table(0) };
    for i in 0..7u32 {
        b.add_undirected(i, i + 1, e, e);
    }
    b.build()
}

fn run_gibbs_child(args: &ShardArgs) -> ShardReport {
    let mut g = gibbs_chain();
    // Color sequentially so every process derives the *same* coloring (and
    // therefore the same chromatic plan) from its identical graph copy.
    {
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .run_on(&super::SequentialEngine, &mut g, &sched, &Sdt::new());
    }
    validate_coloring(&mut g).expect("sequential coloring is proper");
    let classes = color_classes(&mut g);
    let sets = chromatic_sets(&classes, args.sweeps, 0);
    let sched =
        SetScheduler::planned(&sets, g.num_vertices(), |v| g.neighbors(v), ConsistencyModel::Edge);
    let tables = vec![vec![1.5, 0.5, 0.5, 1.5]];
    let upd = GibbsUpdate::new(2, Arc::new(tables), args.workers.max(1), 9);
    let report = run_resident(Program::new().update_fn(&upd), args, &mut g, &sched, &Sdt::new());
    let rows = encode_owned_rows(&mut g, args.shard, args.shards);
    ShardReport::from_run(args.shard, &report, rows)
}

// ---------------------------------------------------------------------------
// Per-shard report (child -> parent)
// ---------------------------------------------------------------------------

/// One shard child's run outcome, serialized into the rendezvous directory
/// as `report-<shard>.bin` and read back by [`ProcessHarness::join`].
///
/// Carries the conservation-relevant [`super::ContentionStats`] counters
/// plus the shard's owned master rows ([`GhostDelta`]-framed), so the
/// parent can both audit the cross-process accounting and reassemble the
/// global result without shared memory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardReport {
    /// Which shard of the fleet produced this report.
    pub shard: usize,
    /// Why the child's engine stopped.
    pub stop: StopReason,
    /// Updates executed in this process (owned tasks only — dropped
    /// cross-shard pops count as `handoffs`, never as updates).
    pub updates: u64,
    /// Boundary (ghost-replicated) vertex updates.
    pub boundary_updates: u64,
    /// Tasks popped but not owned here, dropped to the owning process.
    pub handoffs: u64,
    /// Ghost replica writes applied from peer deltas.
    pub ghost_syncs: u64,
    /// Delta frames shipped to peers.
    pub deltas_sent: u64,
    /// Boundary updates coalesced into a not-yet-flushed delta.
    pub deltas_coalesced: u64,
    /// Bytes moved through the socket transport.
    pub bytes_shipped: u64,
    /// Staleness pulls issued at scope admission.
    pub staleness_pulls: u64,
    /// Pulls answered through a peer's owner-side pull service.
    pub pulls_served: u64,
    /// Admission retries after a pull left the replica past the bound.
    pub pull_retries: u64,
    /// Pulls abandoned after the retry budget (stale read admitted).
    pub pull_timeouts: u64,
    /// Worst replica lag (versions) any admitted scope observed.
    pub max_ghost_staleness: u64,
    /// Chandy–Lamport snapshot parts this shard contributed.
    pub snapshots_taken: u64,
    /// This shard's owned master rows as [`GhostDelta`] wire frames.
    pub rows: Vec<u8>,
}

impl ShardReport {
    /// Project the conservation-relevant counters out of a child's
    /// [`RunReport`], attaching the encoded owned rows.
    pub fn from_run(shard: usize, report: &RunReport, rows: Vec<u8>) -> ShardReport {
        let c = &report.contention;
        ShardReport {
            shard,
            stop: report.stop,
            updates: report.updates,
            boundary_updates: c.boundary_updates,
            handoffs: c.handoffs,
            ghost_syncs: c.ghost_syncs,
            deltas_sent: c.deltas_sent,
            deltas_coalesced: c.deltas_coalesced,
            bytes_shipped: c.bytes_shipped,
            staleness_pulls: c.staleness_pulls,
            pulls_served: c.pulls_served,
            pull_retries: c.pull_retries,
            pull_timeouts: c.pull_timeouts,
            max_ghost_staleness: c.max_ghost_staleness,
            snapshots_taken: c.snapshots_taken,
            rows,
        }
    }

    /// Decode the owned master rows back into `(vertex, version, data)`
    /// triples. `None` if the payloads do not decode as `V`.
    pub fn decode_rows<V: VertexCodec>(&self) -> Option<Vec<(VertexId, u64, V)>> {
        let mut r = ByteReader::new(&self.rows);
        let mut out = Vec::new();
        while !r.is_empty() {
            let d = GhostDelta::decode_from(&mut r)?;
            out.push((d.vertex, d.version, d.decode_vertex::<V>()?));
        }
        Some(out)
    }

    fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(128 + self.rows.len());
        put_u32(&mut buf, REPORT_MAGIC);
        put_u32(&mut buf, self.shard as u32);
        put_u32(
            &mut buf,
            match self.stop {
                StopReason::SchedulerEmpty => 0,
                StopReason::TerminationFn => 1,
                StopReason::UpdateLimit => 2,
                StopReason::ShardAborted => 3,
            },
        );
        for c in [
            self.updates,
            self.boundary_updates,
            self.handoffs,
            self.ghost_syncs,
            self.deltas_sent,
            self.deltas_coalesced,
            self.bytes_shipped,
            self.staleness_pulls,
            self.pulls_served,
            self.pull_retries,
            self.pull_timeouts,
            self.max_ghost_staleness,
            self.snapshots_taken,
        ] {
            put_u64(&mut buf, c);
        }
        put_u64(&mut buf, self.rows.len() as u64);
        buf.extend_from_slice(&self.rows);
        buf
    }

    fn decode(bytes: &[u8]) -> Option<ShardReport> {
        let mut r = ByteReader::new(bytes);
        if r.u32()? != REPORT_MAGIC {
            return None;
        }
        let shard = r.u32()? as usize;
        let stop = match r.u32()? {
            0 => StopReason::SchedulerEmpty,
            1 => StopReason::TerminationFn,
            2 => StopReason::UpdateLimit,
            3 => StopReason::ShardAborted,
            _ => return None,
        };
        let mut c = [0u64; 13];
        for slot in &mut c {
            *slot = r.u64()?;
        }
        let row_len = r.u64()? as usize;
        let rows = r.take(row_len)?.to_vec();
        r.is_empty().then_some(ShardReport {
            shard,
            stop,
            updates: c[0],
            boundary_updates: c[1],
            handoffs: c[2],
            ghost_syncs: c[3],
            deltas_sent: c[4],
            deltas_coalesced: c[5],
            bytes_shipped: c[6],
            staleness_pulls: c[7],
            pulls_served: c[8],
            pull_retries: c[9],
            pull_timeouts: c[10],
            max_ghost_staleness: c[11],
            snapshots_taken: c[12],
            rows,
        })
    }

    /// Serialize to `path` atomically (tmp + rename): the parent either
    /// sees no report or a complete one, never a torn write.
    pub fn write_file(&self, path: &Path) -> std::io::Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, path)
    }

    /// Read a report back; `InvalidData` if the file does not decode.
    pub fn read_file(path: &Path) -> std::io::Result<ShardReport> {
        let bytes = std::fs::read(path)?;
        ShardReport::decode(&bytes).ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{} is not a shard report", path.display()),
            )
        })
    }
}

// ---------------------------------------------------------------------------
// The joined fleet
// ---------------------------------------------------------------------------

/// Outcome of one multi-process run: one [`ShardReport`] slot per shard,
/// `None` where a child died without reporting (killed, crashed, or
/// timed out).
#[derive(Debug)]
pub struct ProcessRun {
    /// Per-shard reports, indexed by shard id.
    pub reports: Vec<Option<ShardReport>>,
}

impl ProcessRun {
    /// Did every shard finish and report a drained scheduler?
    pub fn all_finished(&self) -> bool {
        !self.reports.is_empty()
            && self
                .reports
                .iter()
                .all(|r| matches!(r, Some(r) if r.stop == StopReason::SchedulerEmpty))
    }

    fn sum(&self, f: impl Fn(&ShardReport) -> u64) -> u64 {
        self.reports.iter().flatten().map(f).sum()
    }

    /// Updates executed across the fleet.
    pub fn updates(&self) -> u64 {
        self.sum(|r| r.updates)
    }

    /// Boundary updates across the fleet.
    pub fn boundary_updates(&self) -> u64 {
        self.sum(|r| r.boundary_updates)
    }

    /// Delta frames shipped across the fleet.
    pub fn deltas_sent(&self) -> u64 {
        self.sum(|r| r.deltas_sent)
    }

    /// Deltas coalesced into pending frames across the fleet.
    pub fn deltas_coalesced(&self) -> u64 {
        self.sum(|r| r.deltas_coalesced)
    }

    /// Socket bytes moved across the fleet.
    pub fn bytes_shipped(&self) -> u64 {
        self.sum(|r| r.bytes_shipped)
    }

    /// Staleness pulls issued across the fleet.
    pub fn staleness_pulls(&self) -> u64 {
        self.sum(|r| r.staleness_pulls)
    }

    /// Owner-served pulls across the fleet.
    pub fn pulls_served(&self) -> u64 {
        self.sum(|r| r.pulls_served)
    }

    /// Pulls abandoned past the retry budget across the fleet.
    pub fn pull_timeouts(&self) -> u64 {
        self.sum(|r| r.pull_timeouts)
    }

    /// Merge every reporting shard's owned rows into `(vertex, data)`
    /// pairs, sorted by vertex id. Owned ranges are disjoint, so the merge
    /// is a concatenation. `None` if any report's rows fail to decode.
    pub fn merged_rows<V: VertexCodec>(&self) -> Option<Vec<(VertexId, V)>> {
        let mut out = Vec::new();
        for r in self.reports.iter().flatten() {
            out.extend(r.decode_rows::<V>()?.into_iter().map(|(v, _, d)| (v, d)));
        }
        out.sort_by_key(|&(v, _)| v);
        Some(out)
    }
}

// ---------------------------------------------------------------------------
// The parent-side harness
// ---------------------------------------------------------------------------

/// Launches and joins a fleet of `graphlab shard` child processes — the
/// real multi-process deployment of the sharded engine.
///
/// ```no_run
/// use graphlab::engine::ProcessHarness;
/// let dir = std::env::temp_dir().join("graphlab-fleet");
/// let run = ProcessHarness::new(&dir, 2)
///     .workload("counter")
///     .sweeps(100)
///     .launch()
///     .expect("fleet launches")
///     .join()
///     .expect("fleet joins");
/// assert!(run.all_finished());
/// ```
///
/// The harness owns child lifetime: [`ProcessHarness::join`] bounds the
/// wait (default 180 s) and SIGKILLs stragglers rather than hanging the
/// parent, and `Drop` kills anything still running.
pub struct ProcessHarness {
    dir: PathBuf,
    shards: usize,
    workload: Workload,
    workers: usize,
    staleness: u64,
    batch: usize,
    sweeps: usize,
    snapshot_every: u64,
    snapshot_dir: Option<PathBuf>,
    restore: bool,
    binary: PathBuf,
    join_timeout: Duration,
    children: Vec<Option<Child>>,
}

impl ProcessHarness {
    /// A fleet of `shards` processes rendezvousing under `dir` (created if
    /// missing; each child binds its socket endpoints and leaves its
    /// report there). The child binary defaults to the current executable
    /// — override with [`ProcessHarness::binary`] when the caller is not
    /// the `graphlab` binary itself (tests use `CARGO_BIN_EXE_graphlab`).
    pub fn new(dir: impl Into<PathBuf>, shards: usize) -> ProcessHarness {
        ProcessHarness {
            dir: dir.into(),
            shards,
            workload: Workload::Counter,
            workers: 2,
            staleness: 0,
            batch: 1,
            sweeps: 0,
            snapshot_every: 0,
            snapshot_dir: None,
            restore: false,
            binary: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("graphlab")),
            join_timeout: Duration::from_secs(180),
            children: Vec::new(),
        }
    }

    /// Derive a harness from a [`Program`]-built [`EngineConfig`]: shard
    /// count from [`EngineConfig::processes`], worker count and
    /// ghost/snapshot knobs carried over. The workloads stay the preset
    /// ones — update-function closures cannot cross `exec`.
    pub fn from_config(dir: impl Into<PathBuf>, config: &EngineConfig) -> ProcessHarness {
        let mut h = ProcessHarness::new(dir, config.processes.max(2));
        h.workers = config.workers.max(1);
        h.staleness = config.ghost_staleness;
        h.batch = config.ghost_batch;
        h.snapshot_every = config.snapshot_every;
        h.snapshot_dir = config.snapshot_dir.clone();
        h
    }

    /// Select the preset workload (`counter`, `bp`, or `gibbs`).
    /// Panics on an unknown name — the set is closed.
    pub fn workload(mut self, name: &str) -> Self {
        self.workload =
            Workload::parse(name).unwrap_or_else(|| panic!("unknown workload `{name}`"));
        self
    }

    /// Worker threads per child process.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Ghost staleness bound handed to every child.
    pub fn staleness(mut self, s: u64) -> Self {
        self.staleness = s;
        self
    }

    /// Delta batching window handed to every child.
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    /// Sweeps (set workloads) or per-vertex rounds (counter); 0 keeps the
    /// workload default.
    pub fn sweeps(mut self, n: usize) -> Self {
        self.sweeps = n;
        self
    }

    /// Snapshot epoch length (0 disables snapshots).
    pub fn snapshot_every(mut self, n: u64) -> Self {
        self.snapshot_every = n;
        self
    }

    /// Directory the children spill snapshot parts into (and restore
    /// from, with [`ProcessHarness::restore`]).
    pub fn snapshot_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.snapshot_dir = Some(dir.into());
        self
    }

    /// Start every child from the newest complete snapshot epoch in the
    /// snapshot directory instead of from the initial graph.
    pub fn restore(mut self, yes: bool) -> Self {
        self.restore = yes;
        self
    }

    /// Path of the `graphlab` binary to exec for each shard.
    pub fn binary(mut self, path: impl Into<PathBuf>) -> Self {
        self.binary = path.into();
        self
    }

    /// Cap on [`ProcessHarness::join`]'s wait before stragglers are
    /// SIGKILLed.
    pub fn join_timeout(mut self, t: Duration) -> Self {
        self.join_timeout = t;
        self
    }

    /// Spawn the fleet: one `graphlab shard` child per shard, all pointed
    /// at the rendezvous directory. Returns with the children running.
    pub fn launch(mut self) -> std::io::Result<ProcessHarness> {
        std::fs::create_dir_all(&self.dir)?;
        if let Some(snap) = &self.snapshot_dir {
            std::fs::create_dir_all(snap)?;
        }
        self.children = Vec::with_capacity(self.shards);
        for shard in 0..self.shards {
            let mut cmd = Command::new(&self.binary);
            cmd.arg("shard")
                .arg("--dir")
                .arg(&self.dir)
                .arg("--shard")
                .arg(shard.to_string())
                .arg("--shards")
                .arg(self.shards.to_string())
                .arg("--workload")
                .arg(self.workload.as_str())
                .arg("--workers")
                .arg(self.workers.to_string())
                .arg("--staleness")
                .arg(self.staleness.to_string())
                .arg("--batch")
                .arg(self.batch.to_string())
                .arg("--sweeps")
                .arg(self.sweeps.to_string());
            if self.snapshot_every > 0 {
                cmd.arg("--snapshot-every").arg(self.snapshot_every.to_string());
            }
            if let Some(snap) = &self.snapshot_dir {
                cmd.arg("--snapshot-dir").arg(snap);
            }
            if self.restore {
                cmd.arg("--restore");
            }
            cmd.stdin(std::process::Stdio::null());
            match cmd.spawn() {
                Ok(child) => self.children.push(Some(child)),
                Err(e) => {
                    // Abort the partial fleet before surfacing the error.
                    self.kill_all();
                    return Err(e);
                }
            }
        }
        Ok(self)
    }

    /// SIGKILL one shard's child (`Child::kill` is SIGKILL on Unix): the
    /// mid-run crash of the recovery tests. No-op if it already exited.
    pub fn kill(&mut self, shard: usize) -> std::io::Result<()> {
        match self.children.get_mut(shard).and_then(|c| c.as_mut()) {
            Some(child) => {
                child.kill()?;
                let _ = child.wait();
                self.children[shard] = None;
                Ok(())
            }
            None => Ok(()),
        }
    }

    /// True once `snapshot_dir` holds at least one epoch with every
    /// shard's part present — the earliest point a kill is recoverable.
    pub fn snapshot_ready(&self) -> bool {
        self.snapshot_dir
            .as_deref()
            .and_then(|d| latest_complete_parts(d, self.shards))
            .is_some()
    }

    /// Poll [`ProcessHarness::snapshot_ready`] until it holds or
    /// `timeout` elapses.
    pub fn wait_for_snapshot(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.snapshot_ready() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        false
    }

    /// Wait for every child (bounded by the join timeout — stragglers are
    /// SIGKILLed, never waited on forever), then collect the per-shard
    /// reports. A shard that died without writing its report yields
    /// `None` in [`ProcessRun::reports`]; launch-time kills via
    /// [`ProcessHarness::kill`] land there too.
    pub fn join(mut self) -> std::io::Result<ProcessRun> {
        let deadline = Instant::now() + self.join_timeout;
        loop {
            let mut running = false;
            for slot in &mut self.children {
                if let Some(child) = slot {
                    match child.try_wait()? {
                        Some(_) => *slot = None,
                        None => running = true,
                    }
                }
            }
            if !running {
                break;
            }
            if Instant::now() >= deadline {
                self.kill_all();
                break;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        let mut reports = Vec::with_capacity(self.shards);
        for shard in 0..self.shards {
            reports.push(ShardReport::read_file(&self.dir.join(report_name(shard))).ok());
        }
        Ok(ProcessRun { reports })
    }

    fn kill_all(&mut self) {
        for slot in &mut self.children {
            if let Some(mut child) = slot.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

impl Drop for ProcessHarness {
    fn drop(&mut self) {
        self.kill_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn shard_args_parse_roundtrip() {
        let args = ShardArgs::parse(&strs(&[
            "--dir", "/tmp/rdv", "--shard", "1", "--shards", "4", "--workload", "bp",
            "--workers", "3", "--staleness", "4", "--batch", "8", "--sweeps", "5",
            "--snapshot-every", "100", "--snapshot-dir", "/tmp/snap", "--restore",
        ]))
        .expect("full flag set parses");
        assert_eq!(args.shard, 1);
        assert_eq!(args.shards, 4);
        assert_eq!(args.workload, Workload::Bp);
        assert_eq!(args.workers, 3);
        assert_eq!(args.staleness, 4);
        assert_eq!(args.batch, 8);
        assert_eq!(args.sweeps, 5);
        assert_eq!(args.snapshot_every, 100);
        assert_eq!(args.snapshot_dir.as_deref(), Some(Path::new("/tmp/snap")));
        assert!(args.restore);
    }

    #[test]
    fn shard_args_defaults_and_validation() {
        let ok = ShardArgs::parse(&strs(&[
            "--dir", "/tmp/rdv", "--shard", "0", "--shards", "2", "--workload", "counter",
        ]))
        .expect("minimal flag set parses");
        assert_eq!(ok.workers, 2);
        assert_eq!(ok.staleness, 0);
        assert_eq!(ok.batch, 1);
        assert_eq!(ok.sweeps, Workload::Counter.default_sweeps());
        assert!(!ok.restore);

        for bad in [
            &strs(&["--shard", "0", "--shards", "2", "--workload", "counter"])[..],
            &strs(&["--dir", "d", "--shard", "2", "--shards", "2", "--workload", "counter"]),
            &strs(&["--dir", "d", "--shard", "0", "--shards", "1", "--workload", "counter"]),
            &strs(&["--dir", "d", "--shard", "0", "--shards", "2", "--workload", "nope"]),
            &strs(&["--dir", "d", "--shard", "0", "--shards", "2", "--bogus", "x"]),
        ] {
            assert!(ShardArgs::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn shard_report_file_roundtrip() {
        let mut rows = Vec::new();
        for v in 0..4u32 {
            GhostDelta::from_vertex(v, 7, &(v as u64 * 10)).encode_into(&mut rows);
        }
        let report = ShardReport {
            shard: 2,
            stop: StopReason::SchedulerEmpty,
            updates: 123,
            boundary_updates: 45,
            handoffs: 6,
            ghost_syncs: 78,
            deltas_sent: 40,
            deltas_coalesced: 5,
            bytes_shipped: 9001,
            staleness_pulls: 17,
            pulls_served: 17,
            pull_retries: 2,
            pull_timeouts: 0,
            max_ghost_staleness: 3,
            snapshots_taken: 4,
            rows,
        };
        let dir = std::env::temp_dir()
            .join(format!("graphlab-report-roundtrip-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(report_name(2));
        report.write_file(&path).expect("report writes");
        let back = ShardReport::read_file(&path).expect("report reads back");
        assert_eq!(back, report, "disk roundtrip is exact");
        let decoded = back.decode_rows::<u64>().expect("rows decode");
        assert_eq!(decoded.len(), 4);
        assert_eq!(decoded[3], (3, 7, 30));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn process_run_aggregates_and_merges() {
        let mk = |shard: usize, updates: u64, vals: &[(u32, u64)]| {
            let mut rows = Vec::new();
            for &(v, x) in vals {
                GhostDelta::from_vertex(v, 1, &x).encode_into(&mut rows);
            }
            ShardReport {
                shard,
                stop: StopReason::SchedulerEmpty,
                updates,
                boundary_updates: 10,
                handoffs: 0,
                ghost_syncs: 0,
                deltas_sent: 8,
                deltas_coalesced: 2,
                bytes_shipped: 100,
                staleness_pulls: 5,
                pulls_served: 5,
                pull_retries: 0,
                pull_timeouts: 0,
                max_ghost_staleness: 1,
                snapshots_taken: 0,
                rows,
            }
        };
        let run = ProcessRun {
            reports: vec![
                Some(mk(0, 100, &[(0, 7), (1, 7)])),
                Some(mk(1, 50, &[(2, 7), (3, 7)])),
            ],
        };
        assert!(run.all_finished());
        assert_eq!(run.updates(), 150);
        assert_eq!(run.deltas_sent() + run.deltas_coalesced(), 20);
        assert_eq!(run.staleness_pulls(), run.pulls_served());
        let rows = run.merged_rows::<u64>().expect("rows merge");
        assert_eq!(rows, vec![(0, 7), (1, 7), (2, 7), (3, 7)]);

        let dead = ProcessRun { reports: vec![Some(mk(0, 1, &[])), None] };
        assert!(!dead.all_finished(), "a dead shard fails the fleet check");
        assert_eq!(dead.updates(), 1, "aggregation skips dead shards");
    }

    #[test]
    fn workload_presets_are_stable() {
        for (name, w) in
            [("counter", Workload::Counter), ("bp", Workload::Bp), ("gibbs", Workload::Gibbs)]
        {
            assert_eq!(Workload::parse(name), Some(w));
            assert_eq!(w.as_str(), name);
            assert!(w.default_sweeps() > 0);
            assert!(w.num_vertices() > 0);
        }
        assert_eq!(Workload::parse("pagerank"), None);
    }
}
