//! Multithreaded shared-memory engine — the reproduction of the paper's
//! optimized PThreads implementation (§3.6), rebuilt around a
//! **non-blocking scope protocol** and a **lock-free task-distribution
//! layer**:
//!
//! * Worker threads pull tasks from the scheduler and *try*-acquire each
//!   task's scope all-or-nothing ([`Scope::try_lock`]). A conflict never
//!   parks the worker — after a short adaptive spin ladder the task is
//!   **deferred** to the worker's local Chase–Lev deque
//!   ([`WorkStealingDeque`]) and the worker moves on; idle workers steal
//!   deferred tasks from their peers, with a shared [`Injector`] absorbing
//!   deque overflow.
//! * The in-place re-attempt window is **contention-adaptive**: each worker
//!   tunes its ladder from the deferral rate it actually observes (heavy
//!   contention → fail fast to a deferral; light contention → ride out
//!   transient holds in place).
//! * **Deferral fairness**: per-vertex deferral ages are tracked; once a
//!   vertex has accumulated [`EngineConfig::escalate_after`] deferrals its
//!   next dispatch goes through a *blocking* scope acquisition
//!   ([`Scope::lock`]) so a repeatedly conflicted task on a saturated
//!   neighborhood eventually wins.
//! * **Owner affinity**: the affinity-routing schedulers partition vertex
//!   ids into contiguous blocks ([`crate::graph::PartitionMap`]) and
//!   deliver a vertex's tasks to the owning worker's shard; the engine asks
//!   the scheduler for its routing ([`Scheduler::owner_of`]) and counts the
//!   executed hits ([`ContentionStats::affinity_hits`]).
//!
//! Per-worker conflict/deferral/steal/escalation counters are surfaced
//! through [`RunReport::contention`]. A background thread executes periodic
//! sync operations concurrently with the workers (§3.2.2), taking
//! per-vertex read locks during its fold.

use super::{
    ContentionStats, EngineConfig, RunReport, StopReason, TerminationFn, UpdateContext,
    UpdateFn,
};
use crate::consistency::{LockTable, Scope};
use crate::graph::DataGraph;
use crate::scheduler::{Injector, Scheduler, Task, WorkStealingDeque};
use crate::sdt::{Sdt, SyncOp};
use crate::telemetry::{self, EventKind, SampleSources, Telemetry};
use crate::util::Timer;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::time::Duration;

/// Threaded engine. See module docs.
pub struct ThreadedEngine;

const STOP_NONE: u8 = 0;
const STOP_TERM_FN: u8 = 1;
const STOP_LIMIT: u8 = 2;

/// Bounds of the adaptive in-place re-attempt ladder. Each failed attempt
/// spins a short, growing window (`16 << attempt` spin hints) — long enough
/// to ride out a neighbor's brief lock hold, short enough that a real
/// conflict costs a requeue instead of a stall. Shared with the sharded
/// engine, whose interior (non-boundary) path runs the same ladder.
pub(crate) const MIN_ATTEMPTS: u32 = 1;
pub(crate) const MAX_ATTEMPTS: u32 = 4;
/// Every worker starts at the old fixed ladder depth and adapts from there.
pub(crate) const START_ATTEMPTS: u32 = 3;

/// Re-tune the ladder every this many task dispositions.
pub(crate) const TUNE_WINDOW: u32 = 64;
/// Above this deferral rate the ladder shrinks (spinning is wasted — fail
/// fast to the deque); below [`LO_DEFER_RATE`] it grows back.
pub(crate) const HI_DEFER_RATE: f64 = 0.25;
pub(crate) const LO_DEFER_RATE: f64 = 0.02;

/// Per-worker local deque capacity; overflow spills to the shared injector.
pub(crate) const LOCAL_DEQUE_CAP: usize = 256;

/// Steal-half batch bound: one scan never moves more than this many tasks
/// (keeps a thief from emptying a deep victim wholesale).
pub(crate) const STEAL_HALF_MAX: usize = 32;

/// Minimum task dispatches a worker observes before the steal-half
/// auto-flip ([`EngineConfig::steal_half_auto`]) may trigger — too small a
/// sample would flip on startup noise.
pub(crate) const AUTO_STEAL_MIN_POPS: u64 = 64;

/// Steal-half auto-select: should this worker flip its steal scans to
/// steal-half, given what it has observed so far? Shared by both
/// multi-threaded engines.
#[inline]
pub(crate) fn should_auto_steal_half(pops: u64, steals: u64, frac: f64) -> bool {
    pops >= AUTO_STEAL_MIN_POPS && steals as f64 > frac * pops as f64
}

/// Shrink or grow the re-attempt ladder from the deferral rate observed
/// over the last window. Plain worker-local state — no cross-thread traffic.
pub(crate) fn tune_attempts(
    attempts: &mut u32,
    window_tasks: &mut u32,
    window_deferrals: &mut u32,
) {
    if *window_tasks < TUNE_WINDOW {
        return;
    }
    let rate = *window_deferrals as f64 / *window_tasks as f64;
    if rate > HI_DEFER_RATE {
        *attempts = attempts.saturating_sub(1).max(MIN_ATTEMPTS);
    } else if rate < LO_DEFER_RATE {
        *attempts = (*attempts + 1).min(MAX_ATTEMPTS);
    }
    *window_tasks = 0;
    *window_deferrals = 0;
}

impl ThreadedEngine {
    /// Run the program to completion on `config.workers` threads.
    ///
    /// Crate-internal: external callers go through the [`super::Engine`]
    /// trait / [`super::Program`] builder (or
    /// [`super::Program::run_with_locks`] to reuse a lock table across
    /// runs) — the historical public 8-argument signature is folded away.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run<V: Send + Sync, E: Send + Sync>(
        graph: &DataGraph<V, E>,
        locks: &LockTable,
        scheduler: &dyn Scheduler,
        fns: &[&dyn UpdateFn<V, E>],
        sdt: &Sdt,
        syncs: &[SyncOp<V>],
        terminators: &[TerminationFn],
        config: &EngineConfig,
    ) -> RunReport {
        assert_eq!(locks.len(), graph.num_vertices(), "lock table / graph size mismatch");
        let timer = Timer::start();
        let stop = AtomicU8::new(STOP_NONE);
        let engine_done = AtomicBool::new(false);
        // Tasks popped from the scheduler but not yet completed. Deferred
        // tasks stay counted here, so the drain check below cannot conclude
        // early while a conflicted task sits in a retry deque.
        let inflight = AtomicUsize::new(0);
        let total_updates = AtomicU64::new(0);
        let workers = config.workers.max(1);
        let per_worker: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
        let per_conflicts: Vec<AtomicU64> =
            (0..workers).map(|_| AtomicU64::new(0)).collect();
        let per_deferrals: Vec<AtomicU64> =
            (0..workers).map(|_| AtomicU64::new(0)).collect();
        let total_retries = AtomicU64::new(0);
        let total_steals = AtomicU64::new(0);
        let total_escalations = AtomicU64::new(0);
        let total_affinity = AtomicU64::new(0);
        let total_auto_flips = AtomicU64::new(0);
        let syncs_run = AtomicU64::new(0);
        // Per-worker lock-free retry deques for deferred (conflicted)
        // tasks: the owner pushes/pops LIFO (the conflicted scope is still
        // cache-warm); peers steal FIFO from the cold end; the injector
        // absorbs overflow from a saturated deque.
        let retry: Vec<WorkStealingDeque<Task>> =
            (0..workers).map(|_| WorkStealingDeque::new(LOCAL_DEQUE_CAP)).collect();
        let overflow: Injector<Task> =
            Injector::new(config.injector_capacity.max(LOCAL_DEQUE_CAP * workers));
        // Deferred tasks currently waiting in a deque or the injector
        // (conservative upper bound; gates the steal scan).
        let pending_retries = AtomicUsize::new(0);
        // Per-vertex deferral age for the fairness escalation.
        let defer_age: Vec<AtomicU32> =
            (0..graph.num_vertices()).map(|_| AtomicU32::new(0)).collect();
        // The last worker to exit flips `engine_done`, releasing the
        // background sync thread (the thread scope joins everything).
        let workers_remaining = AtomicUsize::new(workers);
        // Telemetry: one ring per worker plus an "engine" control track
        // (empty on this back-end — kept for track-layout uniformity with
        // the sharded engine).
        let tel = config.telemetry.as_ref().map(|cfg| {
            let mut labels: Vec<String> = (0..workers).map(|w| format!("worker-{w}")).collect();
            labels.push("engine".to_string());
            Telemetry::new(cfg.clone(), labels)
        });

        std::thread::scope(|s| {
            // Background sync thread (periodic ops only).
            let has_periodic = syncs.iter().any(|op| op.interval.is_some());
            if has_periodic {
                let engine_done = &engine_done;
                let syncs_run = &syncs_run;
                s.spawn(move || {
                    let mut last_run: Vec<Timer> = syncs.iter().map(|_| Timer::start()).collect();
                    while !engine_done.load(Ordering::Acquire) {
                        for (i, op) in syncs.iter().enumerate() {
                            let Some(interval) = op.interval else { continue };
                            if last_run[i].elapsed() >= interval {
                                Self::locked_sync(graph, locks, op, sdt);
                                syncs_run.fetch_add(1, Ordering::Relaxed);
                                last_run[i] = Timer::start();
                            }
                        }
                        std::thread::sleep(Duration::from_micros(200));
                    }
                });
            }

            // Sampler thread: collapses the live ring counters into the
            // metric time series until the last worker exits.
            if let Some(t) = &tel {
                let engine_done = &engine_done;
                let pending_retries = &pending_retries;
                s.spawn(move || {
                    let queue_depth = || scheduler.approx_len() as u64;
                    let retry_depth = || pending_retries.load(Ordering::Acquire) as u64;
                    let progress_fn = config.progress_metric.clone();
                    let progress = progress_fn.as_ref().map(|f| move || f(sdt));
                    let sources = SampleSources {
                        queue_depth: &queue_depth,
                        retry_depth: &retry_depth,
                        progress: progress.as_ref().map(|f| f as &(dyn Fn() -> f64 + Sync)),
                    };
                    t.sample_loop(engine_done, &sources);
                });
            }

            for w in 0..workers {
                let stop = &stop;
                let inflight = &inflight;
                let total_updates = &total_updates;
                let per_worker = &per_worker;
                let per_conflicts = &per_conflicts;
                let per_deferrals = &per_deferrals;
                let total_retries = &total_retries;
                let total_steals = &total_steals;
                let total_escalations = &total_escalations;
                let total_affinity = &total_affinity;
                let total_auto_flips = &total_auto_flips;
                let retry = &retry;
                let overflow = &overflow;
                let pending_retries = &pending_retries;
                let defer_age = &defer_age;
                let workers_remaining = &workers_remaining;
                let engine_done = &engine_done;
                let tel = &tel;
                s.spawn(move || {
                    let _tel_bind = tel.as_ref().map(|t| t.bind_worker(w));
                    let mut local: u64 = 0;
                    let mut conflicts: u64 = 0;
                    let mut deferrals: u64 = 0;
                    let mut retries: u64 = 0;
                    let mut steals: u64 = 0;
                    let mut escalations: u64 = 0;
                    let mut affinity: u64 = 0;
                    let mut idle_spins: u32 = 0;
                    // Steal-policy auto-select (worker-local): flip to
                    // steal-half once observed steals dominate pops.
                    let mut pops: u64 = 0;
                    let mut use_steal_half = config.steal_half;
                    let mut auto_flips: u64 = 0;
                    // Adaptive conflict control (worker-local).
                    let mut attempts: u32 = START_ATTEMPTS;
                    let mut window_tasks: u32 = 0;
                    let mut window_deferrals: u32 = 0;
                    // After a retry-sourced task conflicts again, look at the
                    // scheduler first next round instead of hammering the
                    // same contended scope.
                    let mut skip_local_once = false;
                    // reused across tasks: keeps the spawned-task buffer warm
                    let mut ctx = UpdateContext::new(sdt, w);
                    loop {
                        if stop.load(Ordering::Acquire) != STOP_NONE {
                            break;
                        }
                        // Task sources: own local deque (LIFO — cache-warm
                        // retries), the scheduler, the overflow injector,
                        // then steals from peers' deques.
                        let mut task: Option<Task> = None;
                        let mut from_retry = false;
                        if !skip_local_once {
                            if let Some(t) = retry[w].pop() {
                                task = Some(t);
                                from_retry = true;
                            }
                        }
                        if task.is_none() {
                            // Count optimistically *before* popping: a task
                            // must never exist outside both the scheduler and
                            // `inflight`, or a peer could pass the drain check
                            // below in the pop-to-increment window and exit
                            // early, collapsing the rest of the run onto one
                            // worker. (The drain check reads `inflight` before
                            // `is_done()`, so either it sees our increment or
                            // the task is still queued and `is_done()` is
                            // false.)
                            inflight.fetch_add(1, Ordering::AcqRel);
                            match scheduler.next_task(w) {
                                Some(t) => task = Some(t),
                                None => {
                                    inflight.fetch_sub(1, Ordering::AcqRel);
                                }
                            }
                        }
                        if task.is_none() && skip_local_once {
                            if let Some(t) = retry[w].pop() {
                                task = Some(t);
                                from_retry = true;
                            }
                        }
                        if task.is_none() && pending_retries.load(Ordering::Acquire) > 0 {
                            if let Some(t) = overflow.pop() {
                                task = Some(t);
                                from_retry = true;
                            } else {
                                for i in 1..workers {
                                    let peer = (w + i) % workers;
                                    // Steal-one by default; the steal-half
                                    // policy drains a batch into our own
                                    // deque so one scan serves several
                                    // future pops (skewed-load option).
                                    let got = if use_steal_half {
                                        let (first, moved) =
                                            retry[peer].steal_half(STEAL_HALF_MAX, |t| {
                                                if let Err(t) = retry[w].push(t) {
                                                    overflow.push(t);
                                                }
                                            });
                                        steals += moved as u64;
                                        first
                                    } else {
                                        retry[peer].steal()
                                    };
                                    if let Some(t) = got {
                                        steals += 1;
                                        task = Some(t);
                                        from_retry = true;
                                        break;
                                    }
                                }
                            }
                        }
                        skip_local_once = false;
                        let Some(task) = task else {
                            if inflight.load(Ordering::Acquire) == 0 && scheduler.is_done() {
                                break;
                            }
                            idle_spins += 1;
                            if idle_spins < 64 {
                                std::hint::spin_loop();
                            } else if idle_spins < 256 {
                                std::thread::yield_now();
                            } else {
                                std::thread::sleep(Duration::from_micros(50));
                            }
                            continue;
                        };
                        idle_spins = 0;
                        pops += 1;
                        if !use_steal_half
                            && should_auto_steal_half(pops, steals, config.steal_half_auto)
                        {
                            use_steal_half = true;
                            auto_flips += 1;
                        }
                        if from_retry {
                            retries += 1;
                            pending_retries.fetch_sub(1, Ordering::AcqRel);
                        }

                        // Scope acquisition. A task whose vertex has aged past
                        // the deferral bound escalates to a blocking acquire
                        // (fairness: it must eventually win); everything else
                        // gets the adaptive non-blocking ladder.
                        let vidx = task.vertex as usize;
                        let age = defer_age[vidx].load(Ordering::Relaxed);
                        let mut scope = None;
                        if age >= config.escalate_after {
                            escalations += 1;
                            telemetry::instant(
                                EventKind::ScopeEscalate,
                                task.vertex as u64,
                                age as u64,
                            );
                            scope = Some(Scope::lock(graph, locks, task.vertex, config.model));
                        } else {
                            // The contend span clock starts at the *first*
                            // failed attempt — a clean acquire costs no
                            // clock read.
                            let mut contend = telemetry::SPAN_OFF;
                            for attempt in 0..attempts {
                                match Scope::try_lock(graph, locks, task.vertex, config.model)
                                {
                                    Ok(s) => {
                                        scope = Some(s);
                                        break;
                                    }
                                    Err(_) => {
                                        conflicts += 1;
                                        if contend == telemetry::SPAN_OFF {
                                            contend = telemetry::span_start();
                                        }
                                        for _ in 0..(16u32 << attempt) {
                                            std::hint::spin_loop();
                                        }
                                    }
                                }
                            }
                            telemetry::span_end(
                                EventKind::ScopeContend,
                                contend,
                                task.vertex as u64,
                                scope.is_some() as u64,
                            );
                        }
                        window_tasks += 1;
                        let Some(mut scope) = scope else {
                            // Defer and move on. The task still counts as in
                            // flight, so the drain check above cannot fire
                            // while it waits.
                            deferrals += 1;
                            window_deferrals += 1;
                            telemetry::instant(
                                EventKind::ScopeDefer,
                                task.vertex as u64,
                                age as u64 + 1,
                            );
                            defer_age[vidx].fetch_add(1, Ordering::Relaxed);
                            pending_retries.fetch_add(1, Ordering::AcqRel);
                            if from_retry {
                                // A *re*-deferred task rotates out to the
                                // shared injector: pushing it back on the
                                // local LIFO deque would make it the very
                                // next local pop, hammering the same
                                // contended scope while other deferred work
                                // sits beneath it.
                                overflow.push(task);
                                skip_local_once = true;
                                std::thread::yield_now();
                            } else if let Err(t) = retry[w].push(task) {
                                overflow.push(t);
                            }
                            tune_attempts(
                                &mut attempts,
                                &mut window_tasks,
                                &mut window_deferrals,
                            );
                            continue;
                        };
                        if age != 0 {
                            defer_age[vidx].store(0, Ordering::Relaxed);
                        }
                        tune_attempts(&mut attempts, &mut window_tasks, &mut window_deferrals);
                        // Affinity accounting at execution time (a deferred
                        // task is not an affinity hit even if its pop was),
                        // against the *scheduler's* routing map — only
                        // owner-affine schedulers report one.
                        if !from_retry && scheduler.owner_of(task.vertex) == Some(w) {
                            affinity += 1;
                        }

                        ctx.reset(w, task.priority);
                        let exec = telemetry::span_start();
                        fns[task.func as usize].update(&mut scope, &mut ctx);
                        drop(scope); // scope locks released before flushing tasks
                        telemetry::span_end(
                            EventKind::TaskExec,
                            exec,
                            task.vertex as u64,
                            task.func as u64,
                        );
                        ctx.drain_spawned(|t| scheduler.add_task(t));
                        scheduler.task_done(task, w);
                        inflight.fetch_sub(1, Ordering::AcqRel);

                        local += 1;
                        let global = total_updates.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(max) = config.max_updates {
                            if global >= max {
                                stop.store(STOP_LIMIT, Ordering::Release);
                                break;
                            }
                        }
                        if local % config.term_check_every == 0 {
                            for term in terminators {
                                if term(sdt) {
                                    stop.store(STOP_TERM_FN, Ordering::Release);
                                    break;
                                }
                            }
                        }
                    }
                    per_worker[w].store(local, Ordering::Release);
                    per_conflicts[w].store(conflicts, Ordering::Release);
                    per_deferrals[w].store(deferrals, Ordering::Release);
                    total_retries.fetch_add(retries, Ordering::AcqRel);
                    total_steals.fetch_add(steals, Ordering::AcqRel);
                    total_escalations.fetch_add(escalations, Ordering::AcqRel);
                    total_affinity.fetch_add(affinity, Ordering::AcqRel);
                    total_auto_flips.fetch_add(auto_flips, Ordering::AcqRel);
                    if workers_remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        engine_done.store(true, Ordering::Release);
                    }
                });
            }
        });
        engine_done.store(true, Ordering::Release);

        // Final execution of every sync op so the SDT reflects the end state.
        for op in syncs {
            Self::locked_sync(graph, locks, op, sdt);
            syncs_run.fetch_add(1, Ordering::Relaxed);
        }

        let stop_reason = match stop.load(Ordering::Acquire) {
            STOP_TERM_FN => StopReason::TerminationFn,
            STOP_LIMIT => StopReason::UpdateLimit,
            _ => StopReason::SchedulerEmpty,
        };
        let per_worker_conflicts: Vec<u64> =
            per_conflicts.iter().map(|c| c.load(Ordering::Acquire)).collect();
        let per_worker_deferrals: Vec<u64> =
            per_deferrals.iter().map(|c| c.load(Ordering::Acquire)).collect();
        RunReport {
            updates: total_updates.load(Ordering::Relaxed),
            wall_secs: timer.elapsed_secs(),
            stop: stop_reason,
            per_worker: per_worker.iter().map(|c| c.load(Ordering::Acquire)).collect(),
            syncs_run: syncs_run.load(Ordering::Relaxed),
            contention: ContentionStats {
                conflicts: per_worker_conflicts.iter().sum(),
                deferrals: per_worker_deferrals.iter().sum(),
                retries: total_retries.load(Ordering::Acquire),
                steals: total_steals.load(Ordering::Acquire),
                escalations: total_escalations.load(Ordering::Acquire),
                affinity_hits: total_affinity.load(Ordering::Acquire),
                has_owner_map: scheduler.owner_of(0).is_some(),
                auto_steal_half_flips: total_auto_flips.load(Ordering::Acquire),
                per_worker_conflicts,
                per_worker_deferrals,
                ..ContentionStats::default()
            },
            snapshots: Vec::new(),
            telemetry: tel.map(Telemetry::finish),
        }
    }

    /// Sync fold under per-vertex read locks (Alg. 1 running concurrently
    /// with update functions; the aggregate may be temporally inconsistent —
    /// "many ML applications are robust to approximate global statistics").
    /// Shared with the sharded engine's sync thread.
    pub(crate) fn locked_sync<V: Send + Sync, E: Send + Sync>(
        graph: &DataGraph<V, E>,
        locks: &LockTable,
        op: &SyncOp<V>,
        sdt: &Sdt,
    ) {
        let mut acc = op.init_acc();
        for v in 0..graph.num_vertices() as u32 {
            let _g = locks.read(v);
            // SAFETY: read lock on v held.
            acc = op.fold_acc(acc, unsafe { graph.vertex_data_unchecked(v) });
        }
        op.apply_acc(acc, sdt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::graph::GraphBuilder;
    use crate::scheduler::{FifoScheduler, MultiQueueFifo, Task};
    use crate::sdt::SyncOpBuilder;

    fn ring(n: usize) -> (DataGraph<u64, ()>, LockTable) {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n {
            b.add_undirected(i as u32, ((i + 1) % n) as u32, (), ());
        }
        let g = b.build();
        let l = LockTable::new(n);
        (g, l)
    }

    /// Each vertex bumps its counter `rounds` times, rescheduling itself.
    struct SelfBump {
        rounds: u64,
    }
    impl UpdateFn<u64, ()> for SelfBump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.rounds {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    #[test]
    fn all_tasks_execute_exactly_to_convergence() {
        let n = 64;
        let (g, locks) = ring(n);
        let sched = MultiQueueFifo::new(n, 4);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 10 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(4),
        );
        assert_eq!(report.stop, StopReason::SchedulerEmpty);
        assert_eq!(report.updates, (n as u64) * 10);
        let mut g = g;
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10);
        }
        assert_eq!(report.per_worker.iter().sum::<u64>(), report.updates);
    }

    /// Neighbor-increment under Full consistency: concurrent updates to a
    /// shared hub must serialize (no lost updates).
    struct BumpNeighbors;
    impl UpdateFn<u64, ()> for BumpNeighbors {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, _ctx: &mut UpdateContext<'_>) {
            for &u in scope.neighbors() {
                *scope.neighbor_mut(u) += 1;
            }
        }
    }

    #[test]
    fn full_consistency_no_lost_updates() {
        let n = 16;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        // schedule every vertex 50 times via self-rescheduling wrapper
        struct Repeat {
            inner: BumpNeighbors,
            times: u64,
        }
        impl UpdateFn<u64, ()> for Repeat {
            fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
                self.inner.update(scope, ctx);
                let k = ctx.sdt.get_or::<u64>("noop", 0); // exercise SDT read path
                let _ = k;
                ctx.current_priority += 1.0;
                if ctx.current_priority < self.times as f64 {
                    let c = scope.center();
                    let p = ctx.current_priority;
                    ctx.add_task(c, p);
                }
            }
        }
        let f = Repeat { inner: BumpNeighbors, times: 50 };
        for v in 0..n as u32 {
            sched.add_task(Task::with_priority(v, 0.0));
        }
        let sdt = Sdt::new();
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(4).with_model(ConsistencyModel::Full),
        );
        // every vertex updated 50 times, each update bumps 2 neighbors:
        // every vertex receives 2 bumps per round from its two neighbors.
        let mut g = g;
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 100, "vertex {v}");
        }
        assert_eq!(report.updates, n as u64 * 50);
        // accounting: the run drained, so every deferred task was re-dispatched
        assert!(report.contention.retries >= report.contention.deferrals);
    }

    #[test]
    fn update_limit_enforced() {
        let n = 8;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: u64::MAX };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(2).with_max_updates(100),
        );
        assert_eq!(report.stop, StopReason::UpdateLimit);
        assert!(report.updates >= 100 && report.updates < 120);
    }

    #[test]
    fn background_sync_runs_concurrently() {
        let n = 32;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 400 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let sum_op = SyncOpBuilder::<u64, u64>::new("total", 0)
            .every(Duration::from_millis(1))
            .build(|acc, v| acc + *v, |acc, sdt| sdt.set("total", acc));
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[sum_op],
            &[],
            &EngineConfig::default().with_workers(2),
        );
        // final sync always runs, so the SDT must hold the exact final total
        assert_eq!(sdt.get::<u64>("total"), Some(32 * 400));
        assert!(report.syncs_run >= 1);
    }

    #[test]
    fn termination_fn_halts_engine() {
        let n = 8;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: u64::MAX };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let term: TerminationFn = Box::new(|_| true);
        let mut cfg = EngineConfig::default().with_workers(2);
        cfg.term_check_every = 8;
        let report =
            ThreadedEngine::run(&g, &locks, &sched, &fns, &sdt, &[], &[term], &cfg);
        assert_eq!(report.stop, StopReason::TerminationFn);
        assert!(report.updates < 1000);
    }

    /// Single worker, no background sync: nothing can conflict, so the
    /// contention counters must be exactly zero — and the strict FIFO has
    /// no owner-affine routing, so the affinity counter stays zero too
    /// (the 1-worker all-hits invariant lives in engine_stress with the
    /// affinity-routing multiqueue scheduler).
    #[test]
    fn single_worker_never_defers() {
        let n = 32;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 20 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(1).with_model(ConsistencyModel::Full),
        );
        assert_eq!(report.updates, n as u64 * 20);
        assert_eq!(report.contention.conflicts, 0);
        assert_eq!(report.contention.deferrals, 0);
        assert_eq!(report.contention.retries, 0);
        assert_eq!(report.contention.steals, 0);
        assert_eq!(report.contention.escalations, 0);
        assert_eq!(
            report.contention.affinity_hits, 0,
            "strict FIFO reports no owner routing"
        );
    }

    /// `escalate_after = 0` turns every dispatch into a blocking scope
    /// acquisition (the fairness path, exercised deterministically): the
    /// run must still be exactly correct, with zero conflicts/deferrals and
    /// one escalation per update.
    #[test]
    fn immediate_escalation_is_blocking_and_correct() {
        let n = 32;
        let (g, locks) = ring(n);
        let sched = MultiQueueFifo::new(n, 2);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 10 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default()
                .with_workers(2)
                .with_model(ConsistencyModel::Full)
                .with_escalate_after(0),
        );
        assert_eq!(report.updates, n as u64 * 10);
        let mut g = g;
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10);
        }
        assert_eq!(report.contention.escalations, report.updates);
        assert_eq!(report.contention.deferrals, 0, "blocking path never defers");
        assert_eq!(report.contention.conflicts, 0, "blocking path skips the try ladder");
    }

    /// Telemetry conservation on the threaded back-end: exactly one task
    /// span per executed update, one defer/escalate instant per counted
    /// deferral/escalation, and the sampler produced a series.
    #[test]
    fn telemetry_spans_conserve_update_count() {
        use crate::telemetry::TelemetryConfig;
        let n = 32;
        let (g, locks) = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 10 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ThreadedEngine::run(
            &g,
            &locks,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default()
                .with_workers(4)
                .with_model(ConsistencyModel::Full)
                .with_telemetry(TelemetryConfig::default()),
        );
        assert_eq!(report.updates, n as u64 * 10);
        let tel = report.telemetry.expect("telemetry enabled");
        assert_eq!(tel.count(EventKind::TaskExec), report.updates);
        assert_eq!(tel.count(EventKind::ScopeDefer), report.contention.deferrals);
        assert_eq!(tel.count(EventKind::ScopeEscalate), report.contention.escalations);
        assert!(tel.samples.len() >= 2, "first + final sample");
        assert_eq!(tel.tracks.len(), 5, "4 worker rings + engine control track");
    }

    // The contended-hub scenario (nonzero deferrals under Full consistency,
    // conservation vs the sequential engine, per-worker counter accounting,
    // escalation under a saturated hub) lives in rust/tests/sched_stress.rs
    // and rust/tests/engine_stress.rs to avoid maintaining multiple copies.
}
