//! The **sharded engine**: a distributed-style execution back-end rehearsed
//! over threads, after Distributed GraphLab's Locking Engine (Low et al.
//! 2012) — the architectural step between the shared-memory
//! [`ThreadedEngine`] and a real multi-process deployment.
//!
//! The data graph is cut into `k` ghost-replicated shards
//! ([`crate::graph::ShardedGraph`]); each shard runs its **own worker set**
//! against the shared scheduler plus a per-shard injector ring for
//! **cross-shard task handoff** (a worker that pops a task owned by another
//! shard forwards it to the owner's ring instead of executing it —
//! emulating the network hop a cluster would pay, counted in
//! [`ContentionStats::handoffs`]).
//!
//! Scope acquisition is shard-aware:
//!
//! * **Interior** vertices (no remote neighbor) use the threaded engine's
//!   adaptive non-blocking ladder unchanged.
//! * **Boundary** vertices go through **pipelined/split acquisition**
//!   ([`crate::consistency::LockTable::try_lock_split`]): the locks owned
//!   by remote shards are "requested" first, non-blocking; if they are
//!   granted but the local half is busy the worker *parks the held remote
//!   half* ([`ContentionStats::pipelined_stalls`]) and keeps executing
//!   other work, retrying completion each loop until a bounded attempt
//!   budget expires (then the remote half is released and the task
//!   deferred). The worker never blocks while holding — the deadlock-free
//!   discipline of the non-blocking core is preserved.
//!
//! Ghost propagation flows through the pluggable **transport layer**
//! ([`crate::transport`]): after a boundary update the owner bumps the
//! vertex's master version and records a versioned delta in its worker's
//! [`DeltaBatcher`]; the batcher coalesces repeated writes within a sync
//! window and flushes through a [`GhostTransport`] backend — the in-place
//! [`DirectTransport`] for [`ShardedEngine`], the serializing
//! [`ChannelTransport`] for [`ChannelShardedEngine`], the Unix-socket
//! [`SocketTransport`] for [`SocketShardedEngine`] — on window close,
//! batch-size threshold, cross-shard handoff, idle, and worker exit.
//! Read freshness is guarded by the **bounded-staleness** admission check:
//! a scope about to read a ghost replica more than
//! [`EngineConfig::ghost_staleness`] master versions behind forces a
//! pull-on-demand first (`s = 0` reproduces the synchronous per-update
//! flush semantics). The pull rides the transport's request/reply path,
//! so on a serializing backend admission never reads peer master data
//! directly (`ContentionStats::pulls_served` counts the wire-served
//! pulls).
//!
//! **Fault tolerance** rides the same seams: [`EngineConfig::fault_plan`]
//! wraps the chosen backend in a [`crate::transport::FaultInjector`]
//! (deterministic seeded drops, duplicates, delays/reorders, severed
//! pulls), [`EngineConfig::snapshot_every`] triggers Chandy–Lamport-style
//! epoch snapshots of every shard's master rows (see [`super::snapshot`]),
//! and [`EngineConfig::abort_plan`] kills one shard's worker set mid-run
//! (surfaced as [`StopReason::ShardAborted`], batched deltas lost) so
//! recovery via [`ShardedEngine::restore_from_snapshot`] can be exercised
//! end to end.

use super::threaded::{
    should_auto_steal_half, tune_attempts, ThreadedEngine, LOCAL_DEQUE_CAP, START_ATTEMPTS,
    STEAL_HALF_MAX,
};
use super::snapshot::{Snapshot, SnapshotCtl};
use super::{
    ContentionStats, Engine, EngineConfig, Program, RunReport, StopReason, TerminationFn,
    UpdateContext, UpdateFn,
};
use crate::consistency::{LockTable, Scope, SplitScope};
use crate::graph::{DataGraph, ShardedGraph};
use crate::scheduler::{Injector, Scheduler, Task, WorkStealingDeque};
use crate::sdt::{Sdt, SyncOp};
use crate::telemetry::{self, EventKind, SampleSources, Telemetry};
use crate::transport::{
    ChannelTransport, DeltaBatcher, DirectTransport, FaultInjector, GhostTransport, ShmTransport,
    SocketTransport, VertexCodec,
};
use crate::util::Timer;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::time::Duration;

const STOP_NONE: u8 = 0;
const STOP_TERM_FN: u8 = 1;
const STOP_LIMIT: u8 = 2;
const STOP_ABORT: u8 = 3;

/// How many completion attempts a parked split acquisition gets before the
/// worker releases the remote half and defers the task. Bounded so two
/// shards whose pending acquisitions mutually block each other's local
/// halves always make progress (both eventually release and retry).
const PENDING_ATTEMPTS: u32 = 16;

/// Starting drain tick: a worker consults its shard's incoming transport
/// queues every this many completed updates (on top of the
/// idle/handoff/final drains), then adapts the tick per worker on the
/// queued byte depth — see the drain logic in [`run_core`]. Clamped into
/// the backend's [`GhostTransport::drain_tick_bounds`] at run start: the
/// socket-era `(8, 512)` default backs far off between inbox sweeps,
/// while the shm rings advertise tight bounds so a cheap `pop_all` drain
/// is never throttled into stale-replica churn.
const DRAIN_TICK_START: u64 = 64;

/// Queued-byte watermark above which a worker drops its drain tick to
/// the backend's minimum bound.
const DRAIN_HIGH_BYTES: u64 = 64 << 10;

/// A split acquisition whose remote half is held while the local half was
/// busy: the worker carries it across loop iterations, doing other work in
/// between (the Locking-Engine pipeline).
struct PendingAcquire<'a> {
    task: Task,
    split: SplitScope<'a>,
    attempts: u32,
}

/// Sharded engine back-end over the in-place [`DirectTransport`].
/// `shards = 0` defers to [`EngineConfig::shards`] at run time.
#[derive(Debug, Clone, Default)]
pub struct ShardedEngine {
    pub shards: usize,
}

impl ShardedEngine {
    pub fn new(shards: usize) -> ShardedEngine {
        ShardedEngine { shards }
    }

    /// Run the program to completion over `k` shards with the
    /// direct-memory ghost transport. Worker threads: `max(1,
    /// config.workers / k)` per shard, so every shard always has its own
    /// worker set.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run<V: Clone + Send + Sync, E: Send + Sync>(
        &self,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        fns: &[&dyn UpdateFn<V, E>],
        sdt: &Sdt,
        syncs: &[SyncOp<V>],
        terminators: &[TerminationFn],
        config: &EngineConfig,
    ) -> RunReport {
        let requested = if self.shards > 0 { self.shards } else { config.shards };
        let sharded = ShardedGraph::new(graph, requested.max(1));
        let graph: &DataGraph<V, E> = graph;
        let transport = DirectTransport::new(&sharded);
        // No snapshot controller on the direct path: snapshots serialize
        // rows through the vertex codec, which only the codec-bearing
        // engines require of `V`.
        run_with_faults(
            graph,
            &sharded,
            &transport,
            scheduler,
            fns,
            sdt,
            syncs,
            terminators,
            config,
            None,
        )
    }

    /// Restore `graph`'s vertex rows from a completed [`Snapshot`] — the
    /// recovery half of the Chandy–Lamport protocol (see
    /// [`super::snapshot`]). Returns the number of rows rewound.
    ///
    /// Recovery is restore-then-rerun: rewind the graph to the snapshot
    /// cut, then run the program again with a fresh scheduler seed.
    /// Update functions are restartable by contract (re-scheduling a
    /// vertex is always safe), so the re-run converges exactly as an
    /// uninterrupted run would; ghost tables and transport lanes are
    /// rebuilt from the restored masters, never restored themselves.
    pub fn restore_from_snapshot<V: VertexCodec, E>(
        graph: &mut DataGraph<V, E>,
        snapshot: &Snapshot,
    ) -> u64 {
        snapshot.restore_into(graph)
    }
}

/// Sharded engine back-end whose ghost traffic rides the serializing
/// [`ChannelTransport`] — every delta is byte-encoded through the vertex's
/// [`VertexCodec`], queued on a per-shard-pair channel, and decoded at the
/// destination, simulating a multi-process boundary. Requires the vertex
/// type to implement [`VertexCodec`]; everything else (scheduling,
/// locking, batching, staleness) is identical to [`ShardedEngine`].
#[derive(Debug, Clone, Default)]
pub struct ChannelShardedEngine {
    pub shards: usize,
    /// Ship compressed delta frames (varint header + shadow diff) instead
    /// of raw ones — see [`ChannelTransport::compressed`].
    pub compress: bool,
}

impl ChannelShardedEngine {
    pub fn new(shards: usize) -> ChannelShardedEngine {
        ChannelShardedEngine { shards, compress: false }
    }

    /// Like [`ChannelShardedEngine::new`], but delta lanes carry
    /// compressed frames (transport name `"channel-z"`).
    pub fn compressed(shards: usize) -> ChannelShardedEngine {
        ChannelShardedEngine { shards, compress: true }
    }
}

impl<V, E> Engine<V, E> for ChannelShardedEngine
where
    V: VertexCodec + Clone + Send + Sync,
    E: Send + Sync,
{
    fn name(&self) -> &'static str {
        "sharded-channel"
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        let config = &program.config;
        let requested = if self.shards > 0 { self.shards } else { config.shards };
        let sharded = ShardedGraph::new(graph, requested.max(1));
        let graph: &DataGraph<V, E> = graph;
        let transport = if self.compress {
            ChannelTransport::compressed(&sharded)
        } else {
            ChannelTransport::new(&sharded)
        };
        let snap = SnapshotCtl::from_config(config);
        run_with_faults(
            graph,
            &sharded,
            &transport,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            config,
            snap.as_ref(),
        )
    }
}

/// Sharded engine back-end whose ghost traffic rides the
/// [`SocketTransport`]: every delta and every staleness pull crosses a
/// real Unix-domain socket as length-prefixed bytes — the wire-ready
/// rehearsal of a multi-process deployment, selected via
/// `Program::transport("socket")` or `run_on`. Socket files live in a
/// per-run temp directory and are removed when the run ends. Everything
/// above the transport (scheduling, locking, batching, staleness) is
/// identical to [`ShardedEngine`].
#[derive(Debug, Clone, Default)]
pub struct SocketShardedEngine {
    /// Shard count (`0` defers to `EngineConfig::shards` at run time).
    pub shards: usize,
    /// Per-connection bounded send window in bytes (`0` = the transport
    /// default, [`crate::transport::DEFAULT_SEND_BUFFER`]). Senders that
    /// would overflow it stall — counted in
    /// `ContentionStats::backpressure_stalls`. Applies to the raw
    /// variant; the compressed variant uses the default window.
    pub send_buffer: usize,
    /// Ship shadow-diff compressed delta frames instead of raw ones —
    /// see [`SocketTransport::compressed`] (transport name `"socket-z"`).
    pub compress: bool,
}

impl SocketShardedEngine {
    /// Engine over `shards` shards with the default send window.
    pub fn new(shards: usize) -> SocketShardedEngine {
        SocketShardedEngine { shards, send_buffer: 0, compress: false }
    }

    /// Like [`SocketShardedEngine::new`], but delta frames cross the
    /// sockets shadow-diff compressed (transport name `"socket-z"`).
    pub fn compressed(shards: usize) -> SocketShardedEngine {
        SocketShardedEngine { shards, send_buffer: 0, compress: true }
    }

    /// Override the per-connection bounded send window (bytes).
    pub fn with_send_buffer(mut self, bytes: usize) -> SocketShardedEngine {
        self.send_buffer = bytes;
        self
    }
}

impl<V, E> Engine<V, E> for SocketShardedEngine
where
    V: VertexCodec + Clone + Send + Sync,
    E: Send + Sync,
{
    fn name(&self) -> &'static str {
        if self.compress {
            "sharded-socket-z"
        } else {
            "sharded-socket"
        }
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        let config = &program.config;
        let requested = if self.shards > 0 { self.shards } else { config.shards };
        let sharded = ShardedGraph::new(graph, requested.max(1));
        let graph: &DataGraph<V, E> = graph;
        let transport = if self.compress {
            SocketTransport::compressed(&sharded)
        } else {
            match self.send_buffer {
                0 => SocketTransport::new(&sharded),
                cap => SocketTransport::with_send_buffer(&sharded, cap),
            }
        }
        .expect("failed to set up the unix-socket ghost transport");
        let snap = SnapshotCtl::from_config(config);
        run_with_faults(
            graph,
            &sharded,
            &transport,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            config,
            snap.as_ref(),
        )
    }
}

/// Run one **resident shard** of a k-way multi-process deployment to
/// completion inside this process: cut the (identically rebuilt) data
/// graph, bring up the [`SocketTransport`] in resident mode against the
/// shared rendezvous directory (bind own endpoints first, then connect
/// out to every peer with bounded retry), and enter the shared engine
/// core with [`EngineConfig::resident_shard`] set — one shard's worker
/// set, owner-side pull service, cross-shard spawns dropped. Called by
/// the `graphlab shard` child entrypoint ([`super::process`]); the
/// scheduler must be seeded with this shard's **owned vertices only**
/// (peers seed their own).
pub(crate) fn run_resident_shard<V, E>(
    program: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
    dir: &std::path::Path,
    shard: usize,
) -> RunReport
where
    V: VertexCodec + Clone + Send + Sync,
    E: Send + Sync,
{
    let config = &program.config;
    debug_assert_eq!(
        config.resident_shard,
        Some(shard),
        "resident run entered without the resident-shard config"
    );
    let sharded = ShardedGraph::new(graph, config.shards.max(1));
    let graph: &DataGraph<V, E> = graph;
    let transport = SocketTransport::resident(&sharded, dir, shard)
        .expect("failed to set up the resident rendezvous transport");
    let snap = SnapshotCtl::from_config(config);
    run_with_faults(
        graph,
        &sharded,
        &transport,
        scheduler,
        &program.fns,
        sdt,
        &program.syncs,
        &program.terminators,
        config,
        snap.as_ref(),
    )
}

/// Sharded engine back-end whose ghost traffic rides the [`ShmTransport`]:
/// every delta crosses a per-shard-pair lock-free SPSC byte ring over
/// process-shareable memory — the same-host fast lane a forked-shard
/// topology would use, selected via `Program::transport("shm")`. Drains
/// are a `memcpy` off the ring rather than an inbox sweep, so the
/// transport advertises tight [`GhostTransport::drain_tick_bounds`] and
/// the adaptive drain tick stays hot. Everything above the transport
/// (scheduling, locking, batching, staleness) is identical to
/// [`ShardedEngine`].
#[derive(Debug, Clone, Default)]
pub struct ShmShardedEngine {
    /// Shard count (`0` defers to `EngineConfig::shards` at run time).
    pub shards: usize,
}

impl ShmShardedEngine {
    /// Engine over `shards` shards with the default ring capacity.
    pub fn new(shards: usize) -> ShmShardedEngine {
        ShmShardedEngine { shards }
    }
}

impl<V, E> Engine<V, E> for ShmShardedEngine
where
    V: VertexCodec + Clone + Send + Sync,
    E: Send + Sync,
{
    fn name(&self) -> &'static str {
        "sharded-shm"
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        let config = &program.config;
        let requested = if self.shards > 0 { self.shards } else { config.shards };
        let sharded = ShardedGraph::new(graph, requested.max(1));
        let graph: &DataGraph<V, E> = graph;
        let transport = ShmTransport::new(&sharded);
        let snap = SnapshotCtl::from_config(config);
        run_with_faults(
            graph,
            &sharded,
            &transport,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            config,
            snap.as_ref(),
        )
    }
}

/// Pin the calling worker thread to one CPU core (Linux
/// `sched_setaffinity`; no-op elsewhere, with a one-time warning so a
/// `pin_workers(true)` run on another platform is loud about ignoring the
/// knob). Returns whether the pin took.
#[cfg(target_os = "linux")]
fn pin_worker_to_core(core: usize) -> bool {
    // Hand-declared to stay std-only: pid 0 = the calling thread. The
    // 1024-bit mask matches glibc's `cpu_set_t`.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const usize) -> i32;
    }
    const MASK_WORDS: usize = 1024 / (usize::BITS as usize);
    let mut mask = [0usize; MASK_WORDS];
    let bit = core % 1024;
    mask[bit / (usize::BITS as usize)] = 1usize << (bit % (usize::BITS as usize));
    unsafe {
        sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_worker_to_core(_core: usize) -> bool {
    static WARN_ONCE: std::sync::Once = std::sync::Once::new();
    WARN_ONCE.call_once(|| {
        eprintln!(
            "graphlab: pin_workers is only implemented on Linux \
             (sched_setaffinity); running unpinned"
        );
    });
    false
}

/// Close a worker's sync window: ship every batched delta and fold the
/// receipt into the worker's transport counters. The single accounting
/// point for all four flush triggers (window close, handoff, idle, exit).
fn flush_window<V>(
    batcher: &mut DeltaBatcher<V>,
    shard: usize,
    transport: &dyn GhostTransport<V>,
    deltas_sent: &mut u64,
    ghost_syncs: &mut u64,
    bytes_shipped: &mut u64,
) {
    if batcher.is_empty() {
        return;
    }
    let span = telemetry::span_start();
    let r = batcher.flush(shard, transport);
    telemetry::span_end(EventKind::DeltaFlush, span, r.deltas, r.bytes);
    telemetry::add_ghost_bytes(r.bytes);
    *deltas_sent += r.deltas;
    *ghost_syncs += r.replicas;
    *bytes_shipped += r.bytes;
}

/// Resolve the config's fault plan before entering [`run_core`]: with a
/// plan set, the chosen backend is wrapped in a [`FaultInjector`] so every
/// delta send and staleness pull crosses the deterministic lossy wire; the
/// engine core sees only the `GhostTransport` trait either way.
#[allow(clippy::too_many_arguments)]
fn run_with_faults<V: Clone + Send + Sync, E: Send + Sync>(
    graph: &DataGraph<V, E>,
    sharded: &ShardedGraph<V>,
    transport: &dyn GhostTransport<V>,
    scheduler: &dyn Scheduler,
    fns: &[&dyn UpdateFn<V, E>],
    sdt: &Sdt,
    syncs: &[SyncOp<V>],
    terminators: &[TerminationFn],
    config: &EngineConfig,
    snap: Option<&SnapshotCtl<V>>,
) -> RunReport {
    match config.fault_plan {
        Some(plan) => {
            let injector = FaultInjector::new(transport, plan);
            run_core(
                graph, sharded, &injector, scheduler, fns, sdt, syncs, terminators, config, snap,
            )
        }
        None => run_core(
            graph, sharded, transport, scheduler, fns, sdt, syncs, terminators, config, snap,
        ),
    }
}

/// Serialize one shard's owned master rows for a snapshot epoch: each row
/// is frozen under its read lock and encoded in the transport's delta
/// frame format. Locks are taken **one at a time** — the capturer never
/// holds-and-waits, so capture cannot deadlock against parked split
/// acquisitions (their holders never block while holding either).
fn capture_shard_part<V, E>(
    graph: &DataGraph<V, E>,
    sharded: &ShardedGraph<V>,
    locks: &LockTable,
    shard: usize,
    ctl: &SnapshotCtl<V>,
) -> (Vec<u8>, u64) {
    let sh = sharded.shard(shard);
    let mut frames = Vec::with_capacity(sh.num_owned() * 16);
    let mut rows = 0u64;
    for v in sh.owned_range() {
        let _guard = locks.read(v);
        let version = sharded.master_version(v);
        // Safety: the held read lock excludes the owner's write path, so
        // the master row is stable for the duration of the encode.
        let data = unsafe { graph.vertex_data_unchecked(v) };
        ctl.encode_frame(v, version, data, &mut frames);
        rows += 1;
    }
    (frames, rows)
}

/// Resident-mode snapshot persistence: a process hosting one shard cannot
/// assemble its peers' parts, so it writes its own captured part straight
/// to the snapshot directory as `snapshot-epoch-<e>-shard-<r>.bin`
/// (atomically, tmp + rename, so a kill-9 mid-write never leaves a
/// half-part that recovery would mistake for a complete one). Recovery
/// scans the directory for the newest epoch with all `k` parts present
/// ([`super::snapshot::latest_complete_parts`]). Without a snapshot
/// directory configured there is nowhere to persist — the capture is
/// dropped (resident snapshots are only meaningful on disk).
fn write_resident_part<V>(
    ctl: &SnapshotCtl<V>,
    epoch: u64,
    shard: usize,
    frames: Vec<u8>,
    rows: u64,
) {
    let Some(dir) = ctl.dir() else { return };
    let part = Snapshot::from_parts(epoch, rows, frames);
    let path = dir.join(super::snapshot::shard_part_name(epoch, shard));
    let tmp = path.with_extension("tmp");
    if part.write_file(&tmp).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
}

/// The shared worker-loop core: every ghost write leaves through
/// `transport`, every ghost read is staleness-checked at scope admission.
#[allow(clippy::too_many_arguments)]
fn run_core<V: Clone + Send + Sync, E: Send + Sync>(
    graph: &DataGraph<V, E>,
    sharded: &ShardedGraph<V>,
    transport: &dyn GhostTransport<V>,
    scheduler: &dyn Scheduler,
    fns: &[&dyn UpdateFn<V, E>],
    sdt: &Sdt,
    syncs: &[SyncOp<V>],
    terminators: &[TerminationFn],
    config: &EngineConfig,
    snap: Option<&SnapshotCtl<V>>,
) -> RunReport {
    let k = sharded.num_shards();
    let locks = LockTable::new(graph.num_vertices());
    // Synchronous mode over an apply-at-send backend flushes every replica
    // under the owner's still-held write lock, so admission can provably
    // never observe lag — skip the per-ghost staleness scan (keeps the
    // default configuration at PR 3's per-boundary-update cost).
    let staleness_scan = !(transport.applies_at_send()
        && config.ghost_batch <= 1
        && config.ghost_staleness == 0);

    let timer = Timer::start();
    let stop = AtomicU8::new(STOP_NONE);
    let engine_done = AtomicBool::new(false);
    let inflight = AtomicUsize::new(0);
    let total_updates = AtomicU64::new(0);
    // Resident-shard mode: this process hosts exactly one shard of the
    // k-way partition — every worker thread serves it, peers live in
    // other processes behind the transport's rendezvous sockets.
    let resident = config.resident_shard;
    debug_assert!(resident.map_or(true, |r| r < k), "resident shard out of range");
    // Resident row write-back: in one address space ghost vertices' rows
    // ARE the shared masters, but a resident process only has its
    // partition-time snapshot of them — after a pull, copy the replica
    // back into the row the update function reads. Needs the neighbor
    // write locks of the Full model to overwrite rows safely.
    let sync_rows =
        resident.is_some() && config.model == crate::consistency::ConsistencyModel::Full;
    let per_shard = match resident {
        Some(_) => config.workers.max(1),
        None => (config.workers / k).max(1),
    };
    let workers = match resident {
        Some(_) => per_shard,
        None => per_shard * k,
    };
    let per_worker: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
    let per_conflicts: Vec<AtomicU64> =
        (0..workers).map(|_| AtomicU64::new(0)).collect();
    let per_deferrals: Vec<AtomicU64> =
        (0..workers).map(|_| AtomicU64::new(0)).collect();
    let total_retries = AtomicU64::new(0);
    let total_steals = AtomicU64::new(0);
    let total_escalations = AtomicU64::new(0);
    let total_affinity = AtomicU64::new(0);
    let total_ghost_syncs = AtomicU64::new(0);
    let total_boundary = AtomicU64::new(0);
    let total_handoffs = AtomicU64::new(0);
    let total_stalls = AtomicU64::new(0);
    let total_deltas = AtomicU64::new(0);
    let total_coalesced = AtomicU64::new(0);
    let total_bytes = AtomicU64::new(0);
    let total_pulls = AtomicU64::new(0);
    let total_pulls_served = AtomicU64::new(0);
    let total_max_lag = AtomicU64::new(0);
    let total_auto_flips = AtomicU64::new(0);
    let total_pull_retries = AtomicU64::new(0);
    let total_pull_timeouts = AtomicU64::new(0);
    let syncs_run = AtomicU64::new(0);
    // Snapshot protocol state: the highest epoch announced to the run
    // (bumped every `snapshot_every` global updates), the highest epoch
    // each shard has captured (the fetch_max race electing one capturer
    // per shard per epoch), and the part-assembly store.
    let epoch_announced = AtomicU64::new(0);
    let shard_epoch: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
    // A resident process can never assemble the other shards' parts, so it
    // skips the in-process store and writes its own part file per epoch
    // (`snapshot-epoch-<e>-shard-<r>.bin`); recovery reassembles the
    // newest epoch with all k parts present via `latest_complete_parts`.
    let snap_store = match resident {
        Some(_) => None,
        None => snap.map(|ctl| ctl.store(k)),
    };
    // Per-worker retry deques (deferred tasks, always shard-local) and
    // per-shard overflow injectors.
    let retry: Vec<WorkStealingDeque<Task>> =
        (0..workers).map(|_| WorkStealingDeque::new(LOCAL_DEQUE_CAP)).collect();
    // Ring capacity from config (default 4096 per the BENCH_sched cap
    // sweep); the injector's overflow list still absorbs anything past
    // it, so small graphs only pay the slot allocation.
    let overflows: Vec<Injector<Task>> =
        (0..k).map(|_| Injector::new(config.injector_capacity)).collect();
    // Cross-shard handoff rings: tasks popped by the wrong shard's
    // worker ride these to the owner shard (the emulated network hop).
    let rings: Vec<Injector<Task>> =
        (0..k).map(|_| Injector::new(config.injector_capacity)).collect();
    let pending_retries = AtomicUsize::new(0);
    let defer_age: Vec<AtomicU32> =
        (0..graph.num_vertices()).map(|_| AtomicU32::new(0)).collect();
    let workers_remaining = AtomicUsize::new(workers);
    // Worker-core pinning (opt-in): shard `s`'s worker set maps onto the
    // contiguous core block starting at `s * per_shard`, wrapping at the
    // machine's core count — the owner-affinity layout, so a shard's
    // workers share cache with each other (and with their block of vertex
    // data) instead of migrating.
    let total_pinned = AtomicU64::new(0);
    let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // The backend's adaptive drain-tick bounds (satellite of the wire
    // fast path): cheap-drain backends advertise tight bounds, so the
    // clamp below keeps them from inheriting socket-era backoff.
    let (tick_min, tick_max) = transport.drain_tick_bounds();
    let tick_start = DRAIN_TICK_START.clamp(tick_min, tick_max);
    // Telemetry: one ring per worker plus the "engine" control track the
    // main thread binds during the final transport drain (so post-join
    // wire applies are still recorded).
    let tel = config.telemetry.as_ref().map(|cfg| {
        let mut labels: Vec<String> = (0..workers)
            .map(|w| {
                format!(
                    "shard{}-worker{}",
                    resident.unwrap_or(w / per_shard),
                    w % per_shard
                )
            })
            .collect();
        labels.push("engine".to_string());
        Telemetry::new(cfg.clone(), labels)
    });

    // Owner-side master-row reader for the transport's pull service
    // (resident mode): freezes one owned row under its read lock — the
    // same one-lock-at-a-time discipline as `capture_shard_part`, so the
    // service thread can never deadlock against parked split
    // acquisitions — and hands the borrow to the service's encode
    // callback. Built before the thread scope so the scoped service
    // thread's borrow outlives the scope.
    let locks_ref = &locks;
    let master_serve = move |v: crate::graph::VertexId,
                             sink: &mut dyn FnMut(&V, u64)| {
        let _guard = locks_ref.read(v);
        let version = sharded.master_version(v);
        // Safety: the held read lock excludes the owner's write path, so
        // the master row is stable while the callback encodes it.
        let data = unsafe { graph.vertex_data_unchecked(v) };
        sink(data, version);
    };

    std::thread::scope(|s| {
        // Resident mode: answer peers' staleness pulls from this owner's
        // address space for the whole run (no-op on in-process backends).
        transport.serve_pulls(s, &master_serve, &engine_done);
        let has_periodic = syncs.iter().any(|op| op.interval.is_some());
        if has_periodic {
            let engine_done = &engine_done;
            let syncs_run = &syncs_run;
            let locks = &locks;
            s.spawn(move || {
                let mut last_run: Vec<Timer> =
                    syncs.iter().map(|_| Timer::start()).collect();
                while !engine_done.load(Ordering::Acquire) {
                    for (i, op) in syncs.iter().enumerate() {
                        let Some(interval) = op.interval else { continue };
                        if last_run[i].elapsed() >= interval {
                            ThreadedEngine::locked_sync(graph, locks, op, sdt);
                            syncs_run.fetch_add(1, Ordering::Relaxed);
                            last_run[i] = Timer::start();
                        }
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            });
        }

        // Sampler thread: collapses the live ring counters into the metric
        // time series until the last worker exits.
        if let Some(t) = &tel {
            let engine_done = &engine_done;
            let pending_retries = &pending_retries;
            s.spawn(move || {
                let queue_depth = || scheduler.approx_len() as u64;
                let retry_depth = || pending_retries.load(Ordering::Acquire) as u64;
                let progress_fn = config.progress_metric.clone();
                let progress = progress_fn.as_ref().map(|f| move || f(sdt));
                let sources = SampleSources {
                    queue_depth: &queue_depth,
                    retry_depth: &retry_depth,
                    progress: progress.as_ref().map(|f| f as &(dyn Fn() -> f64 + Sync)),
                };
                t.sample_loop(engine_done, &sources);
            });
        }

        for w in 0..workers {
            let my_shard = resident.unwrap_or(w / per_shard);
            let stop = &stop;
            let inflight = &inflight;
            let total_updates = &total_updates;
            let per_worker = &per_worker;
            let per_conflicts = &per_conflicts;
            let per_deferrals = &per_deferrals;
            let total_retries = &total_retries;
            let total_steals = &total_steals;
            let total_escalations = &total_escalations;
            let total_affinity = &total_affinity;
            let total_ghost_syncs = &total_ghost_syncs;
            let total_boundary = &total_boundary;
            let total_handoffs = &total_handoffs;
            let total_stalls = &total_stalls;
            let total_deltas = &total_deltas;
            let total_coalesced = &total_coalesced;
            let total_bytes = &total_bytes;
            let total_pulls = &total_pulls;
            let total_pulls_served = &total_pulls_served;
            let total_max_lag = &total_max_lag;
            let total_auto_flips = &total_auto_flips;
            let total_pull_retries = &total_pull_retries;
            let total_pull_timeouts = &total_pull_timeouts;
            let epoch_announced = &epoch_announced;
            let shard_epoch = &shard_epoch;
            let snap_store = &snap_store;
            let retry = &retry;
            let overflows = &overflows;
            let rings = &rings;
            let pending_retries = &pending_retries;
            let defer_age = &defer_age;
            let workers_remaining = &workers_remaining;
            let engine_done = &engine_done;
            let locks = &locks;
            let transport = transport;
            let sharded = sharded;
            let tel = &tel;
            let total_pinned = &total_pinned;
            s.spawn(move || {
                let _tel_bind = tel.as_ref().map(|t| t.bind_worker(w));
                // Resident processes offset into the machine's core map by
                // their shard index so k sibling processes tile the cores
                // instead of all pinning to the same leading block.
                let core = resident.map_or(w, |r| r * per_shard + w) % ncores;
                if config.pin_workers && pin_worker_to_core(core) {
                    total_pinned.fetch_add(1, Ordering::Relaxed);
                }
                let mut local_updates: u64 = 0;
                let mut conflicts: u64 = 0;
                let mut deferrals: u64 = 0;
                let mut retries: u64 = 0;
                let mut steals: u64 = 0;
                let mut escalations: u64 = 0;
                let mut affinity: u64 = 0;
                let mut ghost_syncs: u64 = 0;
                let mut boundary_updates: u64 = 0;
                let mut handoffs: u64 = 0;
                let mut stalls: u64 = 0;
                let mut deltas_sent: u64 = 0;
                let mut deltas_coalesced: u64 = 0;
                let mut bytes_shipped: u64 = 0;
                let mut staleness_pulls: u64 = 0;
                let mut pulls_served: u64 = 0;
                let mut max_lag: u64 = 0;
                let mut pull_retries: u64 = 0;
                let mut pull_timeouts: u64 = 0;
                // Highest snapshot epoch this worker has adopted.
                let mut my_snap_epoch: u64 = 0;
                // Adaptive drain tick (worker-local, tuned on queued bytes).
                let mut drain_tick: u64 = tick_start;
                let mut since_drain: u64 = 0;
                let mut idle_spins: u32 = 0;
                // Interior-path adaptive ladder (worker-local).
                let mut attempts: u32 = START_ATTEMPTS;
                let mut window_tasks: u32 = 0;
                let mut window_deferrals: u32 = 0;
                let mut skip_local_once = false;
                // Steal-policy auto-select (worker-local).
                let mut pops: u64 = 0;
                let mut use_steal_half = config.steal_half;
                let mut auto_flips: u64 = 0;
                // The one parked split acquisition this worker may hold.
                let mut pending: Option<PendingAcquire<'_>> = None;
                // Per-worker delta batcher: the ghost-sync window.
                let mut batcher: DeltaBatcher<V> = DeltaBatcher::new(config.ghost_batch);
                let mut ctx = UpdateContext::new(sdt, w);
                loop {
                    if stop.load(Ordering::Acquire) != STOP_NONE {
                        break;
                    }
                    // Fault-plan abort: once the global update count
                    // passes the threshold, the configured shard's
                    // workers stop dead — no final window flush, so their
                    // batched deltas are lost exactly as a crashed
                    // process would lose them.
                    if let Some(plan) = config.abort_plan {
                        if plan.shard == my_shard
                            && total_updates.load(Ordering::Relaxed) >= plan.after_updates
                        {
                            stop.store(STOP_ABORT, Ordering::Release);
                            break;
                        }
                    }
                    // Chandy–Lamport marker step: adopting a newly
                    // announced snapshot epoch first clears this worker's
                    // lanes (flush the outgoing window, drain the shard's
                    // inbox — the lane-clearing a marker frame would
                    // force), then one worker per shard (the fetch_max
                    // winner) freezes the shard's owned master rows.
                    // Deferred while a split acquisition is parked: the
                    // capturer takes read locks, and a worker holding
                    // remote halves must never block on locks.
                    if let Some(ctl) = snap {
                        let e = epoch_announced.load(Ordering::Acquire);
                        if e > my_snap_epoch && pending.is_none() {
                            my_snap_epoch = e;
                            telemetry::instant(
                                EventKind::SnapshotAdopt,
                                e,
                                my_shard as u64,
                            );
                            flush_window(
                                &mut batcher,
                                my_shard,
                                transport,
                                &mut deltas_sent,
                                &mut ghost_syncs,
                                &mut bytes_shipped,
                            );
                            ghost_syncs += transport.drain(my_shard).applied;
                            if shard_epoch[my_shard].fetch_max(e, Ordering::AcqRel) < e {
                                let cap = telemetry::span_start();
                                let (frames, rows) =
                                    capture_shard_part(graph, sharded, locks, my_shard, ctl);
                                telemetry::span_end(
                                    EventKind::SnapshotCapture,
                                    cap,
                                    e,
                                    rows,
                                );
                                match snap_store.as_ref() {
                                    // In-process: hand the part to the
                                    // epoch-assembly store shared by all
                                    // k shards.
                                    Some(store) => {
                                        store.add_part(e, my_shard, frames, rows);
                                    }
                                    // Resident: peers are other processes
                                    // — persist this shard's part file
                                    // directly and let recovery reassemble
                                    // complete epochs from the directory.
                                    None => write_resident_part(
                                        ctl, e, my_shard, frames, rows,
                                    ),
                                }
                            }
                        }
                    }
                    let mut run_now: Option<(Task, Scope<'_, V, E>)> = None;
                    let mut run_from_retry = false;

                    // Pipelined completion: retry the parked split's
                    // local half before anything else (its remote locks
                    // are blocking other shards' progress).
                    if let Some(PendingAcquire { task, split, attempts: tries }) =
                        pending.take()
                    {
                        match split.try_complete(graph.lock_neighbors(task.vertex)) {
                            Ok(guard) => {
                                run_now = Some((
                                    task,
                                    Scope::from_guard(
                                        graph,
                                        task.vertex,
                                        config.model,
                                        guard,
                                    ),
                                ));
                                // a stalled dispatch is not a clean
                                // affinity hit
                                run_from_retry = true;
                            }
                            Err((split, _)) => {
                                conflicts += 1;
                                if tries + 1 >= PENDING_ATTEMPTS {
                                    // Give up the pipeline slot: release
                                    // the remote half, defer the task.
                                    drop(split);
                                    deferrals += 1;
                                    telemetry::instant(
                                        EventKind::ScopeDefer,
                                        task.vertex as u64,
                                        0,
                                    );
                                    defer_age[task.vertex as usize]
                                        .fetch_add(1, Ordering::Relaxed);
                                    pending_retries.fetch_add(1, Ordering::AcqRel);
                                    overflows[my_shard].push(task);
                                } else {
                                    pending = Some(PendingAcquire {
                                        task,
                                        split,
                                        attempts: tries + 1,
                                    });
                                }
                            }
                        }
                    }

                    if run_now.is_none() {
                        // Task sources: own retry deque (LIFO), the
                        // shard's handoff ring (already in flight),
                        // the scheduler, then shard-local stealing.
                        let mut task: Option<Task> = None;
                        let mut from_retry = false;
                        if !skip_local_once {
                            if let Some(t) = retry[w].pop() {
                                task = Some(t);
                                from_retry = true;
                            }
                        }
                        if task.is_none() {
                            task = rings[my_shard].pop();
                        }
                        if task.is_none() {
                            // Optimistic in-flight count before the pop
                            // (same drain-race discipline as the
                            // threaded engine).
                            inflight.fetch_add(1, Ordering::AcqRel);
                            match scheduler.next_task(w) {
                                Some(t) => task = Some(t),
                                None => {
                                    inflight.fetch_sub(1, Ordering::AcqRel);
                                }
                            }
                        }
                        if task.is_none() && skip_local_once {
                            if let Some(t) = retry[w].pop() {
                                task = Some(t);
                                from_retry = true;
                            }
                        }
                        if task.is_none() && pending_retries.load(Ordering::Acquire) > 0
                        {
                            if let Some(t) = overflows[my_shard].pop() {
                                task = Some(t);
                                from_retry = true;
                            } else {
                                // First worker index of this worker's own
                                // group — NOT `my_shard * per_shard`: a
                                // resident process numbers its workers
                                // 0..per_shard while serving shard r.
                                let base = (w / per_shard) * per_shard;
                                for i in 1..per_shard {
                                    let peer = base + (w - base + i) % per_shard;
                                    let got = if use_steal_half {
                                        let (first, moved) = retry[peer].steal_half(
                                            STEAL_HALF_MAX,
                                            |t| {
                                                if let Err(t) = retry[w].push(t) {
                                                    overflows[my_shard].push(t);
                                                }
                                            },
                                        );
                                        steals += moved as u64;
                                        first
                                    } else {
                                        retry[peer].steal()
                                    };
                                    if let Some(t) = got {
                                        steals += 1;
                                        task = Some(t);
                                        from_retry = true;
                                        break;
                                    }
                                }
                            }
                        }
                        skip_local_once = false;
                        let Some(task) = task else {
                            // Going idle closes the sync window: flush the
                            // batcher and apply whatever peers have queued
                            // toward this shard (once per idle streak).
                            if idle_spins == 0 {
                                flush_window(
                                    &mut batcher,
                                    my_shard,
                                    transport,
                                    &mut deltas_sent,
                                    &mut ghost_syncs,
                                    &mut bytes_shipped,
                                );
                                ghost_syncs += transport.drain(my_shard).applied;
                            }
                            if inflight.load(Ordering::Acquire) == 0
                                && scheduler.is_done()
                            {
                                break;
                            }
                            idle_spins += 1;
                            if idle_spins < 64 {
                                std::hint::spin_loop();
                            } else if idle_spins < 256 {
                                std::thread::yield_now();
                            } else {
                                std::thread::sleep(Duration::from_micros(50));
                            }
                            continue;
                        };
                        idle_spins = 0;
                        pops += 1;
                        if !use_steal_half
                            && should_auto_steal_half(pops, steals, config.steal_half_auto)
                        {
                            use_steal_half = true;
                            auto_flips += 1;
                        }
                        if from_retry {
                            retries += 1;
                            pending_retries.fetch_sub(1, Ordering::AcqRel);
                        }

                        // Cross-shard handoff: not ours — forward to the
                        // owner shard's ring (the task stays in flight).
                        // A handoff is a shard boundary crossing, so it
                        // also closes the sync window: the peer may be
                        // about to read what we batched.
                        let owner_shard = sharded.owner_of(task.vertex);
                        if owner_shard != my_shard {
                            handoffs += 1;
                            telemetry::instant(
                                EventKind::Handoff,
                                task.vertex as u64,
                                owner_shard as u64,
                            );
                            flush_window(
                                &mut batcher,
                                my_shard,
                                transport,
                                &mut deltas_sent,
                                &mut ghost_syncs,
                                &mut bytes_shipped,
                            );
                            if resident.is_none() {
                                rings[owner_shard].push(task);
                            } else {
                                // Resident mode ships no tasks between
                                // processes: each process seeds and
                                // re-schedules only its owned vertices, so
                                // a cross-shard spawn (an update poking a
                                // remote neighbor) is dropped here — the
                                // owner's own schedule covers that vertex.
                                // Retire it like an executed task so the
                                // in-flight count and the scheduler's
                                // termination check stay balanced.
                                scheduler.task_done(task, w);
                                inflight.fetch_sub(1, Ordering::AcqRel);
                            }
                            continue;
                        }

                        let vidx = task.vertex as usize;
                        let age = defer_age[vidx].load(Ordering::Relaxed);
                        if age >= config.escalate_after {
                            // Fairness escalation is a *blocking*
                            // acquisition — never enter it while holding
                            // a pending split's remote locks (that would
                            // reintroduce hold-and-wait): abandon the
                            // pending first.
                            if let Some(PendingAcquire { task: ptask, split, .. }) =
                                pending.take()
                            {
                                drop(split);
                                deferrals += 1;
                                telemetry::instant(
                                    EventKind::ScopeDefer,
                                    ptask.vertex as u64,
                                    0,
                                );
                                defer_age[ptask.vertex as usize]
                                    .fetch_add(1, Ordering::Relaxed);
                                pending_retries.fetch_add(1, Ordering::AcqRel);
                                overflows[my_shard].push(ptask);
                            }
                            escalations += 1;
                            telemetry::instant(
                                EventKind::ScopeEscalate,
                                task.vertex as u64,
                                age as u64,
                            );
                            run_now = Some((
                                task,
                                Scope::lock(graph, locks, task.vertex, config.model),
                            ));
                            run_from_retry = from_retry;
                        } else if pending.is_none()
                            && config.model.excludes_neighbors()
                            && sharded.is_boundary(task.vertex)
                        {
                            // Pipelined split acquisition: request the
                            // remote half first.
                            match locks.try_lock_split(
                                task.vertex,
                                graph.lock_neighbors(task.vertex),
                                config.model,
                                |u| sharded.owner_of(u) != my_shard,
                            ) {
                                Ok(split) => {
                                    match split.try_complete(
                                        graph.lock_neighbors(task.vertex),
                                    ) {
                                        Ok(guard) => {
                                            run_now = Some((
                                                task,
                                                Scope::from_guard(
                                                    graph,
                                                    task.vertex,
                                                    config.model,
                                                    guard,
                                                ),
                                            ));
                                            run_from_retry = from_retry;
                                        }
                                        Err((split, _)) => {
                                            // Remote half granted, local
                                            // busy: park it and keep
                                            // working.
                                            conflicts += 1;
                                            stalls += 1;
                                            telemetry::instant(
                                                EventKind::SplitStall,
                                                task.vertex as u64,
                                                my_shard as u64,
                                            );
                                            pending = Some(PendingAcquire {
                                                task,
                                                split,
                                                attempts: 0,
                                            });
                                            continue;
                                        }
                                    }
                                }
                                Err(_) => {
                                    // Remote conflict: nothing held —
                                    // fail fast to a deferral.
                                    conflicts += 1;
                                    deferrals += 1;
                                    telemetry::instant(
                                        EventKind::ScopeDefer,
                                        task.vertex as u64,
                                        age as u64 + 1,
                                    );
                                    defer_age[vidx].fetch_add(1, Ordering::Relaxed);
                                    pending_retries.fetch_add(1, Ordering::AcqRel);
                                    if from_retry {
                                        overflows[my_shard].push(task);
                                        skip_local_once = true;
                                        std::thread::yield_now();
                                    } else if let Err(t) = retry[w].push(task) {
                                        overflows[my_shard].push(t);
                                    }
                                    continue;
                                }
                            }
                        } else {
                            // Interior path: the threaded engine's
                            // adaptive non-blocking ladder. The contend
                            // span clock starts at the first failed
                            // attempt — clean acquires read no clock.
                            let mut scope = None;
                            let mut contend = telemetry::SPAN_OFF;
                            for attempt in 0..attempts {
                                match Scope::try_lock(
                                    graph,
                                    locks,
                                    task.vertex,
                                    config.model,
                                ) {
                                    Ok(sc) => {
                                        scope = Some(sc);
                                        break;
                                    }
                                    Err(_) => {
                                        conflicts += 1;
                                        if contend == telemetry::SPAN_OFF {
                                            contend = telemetry::span_start();
                                        }
                                        for _ in 0..(16u32 << attempt) {
                                            std::hint::spin_loop();
                                        }
                                    }
                                }
                            }
                            telemetry::span_end(
                                EventKind::ScopeContend,
                                contend,
                                task.vertex as u64,
                                scope.is_some() as u64,
                            );
                            window_tasks += 1;
                            let Some(scope) = scope else {
                                deferrals += 1;
                                window_deferrals += 1;
                                telemetry::instant(
                                    EventKind::ScopeDefer,
                                    task.vertex as u64,
                                    age as u64 + 1,
                                );
                                defer_age[vidx].fetch_add(1, Ordering::Relaxed);
                                pending_retries.fetch_add(1, Ordering::AcqRel);
                                if from_retry {
                                    overflows[my_shard].push(task);
                                    skip_local_once = true;
                                    std::thread::yield_now();
                                } else if let Err(t) = retry[w].push(task) {
                                    overflows[my_shard].push(t);
                                }
                                tune_attempts(
                                    &mut attempts,
                                    &mut window_tasks,
                                    &mut window_deferrals,
                                );
                                continue;
                            };
                            tune_attempts(
                                &mut attempts,
                                &mut window_tasks,
                                &mut window_deferrals,
                            );
                            run_now = Some((task, scope));
                            run_from_retry = from_retry;
                        }
                    }

                    let Some((task, mut scope)) = run_now else { continue };
                    let vidx = task.vertex as usize;
                    if defer_age[vidx].load(Ordering::Relaxed) != 0 {
                        defer_age[vidx].store(0, Ordering::Relaxed);
                    }
                    if !run_from_retry && scheduler.owner_of(task.vertex) == Some(w) {
                        affinity += 1;
                    }
                    // Bounded-staleness admission: with the scope's
                    // neighbor locks held, pull any ghost replica this
                    // update would read that lags past the bound.
                    if k > 1
                        && staleness_scan
                        && config.model.excludes_neighbors()
                        && sharded.is_boundary(task.vertex)
                    {
                        let refreshed = scope.refresh_stale_ghosts(
                            sharded,
                            my_shard,
                            config.ghost_staleness,
                            config.pull_retry_limit,
                            transport,
                            sync_rows,
                        );
                        staleness_pulls += refreshed.pulls;
                        pulls_served += refreshed.served;
                        bytes_shipped += refreshed.bytes;
                        pull_retries += refreshed.retries;
                        pull_timeouts += refreshed.timeouts;
                        if refreshed.max_lag > max_lag {
                            max_lag = refreshed.max_lag;
                        }
                    }
                    ctx.reset(w, task.priority);
                    let exec = telemetry::span_start();
                    fns[task.func as usize].update(&mut scope, &mut ctx);
                    // Ghost propagation while the center write lock is
                    // still held: bump the master version, record the
                    // versioned delta (the batcher copies into a reused
                    // slot under the lock), and let the batcher decide
                    // when it leaves through the transport.
                    if k > 1 && sharded.is_boundary(task.vertex) {
                        boundary_updates += 1;
                        let version = sharded.bump_master(task.vertex);
                        if batcher.record(task.vertex, version, scope.vertex()) {
                            deltas_coalesced += 1;
                        }
                        if batcher.should_flush() {
                            flush_window(
                                &mut batcher,
                                my_shard,
                                transport,
                                &mut deltas_sent,
                                &mut ghost_syncs,
                                &mut bytes_shipped,
                            );
                        }
                    }
                    drop(scope);
                    telemetry::span_end(
                        EventKind::TaskExec,
                        exec,
                        task.vertex as u64,
                        task.func as u64,
                    );
                    ctx.drain_spawned(|t| scheduler.add_task(t));
                    scheduler.task_done(task, w);
                    inflight.fetch_sub(1, Ordering::AcqRel);

                    local_updates += 1;
                    // Adaptive periodic drain: consume deltas queued toward
                    // this shard even when the worker never idles, so a
                    // queueing backend's buffers stay bounded under
                    // sustained load. The tick adapts to the queued byte
                    // depth within the backend's advertised
                    // `drain_tick_bounds` — empty checks back it off toward
                    // `tick_max` (apply-at-send backends decay to a
                    // cheap no-op), a backlog past DRAIN_HIGH_BYTES
                    // tightens it to `tick_min`. Cheap-drain backends like
                    // shm advertise tight bounds so they are never stuck
                    // in socket-era backoff.
                    if k > 1 {
                        since_drain += 1;
                        if since_drain >= drain_tick {
                            since_drain = 0;
                            let queued = transport.queued_bytes(my_shard);
                            if queued == 0 {
                                drain_tick = (drain_tick * 2).min(tick_max);
                            } else {
                                ghost_syncs += transport.drain(my_shard).applied;
                                drain_tick = if queued >= DRAIN_HIGH_BYTES {
                                    tick_min
                                } else {
                                    drain_tick.min(tick_start)
                                };
                            }
                        }
                    }
                    let global = total_updates.fetch_add(1, Ordering::Relaxed) + 1;
                    // Snapshot epoch announcement: every `snapshot_every`
                    // global updates the due epoch advances; workers pick
                    // it up at their next loop top (the marker step).
                    if let Some(ctl) = snap {
                        let due = global / ctl.every;
                        if due > 0 {
                            epoch_announced.fetch_max(due, Ordering::AcqRel);
                        }
                    }
                    if let Some(max) = config.max_updates {
                        if global >= max {
                            stop.store(STOP_LIMIT, Ordering::Release);
                            break;
                        }
                    }
                    if local_updates % config.term_check_every == 0 {
                        for term in terminators {
                            if term(sdt) {
                                stop.store(STOP_TERM_FN, Ordering::Release);
                                break;
                            }
                        }
                    }
                }
                // Worker exit closes its sync window for good — unless
                // this worker belongs to the aborted shard: a crashed
                // process loses its batched deltas, so the simulation
                // drops them too.
                let crashed = stop.load(Ordering::Acquire) == STOP_ABORT
                    && matches!(config.abort_plan, Some(p) if p.shard == my_shard);
                if !crashed {
                    flush_window(
                        &mut batcher,
                        my_shard,
                        transport,
                        &mut deltas_sent,
                        &mut ghost_syncs,
                        &mut bytes_shipped,
                    );
                }
                per_worker[w].store(local_updates, Ordering::Release);
                per_conflicts[w].store(conflicts, Ordering::Release);
                per_deferrals[w].store(deferrals, Ordering::Release);
                total_retries.fetch_add(retries, Ordering::AcqRel);
                total_steals.fetch_add(steals, Ordering::AcqRel);
                total_escalations.fetch_add(escalations, Ordering::AcqRel);
                total_affinity.fetch_add(affinity, Ordering::AcqRel);
                total_ghost_syncs.fetch_add(ghost_syncs, Ordering::AcqRel);
                total_boundary.fetch_add(boundary_updates, Ordering::AcqRel);
                total_handoffs.fetch_add(handoffs, Ordering::AcqRel);
                total_stalls.fetch_add(stalls, Ordering::AcqRel);
                total_deltas.fetch_add(deltas_sent, Ordering::AcqRel);
                total_coalesced.fetch_add(deltas_coalesced, Ordering::AcqRel);
                total_bytes.fetch_add(bytes_shipped, Ordering::AcqRel);
                total_pulls.fetch_add(staleness_pulls, Ordering::AcqRel);
                total_pulls_served.fetch_add(pulls_served, Ordering::AcqRel);
                total_max_lag.fetch_max(max_lag, Ordering::AcqRel);
                total_auto_flips.fetch_add(auto_flips, Ordering::AcqRel);
                total_pull_retries.fetch_add(pull_retries, Ordering::AcqRel);
                total_pull_timeouts.fetch_add(pull_timeouts, Ordering::AcqRel);
                if workers_remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    engine_done.store(true, Ordering::Release);
                }
            });
        }
    });
    engine_done.store(true, Ordering::Release);

    // Final transport drain: every queued delta lands before the caller
    // regains exclusive access to the graph (no-op for direct backends).
    // `finalize` first blocks until asynchronous backends (reader threads,
    // kernel buffers) have made every sent byte drainable. The main thread
    // binds the "engine" control track so the wire applies recorded here —
    // after every worker ring went quiet — are not lost.
    let engine_bind = tel.as_ref().map(|t| t.bind_worker(workers));
    transport.finalize();
    let mut drained = 0u64;
    match resident {
        // A resident process only ever drains its own shard's inbox —
        // the other shards' inboxes belong to other processes.
        Some(r) => drained += transport.drain(r).applied,
        None => {
            for shard in 0..k {
                drained += transport.drain(shard).applied;
            }
        }
    }
    total_ghost_syncs.fetch_add(drained, Ordering::AcqRel);
    drop(engine_bind);

    for op in syncs {
        ThreadedEngine::locked_sync(graph, &locks, op, sdt);
        syncs_run.fetch_add(1, Ordering::Relaxed);
    }

    let stop_reason = match stop.load(Ordering::Acquire) {
        STOP_TERM_FN => StopReason::TerminationFn,
        STOP_LIMIT => StopReason::UpdateLimit,
        STOP_ABORT => StopReason::ShardAborted,
        _ => StopReason::SchedulerEmpty,
    };
    // Incomplete epochs (interrupted by the abort or run end) are dropped
    // here — only fully assembled snapshots are usable recovery points.
    let snapshots = match snap_store {
        Some(store) => store.into_completed(),
        None => Vec::new(),
    };
    let per_worker_conflicts: Vec<u64> =
        per_conflicts.iter().map(|c| c.load(Ordering::Acquire)).collect();
    let per_worker_deferrals: Vec<u64> =
        per_deferrals.iter().map(|c| c.load(Ordering::Acquire)).collect();
    RunReport {
        updates: total_updates.load(Ordering::Relaxed),
        wall_secs: timer.elapsed_secs(),
        stop: stop_reason,
        per_worker: per_worker.iter().map(|c| c.load(Ordering::Acquire)).collect(),
        syncs_run: syncs_run.load(Ordering::Relaxed),
        contention: ContentionStats {
            conflicts: per_worker_conflicts.iter().sum(),
            deferrals: per_worker_deferrals.iter().sum(),
            retries: total_retries.load(Ordering::Acquire),
            steals: total_steals.load(Ordering::Acquire),
            escalations: total_escalations.load(Ordering::Acquire),
            affinity_hits: total_affinity.load(Ordering::Acquire),
            has_owner_map: scheduler.owner_of(0).is_some(),
            shards: k,
            ghost_syncs: total_ghost_syncs.load(Ordering::Acquire),
            boundary_updates: total_boundary.load(Ordering::Acquire),
            handoffs: total_handoffs.load(Ordering::Acquire),
            pipelined_stalls: total_stalls.load(Ordering::Acquire),
            deltas_sent: total_deltas.load(Ordering::Acquire),
            deltas_coalesced: total_coalesced.load(Ordering::Acquire),
            bytes_shipped: total_bytes.load(Ordering::Acquire),
            staleness_pulls: total_pulls.load(Ordering::Acquire),
            pulls_served: total_pulls_served.load(Ordering::Acquire),
            backpressure_stalls: transport.backpressure_stalls(),
            max_ghost_staleness: total_max_lag.load(Ordering::Acquire),
            auto_steal_half_flips: total_auto_flips.load(Ordering::Acquire),
            faults_injected: transport.faults_injected(),
            pull_retries: total_pull_retries.load(Ordering::Acquire),
            pull_timeouts: total_pull_timeouts.load(Ordering::Acquire)
                + transport.pull_timeouts(),
            reconnect_backoffs: transport.reconnect_backoffs(),
            snapshots_taken: snapshots.len() as u64,
            pinned_workers: total_pinned.load(Ordering::Acquire),
            per_worker_conflicts,
            per_worker_deferrals,
        },
        snapshots,
        telemetry: tel.map(Telemetry::finish),
    }
}

impl<V: Clone + Send + Sync, E: Send + Sync> Engine<V, E> for ShardedEngine {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        self.run(
            graph,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            &program.config,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::graph::GraphBuilder;
    use crate::scheduler::{FifoScheduler, MultiQueueFifo};

    fn ring(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n {
            b.add_undirected(i as u32, ((i + 1) % n) as u32, (), ());
        }
        b.build()
    }

    struct SelfBump {
        rounds: u64,
    }
    impl UpdateFn<u64, ()> for SelfBump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.rounds {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    #[test]
    fn sharded_ring_runs_to_convergence_with_ghost_traffic() {
        let n = 64;
        let mut g = ring(n);
        let sched = MultiQueueFifo::new(n, 4);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 10 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ShardedEngine::new(4).run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(4).with_model(ConsistencyModel::Full),
        );
        assert_eq!(report.stop, StopReason::SchedulerEmpty);
        assert_eq!(report.updates, n as u64 * 10);
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10, "vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.shards, 4);
        // a ring cut 4 ways has 8 boundary vertices, each updated 10 times
        assert_eq!(c.boundary_updates, 80);
        assert_eq!(c.ghost_syncs, 80, "each ring-boundary vertex has 1 replica");
        // default sync window of 1: every boundary update is its own delta
        assert_eq!(c.deltas_sent, 80);
        assert_eq!(c.deltas_coalesced, 0);
        assert_eq!(c.bytes_shipped, 0, "direct backend ships no wire bytes");
        assert_eq!(c.staleness_pulls, 0, "synchronous flush leaves nothing stale");
        assert_eq!(c.max_ghost_staleness, 0);
        assert_eq!(report.per_worker.iter().sum::<u64>(), report.updates);
    }

    /// The channel backend serializes every delta through the codec yet
    /// must converge to the same result with the same delta count.
    #[test]
    fn channel_backend_matches_direct_on_ring() {
        let n = 64;
        let f = SelfBump { rounds: 10 };
        let program = Program::new()
            .update_fn(&f)
            .workers(4)
            .model(ConsistencyModel::Full);
        let mut g = ring(n);
        let sched = MultiQueueFifo::new(n, 4);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let report =
            program.run_on(&ChannelShardedEngine::new(4), &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, n as u64 * 10);
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10, "vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.shards, 4);
        assert_eq!(c.boundary_updates, 80);
        assert_eq!(c.deltas_sent, 80);
        assert!(c.bytes_shipped > 0, "channel backend really ships bytes");
        // every delta is either applied at a drain or superseded by a
        // staleness pull that already carried a newer version
        assert!(c.ghost_syncs <= 80);
        // a serializing backend serves every pull through request/reply
        assert_eq!(c.pulls_served, c.staleness_pulls);
    }

    /// The socket backend moves every delta through real Unix-domain
    /// sockets yet must converge to the same result.
    #[test]
    fn socket_backend_matches_direct_on_ring() {
        let n = 64;
        let f = SelfBump { rounds: 10 };
        let program = Program::new()
            .update_fn(&f)
            .workers(4)
            .model(ConsistencyModel::Full);
        let mut g = ring(n);
        let sched = MultiQueueFifo::new(n, 4);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let report =
            program.run_on(&SocketShardedEngine::new(4), &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, n as u64 * 10);
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10, "vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.shards, 4);
        assert_eq!(c.boundary_updates, 80);
        assert_eq!(c.deltas_sent, 80);
        assert!(c.bytes_shipped > 0, "socket backend really ships bytes");
        assert!(c.ghost_syncs <= 80);
        assert_eq!(c.pulls_served, c.staleness_pulls, "pulls ride the socket");
    }

    #[test]
    fn shm_backend_matches_direct_on_ring() {
        let n = 64;
        let f = SelfBump { rounds: 10 };
        let program = Program::new()
            .update_fn(&f)
            .workers(4)
            .model(ConsistencyModel::Full);
        let mut g = ring(n);
        let sched = MultiQueueFifo::new(n, 4);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let report =
            program.run_on(&ShmShardedEngine::new(4), &mut g, &sched, &Sdt::new());
        assert_eq!(report.updates, n as u64 * 10);
        for v in 0..n as u32 {
            assert_eq!(*g.vertex_data(v), 10, "vertex {v}");
        }
        let c = &report.contention;
        assert_eq!(c.shards, 4);
        assert_eq!(c.boundary_updates, 80);
        assert_eq!(c.deltas_sent, 80);
        assert!(c.bytes_shipped > 0, "shm backend really ships bytes");
        assert!(c.ghost_syncs <= 80);
        assert_eq!(c.pulls_served, c.staleness_pulls, "pulls ride the rings");
    }

    #[test]
    fn one_shard_has_no_ghost_traffic() {
        let n = 32;
        let mut g = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: 5 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ShardedEngine::new(1).run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(2),
        );
        assert_eq!(report.updates, n as u64 * 5);
        let c = &report.contention;
        assert_eq!(c.shards, 1);
        assert_eq!(c.ghost_syncs, 0);
        assert_eq!(c.boundary_updates, 0);
        assert_eq!(c.handoffs, 0);
        assert_eq!(c.pipelined_stalls, 0);
        assert_eq!(c.deltas_sent, 0);
        assert_eq!(c.bytes_shipped, 0);
        assert_eq!(c.staleness_pulls, 0);
    }

    #[test]
    fn update_limit_and_terminators_respected() {
        let n = 16;
        let mut g = ring(n);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let f = SelfBump { rounds: u64::MAX };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let report = ShardedEngine::new(2).run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::default().with_workers(2).with_max_updates(100),
        );
        assert_eq!(report.stop, StopReason::UpdateLimit);
        assert!(report.updates >= 100 && report.updates < 140);
    }
}
