//! The GraphLab **engine**: pulls tasks from the scheduler, acquires each
//! task's scope under the configured consistency model, applies the update
//! function, and feeds spawned tasks back (paper §3.2, §3.5, Fig. 3).
//!
//! Three engines share the same semantics:
//! * [`ThreadedEngine`] — worker threads over shared memory (the paper's
//!   PThreads implementation).
//! * [`ShardedEngine`] — the data graph cut into ghost-replicated shards
//!   ([`crate::graph::ShardedGraph`]), each run by its own worker set, with
//!   pipelined/split lock acquisition for cross-shard scopes — the
//!   Distributed GraphLab Locking-Engine pattern rehearsed over threads.
//! * [`SequentialEngine`] — single-threaded, deterministic, and able to
//!   capture a [task trace](trace::TaskTrace) consumed by the multicore
//!   simulator ([`crate::sim`]) that regenerates the paper's speedup figures.

pub mod process;
pub mod program;
pub mod sequential;
pub mod sharded;
pub mod snapshot;
pub mod threaded;
pub mod trace;

pub use process::{ProcessHarness, ProcessRun, ShardReport};
pub use program::{Engine, Program};
pub use sequential::SequentialEngine;
pub use sharded::{
    ChannelShardedEngine, ShardedEngine, ShmShardedEngine, SocketShardedEngine,
};
pub use snapshot::Snapshot;
pub use threaded::ThreadedEngine;

use crate::consistency::{ConsistencyModel, Scope};
use crate::graph::VertexId;
use crate::scheduler::{FuncId, Task};
use crate::sdt::Sdt;

/// A stateless user-defined update function `D_{S_v} <- f(D_{S_v}, T)`
/// (paper §3.2.1). Implementations receive the locked scope and a context
/// for scheduling further tasks and reading the SDT.
pub trait UpdateFn<V, E>: Send + Sync {
    fn update(&self, scope: &mut Scope<'_, V, E>, ctx: &mut UpdateContext<'_>);

    fn name(&self) -> &'static str {
        "update"
    }
}

/// Blanket impl so plain closures can be used as update functions.
impl<V, E, F> UpdateFn<V, E> for F
where
    F: Fn(&mut Scope<'_, V, E>, &mut UpdateContext<'_>) + Send + Sync,
{
    fn update(&self, scope: &mut Scope<'_, V, E>, ctx: &mut UpdateContext<'_>) {
        self(scope, ctx)
    }
}

/// Per-invocation context handed to update functions: read-only SDT access
/// plus task creation (`AddTask` in the paper's pseudocode).
pub struct UpdateContext<'a> {
    /// The shared data table (read-only by convention; enforced socially —
    /// update functions should only *read*; writes belong to sync Apply).
    pub sdt: &'a Sdt,
    /// Executing worker id (for per-worker RNG streams etc.).
    pub worker: usize,
    /// Priority the current task was scheduled with.
    pub current_priority: f64,
    spawned: Vec<Task>,
}

impl<'a> UpdateContext<'a> {
    pub fn new(sdt: &'a Sdt, worker: usize) -> UpdateContext<'a> {
        UpdateContext { sdt, worker, current_priority: 0.0, spawned: Vec::new() }
    }

    /// Schedule `vertex` for another update (same function, given priority).
    #[inline]
    pub fn add_task(&mut self, vertex: VertexId, priority: f64) {
        self.spawned.push(Task::with_priority(vertex, priority));
    }

    /// Schedule `vertex` for update function `func`.
    #[inline]
    pub fn add_task_func(&mut self, vertex: VertexId, func: FuncId, priority: f64) {
        self.spawned.push(Task::with_func(vertex, func, priority));
    }

    /// Tasks spawned so far (drained by the engine after scope release).
    pub fn take_spawned(&mut self) -> Vec<Task> {
        std::mem::take(&mut self.spawned)
    }

    /// Reuse this context for the next task (keeps the spawned buffer's
    /// allocation — the engine hot path calls this once per update).
    #[inline]
    pub fn reset(&mut self, worker: usize, priority: f64) {
        self.worker = worker;
        self.current_priority = priority;
        self.spawned.clear();
    }

    /// Drain spawned tasks without giving up the buffer.
    #[inline]
    pub fn drain_spawned(&mut self, mut f: impl FnMut(Task)) {
        for t in self.spawned.drain(..) {
            f(t);
        }
    }
}

/// Why an engine run stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Scheduler drained: no tasks remained (paper's first termination mode).
    SchedulerEmpty,
    /// A registered termination function returned true (second mode).
    TerminationFn,
    /// The configured update budget was exhausted.
    UpdateLimit,
    /// A configured [`AbortPlan`] fired: one shard's worker set simulated a
    /// crash (dying with its batched-but-unflushed deltas) and the rest of
    /// the engine shut down cleanly behind it. Recovery restarts from the
    /// latest completed [`Snapshot`] via [`Snapshot::restore_into`].
    ShardAborted,
}

/// A scripted mid-run shard crash for fault-tolerance tests: once the
/// global update count reaches `after_updates`, the workers of `shard`
/// die *without* flushing their delta batchers (simulated data loss on
/// the wire) and every other worker shuts down cleanly. The run reports
/// [`StopReason::ShardAborted`]; all threads still join — the crash is
/// simulated at the protocol level, never by detaching a thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AbortPlan {
    /// Index of the shard whose worker set crashes.
    pub shard: usize,
    /// Global update count at which the crash fires.
    pub after_updates: u64,
}

/// Engine configuration.
pub struct EngineConfig {
    /// Worker thread count (ignored by the sequential engine).
    pub workers: usize,
    /// Consistency model for scope locking.
    pub model: ConsistencyModel,
    /// Hard cap on total updates (safety valve for non-converging runs).
    pub max_updates: Option<u64>,
    /// Check termination functions every N completed updates (per worker).
    pub term_check_every: u64,
    /// Deferral-fairness bound: once a vertex's task has been deferred this
    /// many times without executing, its next dispatch *escalates* to a
    /// blocking scope acquisition so it eventually wins against a saturated
    /// neighborhood (0 = escalate immediately, i.e. a fully blocking
    /// engine).
    pub escalate_after: u32,
    /// Number of data-graph shards for the sharded engine (ghost-replicated
    /// partitions + pipelined cross-shard locking). 0 or 1 = unsharded;
    /// [`Program::run`](program::Program::run) routes to
    /// [`ShardedEngine`] when this exceeds 1.
    pub shards: usize,
    /// Retry-deque steal policy: `false` = steal one task per attempt (the
    /// default), `true` = steal roughly half the victim's deque per attempt
    /// ([`crate::scheduler::WorkStealingDeque::steal_half`]). Enable when a
    /// run's steal counters dominate its retries (skewed loads where
    /// one-at-a-time stealing keeps thieves coming back).
    pub steal_half: bool,
    /// Auto-select threshold for steal-half: once a worker has dispatched
    /// enough tasks, it flips its own steal scans to steal-half mid-run if
    /// its observed steals exceed this fraction of its pops (skew it can
    /// measure itself). `f64::INFINITY` disables the auto-flip; the
    /// explicit [`EngineConfig::steal_half`] override forces half-stealing
    /// from the start. Flips are counted in
    /// [`ContentionStats::auto_steal_half_flips`].
    pub steal_half_auto: f64,
    /// Ghost staleness bound `s` for the sharded engine's bounded-staleness
    /// mode: a scope about to read a ghost replica more than `s` master
    /// versions behind forces a pull-on-demand first. `0` (default)
    /// reproduces the synchronous read semantics of the per-update flush.
    pub ghost_staleness: u64,
    /// Ghost delta-batcher sync window (boundary-update records per flush)
    /// for the sharded engine. `1` (default) flushes synchronously per
    /// boundary update — PR 3 semantics; larger windows coalesce repeated
    /// writes to the same vertex and ship fewer, fatter deltas, with
    /// read freshness guarded by [`EngineConfig::ghost_staleness`].
    pub ghost_batch: usize,
    /// Deterministic fault-injection schedule for the sharded engine's
    /// ghost transport: when set, every backend is wrapped in a
    /// [`crate::transport::FaultInjector`] that drops, duplicates, delays
    /// (reorders) delta frames and severs pull exchanges per the plan's
    /// seeded per-mille rates. `None` (default) = perfect wire.
    pub fault_plan: Option<crate::transport::FaultPlan>,
    /// Consistent-snapshot cadence for the sharded wire engines: capture a
    /// Chandy–Lamport-style snapshot epoch every `n` global updates
    /// (0 = never). Completed snapshots are returned in
    /// [`RunReport::snapshots`]. Only the serializing backends snapshot —
    /// capture needs the [`crate::transport::VertexCodec`] row encoding.
    pub snapshot_every: u64,
    /// When set, each completed snapshot is also written to
    /// `snapshot-epoch-<e>.bin` under this directory
    /// ([`Snapshot::write_file`] format).
    pub snapshot_dir: Option<std::path::PathBuf>,
    /// Scripted mid-run shard crash (fault-tolerance tests). `None`
    /// (default) = no crash.
    pub abort_plan: Option<AbortPlan>,
    /// Bounded retry budget for a stale-ghost pull at scope admission:
    /// after a pull fails to bring a replica inside the staleness bound
    /// (lossy or severed transport), the admitting worker re-pulls with
    /// exponential spin backoff up to this many times before giving up and
    /// admitting the stale read (counted as a
    /// [`ContentionStats::pull_timeouts`]). A dead peer therefore delays
    /// admission, never hangs it.
    pub pull_retry_limit: u32,
    /// Run-time telemetry: when set, the run records per-worker event
    /// rings, samples a metric time series, and (per the config's paths)
    /// exports a Chrome trace and a JSONL metrics stream into
    /// [`RunReport::telemetry`]. `None` (default) = telemetry off, near
    /// zero cost.
    pub telemetry: Option<crate::telemetry::TelemetryConfig>,
    /// App-supplied convergence scalar, probed by the telemetry sampler
    /// once per sampling interval (e.g. a residual norm maintained by a
    /// sync). Only observed when [`EngineConfig::telemetry`] is set.
    pub progress_metric: Option<ProgressFn>,
    /// Lock-free slot count of the per-shard injector rings (overflow and
    /// cross-shard handoff). The `BENCH_sched.json` capacity sweep showed
    /// a 6× throughput win moving 64 → 4096, so 4096 is the default; the
    /// injector's mutex spill list still absorbs anything past the ring, so
    /// small graphs only pay the (bounded) slot allocation.
    pub injector_capacity: usize,
    /// Pin each worker thread to a core (Linux `sched_setaffinity`): shard
    /// `s`'s worker set maps onto a contiguous core block, so a shard's
    /// workers share cache instead of migrating. No-op with a one-time
    /// warning on other platforms. Successful pins are counted in
    /// [`ContentionStats::pinned_workers`].
    pub pin_workers: bool,
    /// Resident-shard mode for true multi-process runs: when set, this
    /// process hosts exactly the named shard of a `shards`-way partition —
    /// `workers` threads all serve that one shard, ghost traffic crosses
    /// the rendezvous sockets of [`crate::transport::SocketTransport`]'s
    /// resident mode, staleness pulls are answered by each owner's
    /// in-process pull service, and cross-shard task spawns are dropped
    /// (every process seeds its own owned vertices). `None` (default) =
    /// all shards in one process. Set by the `graphlab shard` child
    /// entrypoint via [`process::ProcessHarness`]; not useful standalone.
    pub resident_shard: Option<usize>,
    /// Requested process count for a true multi-process deployment: the
    /// number of `graphlab shard` children a
    /// [`process::ProcessHarness::from_config`] fleet launches (each
    /// hosting one shard). `0` (default) = in-process execution.
    /// [`Program::run`](program::Program::run) itself never forks —
    /// update-function closures cannot cross `exec`, so multi-process runs
    /// go through the harness and its preset workloads.
    pub processes: usize,
}

/// The telemetry sampler's convergence-scalar hook: reads the SDT (where
/// syncs publish aggregates) and returns the run's progress measure.
pub type ProgressFn = std::sync::Arc<dyn Fn(&Sdt) -> f64 + Send + Sync>;

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            model: ConsistencyModel::Edge,
            max_updates: None,
            term_check_every: 256,
            escalate_after: 8,
            shards: 0,
            steal_half: false,
            steal_half_auto: 0.25,
            ghost_staleness: 0,
            ghost_batch: 1,
            fault_plan: None,
            snapshot_every: 0,
            snapshot_dir: None,
            abort_plan: None,
            pull_retry_limit: 8,
            telemetry: None,
            progress_metric: None,
            injector_capacity: 4096,
            pin_workers: false,
            resident_shard: None,
            processes: 0,
        }
    }
}

impl EngineConfig {
    pub fn sequential(model: ConsistencyModel) -> EngineConfig {
        EngineConfig { workers: 1, model, ..Default::default() }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_model(mut self, model: ConsistencyModel) -> Self {
        self.model = model;
        self
    }

    pub fn with_max_updates(mut self, max: u64) -> Self {
        self.max_updates = Some(max);
        self
    }

    pub fn with_escalate_after(mut self, deferrals: u32) -> Self {
        self.escalate_after = deferrals;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn with_steal_half(mut self, on: bool) -> Self {
        self.steal_half = on;
        self
    }

    pub fn with_steal_half_auto(mut self, frac: f64) -> Self {
        self.steal_half_auto = frac;
        self
    }

    pub fn with_ghost_staleness(mut self, bound: u64) -> Self {
        self.ghost_staleness = bound;
        self
    }

    pub fn with_ghost_batch(mut self, window: usize) -> Self {
        self.ghost_batch = window;
        self
    }

    pub fn with_fault_plan(mut self, plan: crate::transport::FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    pub fn with_snapshot_every(mut self, updates: u64) -> Self {
        self.snapshot_every = updates;
        self
    }

    pub fn with_snapshot_dir(mut self, dir: std::path::PathBuf) -> Self {
        self.snapshot_dir = Some(dir);
        self
    }

    pub fn with_abort_plan(mut self, plan: AbortPlan) -> Self {
        self.abort_plan = Some(plan);
        self
    }

    pub fn with_pull_retry_limit(mut self, retries: u32) -> Self {
        self.pull_retry_limit = retries;
        self
    }

    pub fn with_telemetry(mut self, cfg: crate::telemetry::TelemetryConfig) -> Self {
        self.telemetry = Some(cfg);
        self
    }

    pub fn with_progress_metric(
        mut self,
        f: impl Fn(&Sdt) -> f64 + Send + Sync + 'static,
    ) -> Self {
        self.progress_metric = Some(std::sync::Arc::new(f));
        self
    }

    pub fn with_injector_capacity(mut self, slots: usize) -> Self {
        self.injector_capacity = slots;
        self
    }

    pub fn with_pin_workers(mut self, on: bool) -> Self {
        self.pin_workers = on;
        self
    }

    pub fn with_resident_shard(mut self, shard: usize) -> Self {
        self.resident_shard = Some(shard);
        self
    }

    pub fn with_processes(mut self, n: usize) -> Self {
        self.processes = n;
        if self.shards <= 1 {
            self.shards = n;
        }
        self
    }
}

/// Termination predicate over the SDT (paper §3.5, second mode).
pub type TerminationFn = Box<dyn Fn(&Sdt) -> bool + Send + Sync>;

/// Scope-lock contention counters from a threaded run. The engine never
/// parks a worker on a scope lock; every failed all-or-nothing try-acquire
/// is a `conflict`, and a task whose adaptive in-place re-attempts all
/// conflict is a `deferral` (pushed to the worker's lock-free retry deque
/// and re-dispatched later). All counters are zero for sequential runs and
/// for uncontended workloads; `steals` is zero for single-worker runs.
#[derive(Debug, Clone, Default)]
pub struct ContentionStats {
    /// Failed scope try-acquires (each costs a rollback, not a park).
    pub conflicts: u64,
    /// Tasks pushed to a per-worker retry deque after exhausting their
    /// adaptive spin re-attempts.
    pub deferrals: u64,
    /// Tasks re-dispatched from a retry deque (own, stolen, or via the
    /// overflow injector).
    pub retries: u64,
    /// Retries stolen from *another* worker's retry deque.
    pub steals: u64,
    /// Tasks whose deferral age crossed [`EngineConfig::escalate_after`]
    /// and were dispatched through a blocking scope acquisition instead of
    /// another try/defer round (the deferral-fairness path).
    pub escalations: u64,
    /// Executed updates whose task was popped from the scheduler by the
    /// worker *owning* its vertex, per the scheduler's own affinity routing
    /// ([`crate::scheduler::Scheduler::owner_of`]). Always zero for
    /// schedulers without owner-affine routing (strict FIFO, splash, set).
    pub affinity_hits: u64,
    /// Did the scheduler advertise an owner-affinity routing map
    /// ([`crate::scheduler::Scheduler::owner_of`])? When false the affinity
    /// counter is structurally zero and reporting it would be meaningless —
    /// [`crate::metrics::run_summary`] hides the affinity line.
    pub has_owner_map: bool,
    /// Data-graph shard count of the engine that produced this report
    /// (0 = a non-sharded engine ran; the ghost/boundary counters below are
    /// then structurally zero and not rendered).
    pub shards: usize,
    /// Owned-vertex writes propagated to remote shards' ghost replicas
    /// (sharded engine; the emulated network flush traffic).
    pub ghost_syncs: u64,
    /// Executed updates whose vertex lies on a shard cut boundary.
    pub boundary_updates: u64,
    /// Tasks popped by a worker of the wrong shard and handed off to the
    /// owner shard's injector ring (sharded engine).
    pub handoffs: u64,
    /// Pipelined split acquisitions that went **pending**: the remote half
    /// was granted but the local half conflicted, so the worker parked the
    /// held remote locks and went on to other work (sharded engine).
    pub pipelined_stalls: u64,
    /// Ghost deltas handed to the transport (post-coalescing; sharded
    /// engine). With the default sync window of 1 this equals
    /// [`ContentionStats::boundary_updates`].
    pub deltas_sent: u64,
    /// Boundary-vertex writes absorbed into an existing batcher slot
    /// instead of becoming their own delta (the coalescing win).
    pub deltas_coalesced: u64,
    /// Serialized bytes enqueued by the transport (zero for the
    /// direct-memory backend, which applies in place).
    pub bytes_shipped: u64,
    /// Pull-on-demand refreshes forced by the bounded-staleness admission
    /// check ([`EngineConfig::ghost_staleness`]): a reader found a ghost
    /// replica lagging past the bound and refreshed it before its update
    /// ran.
    pub staleness_pulls: u64,
    /// Staleness pulls whose request and reply crossed the transport's
    /// byte path (`GhostTransport::pull` request/reply frames). On a
    /// serializing backend this equals [`ContentionStats::staleness_pulls`]
    /// — scope admission never reads peer master data directly; on the
    /// direct backend it is structurally zero (pulls are in-place reads).
    pub pulls_served: u64,
    /// Sends that stalled on a full bounded transport send window (the
    /// socket backend's backpressure; zero for unbounded backends).
    pub backpressure_stalls: u64,
    /// Largest replica staleness (in master versions) any update function
    /// actually observed after the admission check — never exceeds
    /// [`EngineConfig::ghost_staleness`] on Edge/Full-model runs.
    pub max_ghost_staleness: u64,
    /// Workers that auto-flipped their steal scans to steal-half mid-run
    /// (observed steals crossed [`EngineConfig::steal_half_auto`]).
    pub auto_steal_half_flips: u64,
    /// Faults the transport layer injected or absorbed: deltas dropped,
    /// duplicated, or delayed and pull exchanges severed by an active
    /// [`EngineConfig::fault_plan`]. Zero on a perfect wire.
    pub faults_injected: u64,
    /// Stale-ghost pulls re-issued at scope admission because a prior pull
    /// failed to bring the replica inside the staleness bound (lossy or
    /// severed transport). Zero on a perfect wire.
    pub pull_retries: u64,
    /// Pull exchanges that gave up: scope-admission retries that exhausted
    /// [`EngineConfig::pull_retry_limit`], plus socket pull lanes whose
    /// read or write timed out against a dead peer. The admitting worker
    /// proceeds with the stale read instead of hanging.
    pub pull_timeouts: u64,
    /// Exponential-backoff waits spent reconnecting a severed socket delta
    /// connection (one per reconnect attempt; the socket backend's
    /// capped-backoff path).
    pub reconnect_backoffs: u64,
    /// Consistent snapshots completed during the run (every shard
    /// contributed its part for the epoch); the snapshots themselves are
    /// in [`RunReport::snapshots`].
    pub snapshots_taken: u64,
    /// Worker threads successfully pinned to a core via
    /// [`EngineConfig::pin_workers`]. Zero when pinning is off or
    /// unsupported on this platform.
    pub pinned_workers: u64,
    /// Per-worker conflict counts (index = worker id).
    pub per_worker_conflicts: Vec<u64>,
    /// Per-worker deferral counts (index = worker id).
    pub per_worker_deferrals: Vec<u64>,
}

impl ContentionStats {
    /// Conflicts per completed update — the headline contention metric.
    pub fn conflict_rate(&self, updates: u64) -> f64 {
        self.conflicts as f64 / updates.max(1) as f64
    }
}

/// Outcome of an engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub updates: u64,
    pub wall_secs: f64,
    pub stop: StopReason,
    /// Updates per worker (threaded engine).
    pub per_worker: Vec<u64>,
    /// Number of background/on-demand sync executions performed.
    pub syncs_run: u64,
    /// Scope-lock contention counters (all zero for sequential runs).
    pub contention: ContentionStats,
    /// Consistent snapshots captured during the run, oldest first (empty
    /// unless [`EngineConfig::snapshot_every`] was set on a sharded wire
    /// engine). The last entry is the newest recovery point.
    pub snapshots: Vec<Snapshot>,
    /// Telemetry collected during the run: per-kind event counts, the
    /// sampled metric time series, and the export paths actually written.
    /// `None` when [`EngineConfig::telemetry`] was unset.
    pub telemetry: Option<crate::telemetry::TelemetryReport>,
}

impl RunReport {
    pub fn updates_per_sec(&self) -> f64 {
        self.updates as f64 / self.wall_secs.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_collects_tasks() {
        let sdt = Sdt::new();
        let mut ctx = UpdateContext::new(&sdt, 3);
        ctx.add_task(5, 1.5);
        ctx.add_task_func(7, 2, 0.5);
        let tasks = ctx.take_spawned();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0].vertex, 5);
        assert_eq!(tasks[0].priority, 1.5);
        assert_eq!(tasks[1].func, 2);
        assert!(ctx.take_spawned().is_empty(), "drained");
        assert_eq!(ctx.worker, 3);
    }

    #[test]
    fn config_builders() {
        let c = EngineConfig::default()
            .with_workers(8)
            .with_model(ConsistencyModel::Full)
            .with_max_updates(100)
            .with_ghost_staleness(4)
            .with_ghost_batch(16)
            .with_steal_half_auto(0.5);
        assert_eq!(c.workers, 8);
        assert_eq!(c.model, ConsistencyModel::Full);
        assert_eq!(c.max_updates, Some(100));
        assert_eq!(c.ghost_staleness, 4);
        assert_eq!(c.ghost_batch, 16);
        assert_eq!(c.steal_half_auto, 0.5);
        let d = EngineConfig::default();
        assert_eq!(d.ghost_staleness, 0, "synchronous semantics by default");
        assert_eq!(d.ghost_batch, 1, "per-update flush by default");
        assert!(d.fault_plan.is_none(), "perfect wire by default");
        assert_eq!(d.snapshot_every, 0, "no snapshots by default");
        assert!(d.snapshot_dir.is_none());
        assert!(d.abort_plan.is_none(), "no scripted crash by default");
        assert_eq!(d.pull_retry_limit, 8);
        assert!(d.telemetry.is_none(), "telemetry off by default");
        assert!(d.progress_metric.is_none());
        assert_eq!(d.injector_capacity, 4096, "BENCH_sched cap-sweep default");
        assert!(!d.pin_workers, "unpinned by default");
        assert!(d.resident_shard.is_none(), "single-process by default");
        assert_eq!(d.processes, 0, "in-process execution by default");
        let e = EngineConfig::default()
            .with_injector_capacity(64)
            .with_pin_workers(true)
            .with_resident_shard(2);
        assert_eq!(e.injector_capacity, 64);
        assert!(e.pin_workers);
        assert_eq!(e.resident_shard, Some(2));
        let p = EngineConfig::default().with_processes(4);
        assert_eq!(p.processes, 4);
        assert_eq!(p.shards, 4, "processes implies a matching cut");
        let q = EngineConfig::default().with_shards(8).with_processes(4);
        assert_eq!(q.shards, 8, "an explicit cut is not overridden");
    }

    #[test]
    fn telemetry_builders() {
        let c = EngineConfig::default()
            .with_telemetry(crate::telemetry::TelemetryConfig::default().with_ring_capacity(64))
            .with_progress_metric(|_sdt| 0.75);
        assert_eq!(c.telemetry.as_ref().unwrap().ring_capacity, 64);
        let sdt = Sdt::new();
        assert_eq!((c.progress_metric.unwrap())(&sdt), 0.75);
    }

    #[test]
    fn fault_tolerance_builders() {
        let plan = crate::transport::FaultPlan {
            seed: 7,
            drop_per_mille: 100,
            dup_per_mille: 50,
            delay_per_mille: 50,
            sever_per_mille: 25,
        };
        let c = EngineConfig::default()
            .with_fault_plan(plan)
            .with_snapshot_every(500)
            .with_snapshot_dir(std::path::PathBuf::from("/tmp/snaps"))
            .with_abort_plan(AbortPlan { shard: 1, after_updates: 1_000 })
            .with_pull_retry_limit(3);
        assert_eq!(c.fault_plan, Some(plan));
        assert_eq!(c.snapshot_every, 500);
        assert_eq!(c.snapshot_dir.as_deref(), Some(std::path::Path::new("/tmp/snaps")));
        assert_eq!(c.abort_plan, Some(AbortPlan { shard: 1, after_updates: 1_000 }));
        assert_eq!(c.pull_retry_limit, 3);
    }
}
