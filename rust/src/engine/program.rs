//! The unified engine front-end: a [`Program`] bundles everything a GraphLab
//! run needs — update functions, syncs, terminators, and the engine
//! configuration — and the [`Engine`] trait abstracts over the sequential
//! and threaded back-ends, so call sites stop hand-assembling the historical
//! 8-argument `run(...)` invocation (and stop managing lock tables: the
//! threaded back-end builds its own).
//!
//! ```ignore
//! let report = Program::new()
//!     .update_fn(&diffuse)
//!     .sync(mean_op)
//!     .workers(4)
//!     .model(ConsistencyModel::Edge)
//!     .run(&mut graph, &sched, &sdt);
//! ```

use super::sequential::{SeqOptions, SequentialEngine};
use super::sharded::{
    ChannelShardedEngine, ShardedEngine, ShmShardedEngine, SocketShardedEngine,
};
use super::threaded::ThreadedEngine;
use super::trace::TaskTrace;
use super::{EngineConfig, RunReport, TerminationFn, UpdateFn};
use crate::consistency::{ConsistencyModel, LockTable};
use crate::graph::DataGraph;
use crate::scheduler::Scheduler;
use crate::sdt::{Sdt, SyncOp};
use crate::transport::VertexCodec;

/// An engine back-end that can execute a [`Program`]. Both back-ends take
/// `&mut DataGraph` for a uniform signature; the threaded engine reborrows
/// it shared (its interior mutability is guarded by the lock table it
/// builds for the run).
pub trait Engine<V, E> {
    fn name(&self) -> &'static str;

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport;
}

impl<V: Send + Sync, E: Send + Sync> Engine<V, E> for ThreadedEngine {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        let locks = LockTable::new(graph.num_vertices());
        ThreadedEngine::run(
            graph,
            &locks,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            &program.config,
        )
    }
}

impl<V, E> Engine<V, E> for SequentialEngine {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn execute(
        &self,
        program: &Program<'_, V, E>,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        SequentialEngine::run(
            graph,
            scheduler,
            &program.fns,
            sdt,
            &program.syncs,
            &program.terminators,
            &program.config,
            &program.seq,
        )
        .0
    }
}

/// Sharded run-path selector installed by [`Program::transport`]: a plain
/// function pointer, so the serializing back-ends' `V: VertexCodec` bound
/// lives on the *setter* and [`Program::run`] keeps its loose bounds for
/// vertex types that never leave one address space.
type WireRunner<V, E> =
    for<'p> fn(&Program<'p, V, E>, &mut DataGraph<V, E>, &dyn Scheduler, &Sdt) -> RunReport;

fn run_channel<V: VertexCodec + Clone + Send + Sync, E: Send + Sync>(
    p: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport {
    p.run_on(&ChannelShardedEngine::new(p.config.shards), graph, scheduler, sdt)
}

fn run_channel_compressed<V: VertexCodec + Clone + Send + Sync, E: Send + Sync>(
    p: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport {
    p.run_on(&ChannelShardedEngine::compressed(p.config.shards), graph, scheduler, sdt)
}

fn run_socket<V: VertexCodec + Clone + Send + Sync, E: Send + Sync>(
    p: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport {
    p.run_on(&SocketShardedEngine::new(p.config.shards), graph, scheduler, sdt)
}

fn run_socket_z<V: VertexCodec + Clone + Send + Sync, E: Send + Sync>(
    p: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport {
    p.run_on(&SocketShardedEngine::compressed(p.config.shards), graph, scheduler, sdt)
}

fn run_shm<V: VertexCodec + Clone + Send + Sync, E: Send + Sync>(
    p: &Program<'_, V, E>,
    graph: &mut DataGraph<V, E>,
    scheduler: &dyn Scheduler,
    sdt: &Sdt,
) -> RunReport {
    p.run_on(&ShmShardedEngine::new(p.config.shards), graph, scheduler, sdt)
}

/// A complete GraphLab program: graph-independent logic (update functions,
/// syncs, terminators) plus run configuration. Built with chained setters,
/// executed against a graph + scheduler + SDT via [`Program::run`] (which
/// picks a back-end from `workers`), [`Program::run_on`] (explicit
/// back-end), or [`Program::run_traced`] (sequential + task trace for the
/// multicore simulator).
pub struct Program<'a, V, E> {
    pub(crate) fns: Vec<&'a dyn UpdateFn<V, E>>,
    pub(crate) syncs: Vec<SyncOp<V>>,
    pub(crate) terminators: Vec<TerminationFn>,
    /// Engine configuration (workers, model, budget, term-check cadence).
    pub config: EngineConfig,
    /// Sequential-backend options (trace capture, sync cadence, virtual
    /// workers for worker-affine schedulers).
    pub seq: SeqOptions,
    /// Ghost-transport backend name selected by [`Program::transport`].
    transport_name: &'static str,
    /// Sharded run path for the selected serializing transport, if any.
    wire: Option<WireRunner<V, E>>,
}

impl<'a, V, E> Default for Program<'a, V, E> {
    fn default() -> Self {
        Program {
            fns: Vec::new(),
            syncs: Vec::new(),
            terminators: Vec::new(),
            config: EngineConfig::default(),
            seq: SeqOptions::default(),
            transport_name: "direct",
            wire: None,
        }
    }
}

impl<'a, V, E> Program<'a, V, E> {
    pub fn new() -> Program<'a, V, E> {
        Program::default()
    }

    /// Register an update function. `FuncId` in a [`crate::scheduler::Task`]
    /// indexes the functions in registration order.
    pub fn update_fn(mut self, f: &'a dyn UpdateFn<V, E>) -> Self {
        self.fns.push(f);
        self
    }

    /// Register a sync operation (periodic if its interval is set; every
    /// sync also runs once at the end of the run).
    pub fn sync(mut self, op: SyncOp<V>) -> Self {
        self.syncs.push(op);
        self
    }

    /// Register a termination predicate over the SDT (paper §3.5).
    pub fn terminate_when(
        mut self,
        f: impl Fn(&Sdt) -> bool + Send + Sync + 'static,
    ) -> Self {
        self.terminators.push(Box::new(f));
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.config.workers = workers;
        self
    }

    pub fn model(mut self, model: ConsistencyModel) -> Self {
        self.config.model = model;
        self
    }

    pub fn max_updates(mut self, max: u64) -> Self {
        self.config.max_updates = Some(max);
        self
    }

    pub fn term_check_every(mut self, every: u64) -> Self {
        self.config.term_check_every = every;
        self
    }

    /// Deferral-fairness bound for the threaded back-end: once a task's
    /// vertex has accumulated this many deferrals, its next dispatch
    /// escalates to a blocking scope acquisition (see
    /// [`EngineConfig::escalate_after`]).
    pub fn escalate_after(mut self, deferrals: u32) -> Self {
        self.config.escalate_after = deferrals;
        self
    }

    /// Cut the data graph into `k` ghost-replicated shards and execute on
    /// the [`ShardedEngine`] (each shard gets its own worker set; scopes
    /// crossing a shard boundary use pipelined/split lock acquisition).
    /// `k <= 1` keeps the unsharded back-ends. See
    /// [`EngineConfig::shards`].
    pub fn shards(mut self, k: usize) -> Self {
        self.config.shards = k;
        self
    }

    /// Request a true **multi-process** deployment of `n` single-shard
    /// processes (implies a `n`-way cut unless [`Program::shards`] was set
    /// explicitly). [`Program::run`] itself stays in-process — update
    /// functions are closures and cannot cross `exec` — so the configured
    /// program is handed to
    /// [`ProcessHarness::from_config`](super::process::ProcessHarness::from_config),
    /// which launches `graphlab shard` children running the preset
    /// workloads against a shared rendezvous directory (see
    /// [`EngineConfig::processes`]).
    pub fn processes(mut self, n: usize) -> Self {
        self.config = self.config.with_processes(n);
        self
    }

    /// Switch the retry-deque steal policy from steal-one to steal-half
    /// (see [`EngineConfig::steal_half`]).
    pub fn steal_half(mut self, on: bool) -> Self {
        self.config.steal_half = on;
        self
    }

    /// Auto-select steal-half: flip a worker's steal scans to steal-half
    /// mid-run once its observed steals exceed this fraction of its pops
    /// (see [`EngineConfig::steal_half_auto`]; `f64::INFINITY` disables).
    pub fn steal_half_auto(mut self, frac: f64) -> Self {
        self.config.steal_half_auto = frac;
        self
    }

    /// Ghost staleness bound for the sharded back-end: readers of a ghost
    /// replica more than `s` master versions behind force a pull before
    /// their scope runs; `s = 0` (default) reproduces the synchronous
    /// per-update flush semantics (see [`EngineConfig::ghost_staleness`]).
    pub fn ghost_staleness(mut self, s: u64) -> Self {
        self.config.ghost_staleness = s;
        self
    }

    /// Select the ghost-sync transport backend for sharded runs
    /// ([`Program::shards`] `> 1`): `"direct"` (default — in-place replica
    /// writes, zero wire bytes), `"channel"` (serializing per-shard-pair
    /// byte queues), `"channel-compressed"` (the same queues carrying
    /// shadow-diffed varint frames — fewer bytes per delta for converging
    /// algorithms), `"shm"` (per-shard-pair lock-free SPSC byte rings over
    /// process-shareable memory — the same-host fast lane), `"socket"`
    /// (real Unix-domain-socket bytes with bounded send windows and
    /// backpressure), or `"socket-z"` (the socket path carrying
    /// shadow-diffed frames). The serializing backends
    /// require the vertex type to implement
    /// [`VertexCodec`](crate::transport::VertexCodec) — the bound lives on
    /// this setter, so programs that never call it keep the loose
    /// [`Program::run`] bounds.
    ///
    /// # Panics
    /// On an unknown backend name.
    pub fn transport(mut self, name: &str) -> Self
    where
        V: VertexCodec + Clone + Send + Sync,
        E: Send + Sync,
    {
        match name {
            "direct" => {
                self.transport_name = "direct";
                self.wire = None;
            }
            "channel" => {
                self.transport_name = "channel";
                self.wire = Some(run_channel::<V, E> as WireRunner<V, E>);
            }
            "channel-compressed" => {
                self.transport_name = "channel-compressed";
                self.wire = Some(run_channel_compressed::<V, E> as WireRunner<V, E>);
            }
            "shm" => {
                self.transport_name = "shm";
                self.wire = Some(run_shm::<V, E> as WireRunner<V, E>);
            }
            "socket" => {
                self.transport_name = "socket";
                self.wire = Some(run_socket::<V, E> as WireRunner<V, E>);
            }
            "socket-z" => {
                self.transport_name = "socket-z";
                self.wire = Some(run_socket_z::<V, E> as WireRunner<V, E>);
            }
            other => panic!(
                "unknown ghost transport {other:?} (expected \"direct\", \"channel\", \
                 \"channel-compressed\", \"shm\", \"socket\", or \"socket-z\")"
            ),
        }
        self
    }

    /// The ghost-transport backend [`Program::run`] will use for sharded
    /// runs (`"direct"` unless [`Program::transport`] overrode it).
    pub fn transport_name(&self) -> &'static str {
        self.transport_name
    }

    /// Ghost delta-batcher sync window for the sharded back-end: flush
    /// after this many boundary-update records, coalescing repeated writes
    /// to the same vertex within the window (see
    /// [`EngineConfig::ghost_batch`]; `1` = synchronous per-update flush).
    pub fn ghost_batch(mut self, window: usize) -> Self {
        self.config.ghost_batch = window;
        self
    }

    /// Lock-free slot count of the engines' injector rings (see
    /// [`EngineConfig::injector_capacity`]; default 4096 per the
    /// `BENCH_sched.json` capacity sweep). Overflow still spills to the
    /// injector's mutex list, so any value is safe.
    pub fn injector_capacity(mut self, slots: usize) -> Self {
        self.config.injector_capacity = slots;
        self
    }

    /// Pin worker threads to contiguous cores per shard (Linux
    /// `sched_setaffinity`; no-op + warning elsewhere — see
    /// [`EngineConfig::pin_workers`]).
    pub fn pin_workers(mut self, on: bool) -> Self {
        self.config.pin_workers = on;
        self
    }

    /// Run the sharded back-end over a deterministic lossy wire: the
    /// transport is wrapped in a
    /// [`FaultInjector`](crate::transport::FaultInjector) that drops,
    /// duplicates, delays/reorders delta frames and severs staleness
    /// pulls per `plan`'s seeded schedule (see
    /// [`EngineConfig::fault_plan`]).
    pub fn fault_plan(mut self, plan: crate::transport::FaultPlan) -> Self {
        self.config.fault_plan = Some(plan);
        self
    }

    /// Capture a Chandy–Lamport-style snapshot of every shard's master
    /// rows each `n` global updates on the codec-bearing sharded
    /// back-ends; completed snapshots land in `RunReport::snapshots`
    /// (see [`EngineConfig::snapshot_every`]; `0` = off).
    pub fn snapshot_every(mut self, n: u64) -> Self {
        self.config.snapshot_every = n;
        self
    }

    /// Additionally spill each completed snapshot to
    /// `dir/snapshot-epoch-<e>.bin` (see [`EngineConfig::snapshot_dir`]).
    pub fn snapshot_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.config.snapshot_dir = Some(dir.into());
        self
    }

    /// Fault-injection hook: kill shard `shard`'s worker set once the
    /// global update count reaches `after_updates` — the run stops with
    /// `StopReason::ShardAborted` and the shard's batched deltas are
    /// lost, as a crashed process would lose them (see
    /// [`EngineConfig::abort_plan`]). Recover by restoring the latest
    /// completed snapshot and re-running.
    pub fn abort_shard(mut self, shard: usize, after_updates: u64) -> Self {
        self.config.abort_plan = Some(super::AbortPlan { shard, after_updates });
        self
    }

    /// Retry budget for staleness-admission pulls on a faulty wire: a
    /// reader whose pull fails to bring the replica inside the bound
    /// re-issues it up to this many times (exponential spin backoff)
    /// before admitting the stale read as a counted `pull_timeout` (see
    /// [`EngineConfig::pull_retry_limit`]).
    pub fn pull_retry_limit(mut self, limit: u32) -> Self {
        self.config.pull_retry_limit = limit;
        self
    }

    /// Enable the runtime-gated [telemetry](crate::telemetry) layer for
    /// this program's runs: per-worker event rings, the fixed-interval
    /// metrics sampler, and (when `cfg` carries paths) Chrome-trace /
    /// JSONL export. The collected [`TelemetryReport`](crate::telemetry::TelemetryReport)
    /// lands in `RunReport::telemetry` (see [`EngineConfig::telemetry`]).
    pub fn telemetry(mut self, cfg: crate::telemetry::TelemetryConfig) -> Self {
        self.config.telemetry = Some(cfg);
        self
    }

    /// Register the app-supplied convergence scalar the telemetry sampler
    /// probes each interval (e.g. residual norm or belief delta read from
    /// the SDT); it lands in each sample's `progress` field (see
    /// [`EngineConfig::progress_metric`]).
    pub fn progress_metric(
        mut self,
        f: impl Fn(&Sdt) -> f64 + Send + Sync + 'static,
    ) -> Self {
        self.config.progress_metric = Some(std::sync::Arc::new(f));
        self
    }

    /// Sequential back-end: run on-demand syncs every N updates (0 = only
    /// at the end).
    pub fn sync_every(mut self, every: u64) -> Self {
        self.seq.sync_every = every;
        self
    }

    /// Sequential back-end: cycle `next_task(worker)` over this many
    /// virtual worker ids (needed for worker-affine schedulers).
    pub fn virtual_workers(mut self, n: usize) -> Self {
        self.seq.virtual_workers = n;
        self
    }

    /// Number of registered update functions.
    pub fn num_fns(&self) -> usize {
        self.fns.len()
    }

    /// Execute on an explicit back-end.
    pub fn run_on<Eng: Engine<V, E> + ?Sized>(
        &self,
        engine: &Eng,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport {
        assert!(!self.fns.is_empty(), "program has no update functions");
        engine.execute(self, graph, scheduler, sdt)
    }

    /// Execute, picking the back-end from the configuration:
    /// [`Program::shards`] `> 1` runs the sharded engine (over the
    /// backend [`Program::transport`] selected — direct unless
    /// overridden), otherwise `workers > 1` runs threaded, otherwise
    /// sequential. Programs with *periodic* syncs never downgrade to
    /// sequential — only the multi-threaded back-ends have the background
    /// sync thread that honors `SyncOp::interval`, so downgrading would
    /// silently drop the cadence.
    pub fn run(
        &self,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport
    where
        V: Clone + Send + Sync,
        E: Send + Sync,
    {
        let needs_background_sync = self.syncs.iter().any(|op| op.interval.is_some());
        if self.config.shards > 1 {
            if let Some(wire) = self.wire {
                return wire(self, graph, scheduler, sdt);
            }
            self.run_on(&ShardedEngine::new(self.config.shards), graph, scheduler, sdt)
        } else if self.config.workers > 1 || needs_background_sync {
            self.run_on(&ThreadedEngine, graph, scheduler, sdt)
        } else {
            self.run_on(&SequentialEngine, graph, scheduler, sdt)
        }
    }

    /// Threaded back-end with a caller-managed lock table. For hot loops
    /// that execute many runs over the same graph (e.g. an interior-point
    /// outer loop driving inner solves), where rebuilding the per-vertex
    /// table on every [`Program::run`] would be wasted allocation.
    pub fn run_with_locks(
        &self,
        graph: &DataGraph<V, E>,
        locks: &LockTable,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> RunReport
    where
        V: Send + Sync,
        E: Send + Sync,
    {
        assert!(!self.fns.is_empty(), "program has no update functions");
        ThreadedEngine::run(
            graph,
            locks,
            scheduler,
            &self.fns,
            sdt,
            &self.syncs,
            &self.terminators,
            &self.config,
        )
    }

    /// Execute sequentially and capture the task trace the multicore
    /// simulator replays (`capture_trace` is forced on).
    pub fn run_traced(
        &self,
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        sdt: &Sdt,
    ) -> (RunReport, TaskTrace) {
        assert!(!self.fns.is_empty(), "program has no update functions");
        let mut opts = self.seq.clone();
        opts.capture_trace = true;
        SequentialEngine::run(
            graph,
            scheduler,
            &self.fns,
            sdt,
            &self.syncs,
            &self.terminators,
            &self.config,
            &opts,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::Scope;
    use crate::engine::{StopReason, UpdateContext};
    use crate::graph::GraphBuilder;
    use crate::scheduler::{FifoScheduler, Task};
    use crate::sdt::SyncOpBuilder;

    fn ring(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n {
            b.add_undirected(i as u32, ((i + 1) % n) as u32, (), ());
        }
        b.build()
    }

    struct Bump {
        rounds: u64,
    }
    impl UpdateFn<u64, ()> for Bump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.rounds {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    fn seeded_fifo(n: usize) -> FifoScheduler {
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        sched
    }

    #[test]
    fn program_runs_on_both_backends_with_same_result() {
        let n = 32;
        let f = Bump { rounds: 7 };
        let total_op = SyncOpBuilder::<u64, u64>::new("total", 0)
            .build(|acc, v| acc + *v, |acc, sdt| sdt.set("total", acc));
        let program = Program::new().update_fn(&f).sync(total_op).workers(1);
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.stop, StopReason::SchedulerEmpty);
        assert_eq!(report.updates, n as u64 * 7);
        assert_eq!(sdt.get::<u64>("total"), Some(n as u64 * 7));

        let f4 = Bump { rounds: 7 };
        let total_op = SyncOpBuilder::<u64, u64>::new("total", 0)
            .build(|acc, v| acc + *v, |acc, sdt| sdt.set("total", acc));
        let threaded = Program::new().update_fn(&f4).sync(total_op).workers(4);
        let mut g2 = ring(n);
        let sdt2 = Sdt::new();
        let report2 = threaded.run(&mut g2, &seeded_fifo(n), &sdt2);
        assert_eq!(report2.updates, report.updates);
        assert_eq!(sdt2.get::<u64>("total"), Some(n as u64 * 7));
    }

    /// A program with a *periodic* sync must not be downgraded to the
    /// sequential back-end at 1 worker — only the threaded engine owns the
    /// background thread that honors `SyncOp::interval`.
    #[test]
    fn periodic_sync_runs_even_at_one_worker() {
        let n = 32;
        let f = Bump { rounds: 200 };
        let op = SyncOpBuilder::<u64, u64>::new("total", 0)
            .every(std::time::Duration::from_millis(1))
            .build(|acc, v| acc + *v, |acc, sdt| sdt.set("total", acc));
        let program = Program::new().update_fn(&f).sync(op).workers(1);
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.updates, n as u64 * 200);
        // final sync always runs, so the SDT holds the exact final total
        assert_eq!(sdt.get::<u64>("total"), Some(n as u64 * 200));
        assert!(report.syncs_run >= 1);
    }

    #[test]
    fn run_on_explicit_backend_and_trace() {
        let n = 8;
        let f = Bump { rounds: 3 };
        let program = Program::new().update_fn(&f);
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report =
            program.run_on(&SequentialEngine, &mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.updates, n as u64 * 3);

        let mut g = ring(n);
        let (report, trace) = program.run_traced(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(trace.len() as u64, report.updates);
    }

    #[test]
    fn terminator_and_budget_flow_through() {
        let n = 8;
        let f = Bump { rounds: u64::MAX };
        let program = Program::new()
            .update_fn(&f)
            .terminate_when(|sdt: &Sdt| sdt.get_or::<bool>("stop", false))
            .term_check_every(4)
            .max_updates(40)
            .workers(1);
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.stop, StopReason::UpdateLimit);
        assert_eq!(report.updates, 40);

        sdt.set("stop", true);
        let mut g = ring(n);
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.stop, StopReason::TerminationFn);
    }

    /// `.shards(k)` with k > 1 must route `run` to the sharded back-end
    /// (visible through the report's shard-aware counters).
    #[test]
    fn shards_knob_routes_to_sharded_backend() {
        let n = 32;
        let f = Bump { rounds: 5 };
        let program = Program::new().update_fn(&f).workers(4).shards(2);
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        assert_eq!(report.updates, n as u64 * 5);
        assert_eq!(report.contention.shards, 2, "sharded engine ran");
        assert!(report.contention.boundary_updates > 0, "ring cut 2 ways has a boundary");
        assert!(report.contention.ghost_syncs > 0);
        // unsharded runs report no shard counters
        let f2 = Bump { rounds: 5 };
        let threaded = Program::new().update_fn(&f2).workers(2);
        let mut g2 = ring(n);
        let report2 = threaded.run(&mut g2, &seeded_fifo(n), &Sdt::new());
        assert_eq!(report2.contention.shards, 0);
    }

    /// `.transport("channel"|"socket")` must route `run` through the
    /// matching serializing sharded back-end (visible as shipped wire
    /// bytes), while the default stays direct (zero wire bytes).
    #[test]
    fn transport_knob_routes_to_serializing_backends() {
        let n = 32;
        for (name, expect_bytes) in [
            ("direct", false),
            ("channel", true),
            ("channel-compressed", true),
            ("shm", true),
            ("socket", true),
            ("socket-z", true),
        ] {
            let f = Bump { rounds: 5 };
            let program =
                Program::new().update_fn(&f).workers(4).shards(2).transport(name);
            assert_eq!(program.transport_name(), name);
            let mut g = ring(n);
            let report = program.run(&mut g, &seeded_fifo(n), &Sdt::new());
            assert_eq!(report.updates, n as u64 * 5, "{name}");
            assert_eq!(report.contention.shards, 2, "{name}: sharded engine ran");
            assert_eq!(
                report.contention.bytes_shipped > 0,
                expect_bytes,
                "{name}: wire bytes"
            );
        }
    }

    /// `.telemetry(...)` + `.progress_metric(...)` flow through to the
    /// run: the report carries a telemetry section whose task-span count
    /// matches the update count and whose samples probed the hook.
    #[test]
    fn telemetry_flows_through_program() {
        use crate::telemetry::{EventKind, TelemetryConfig};
        let n = 16;
        let f = Bump { rounds: 3 };
        let program = Program::new()
            .update_fn(&f)
            .workers(1)
            .telemetry(TelemetryConfig::default())
            .progress_metric(|sdt: &Sdt| sdt.get_or::<f64>("resid", 0.5));
        let mut g = ring(n);
        let sdt = Sdt::new();
        let report = program.run(&mut g, &seeded_fifo(n), &sdt);
        let tel = report.telemetry.expect("telemetry enabled");
        assert_eq!(tel.count(EventKind::TaskExec), report.updates);
        assert!(!tel.samples.is_empty(), "at least one inline sample");
        assert_eq!(tel.samples[0].progress, Some(0.5));
    }

    #[test]
    #[should_panic(expected = "unknown ghost transport")]
    fn unknown_transport_panics() {
        let _ = Program::<u64, ()>::new().transport("carrier-pigeon");
    }

    #[test]
    #[should_panic(expected = "no update functions")]
    fn empty_program_panics() {
        let program: Program<'_, u64, ()> = Program::new();
        let mut g = ring(4);
        let sdt = Sdt::new();
        program.run_on(&SequentialEngine, &mut g, &seeded_fifo(4), &sdt);
    }
}
