//! **Consistent snapshots** for the sharded engine, after Distributed
//! GraphLab's Chandy–Lamport snapshot-as-update-function (arXiv:1204.6078
//! §4.2): a recovery point a run can be restarted from after a shard's
//! worker set dies mid-run.
//!
//! The protocol is the classic marker algorithm specialized to this
//! engine's ownership discipline. Every vertex has exactly one **master**
//! row (on its owner shard, written only under the vertex's write lock);
//! ghost replicas are caches, and every in-flight delta or pull reply is
//! re-derivable from master data. That collapses the hard half of
//! Chandy–Lamport — recording channel state — to nothing: a consistent
//! global cut is exactly *one committed row per master vertex*, and the
//! snapshot's channel state is empty by construction.
//!
//! Concretely, when the engine announces snapshot epoch `e` (every
//! `EngineConfig::snapshot_every` global updates), each worker observes
//! the new epoch at its loop top and performs the **marker step**: flush
//! its outgoing delta window and drain its shard's inbox — the same
//! lane-clearing a marker frame would force — then race (one winner per
//! shard) to serialize the shard's owned rows through the vertex type's
//! [`VertexCodec`] encoding, each row frozen under its read lock. When
//! all `k` shards have contributed their part for epoch `e`, the
//! [`Snapshot`] is complete and lands in `RunReport::snapshots` (and on
//! disk when `EngineConfig::snapshot_dir` is set). Epochs interrupted by
//! a crash or run end simply never complete and are discarded — the
//! standard completion rule.
//!
//! **What a snapshot does and does not capture**: master vertex rows and
//! their version stamps — nothing else. Ghost tables, scheduler contents,
//! SDT state, and in-flight deltas are not captured; ghosts and channels
//! are rebuilt from masters on restart, and recovery re-seeds the
//! scheduler exactly like a fresh run (GraphLab update functions are
//! restartable by contract — rescheduling a vertex is always safe).

use crate::graph::{DataGraph, VertexId};
use crate::transport::{put_u32, put_u64, ByteReader, GhostDelta, VertexCodec};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One completed consistent snapshot: every master vertex row in the
/// graph, serialized at epoch `epoch`'s cut. Rows are stored as
/// concatenated delta-format frames (`u32 vertex, u64 version, u32 len,
/// payload`) — the transport's own wire format, reused so the snapshot
/// codec path is the one the live engine already exercises.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    epoch: u64,
    rows: u64,
    frames: Vec<u8>,
}

impl Snapshot {
    /// Snapshot epoch (monotone within a run: `global_updates /
    /// snapshot_every` at announcement time).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Master rows captured (equals the graph's vertex count for a
    /// complete snapshot).
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Serialized size of the captured rows in bytes.
    pub fn byte_len(&self) -> usize {
        self.frames.len()
    }

    /// Decode every captured row: `(vertex, master_version, data)`.
    /// Returns `None` if any frame is torn or fails the codec round-trip
    /// (a truncated snapshot file, a vertex-type mismatch).
    pub fn decode_rows<V: VertexCodec>(&self) -> Option<Vec<(VertexId, u64, V)>> {
        let mut r = ByteReader::new(&self.frames);
        let mut rows = Vec::with_capacity(self.rows as usize);
        while !r.is_empty() {
            let delta = GhostDelta::decode_from(&mut r)?;
            rows.push((delta.vertex, delta.version, delta.decode_vertex::<V>()?));
        }
        (rows.len() as u64 == self.rows).then_some(rows)
    }

    /// Restore every captured row into `graph`, rewinding each vertex's
    /// data to the snapshot cut. Returns the number of rows written;
    /// panics if the snapshot does not decode against `V` (restoring a
    /// snapshot of the wrong vertex type is unrecoverable caller error).
    ///
    /// This is the recovery half of the protocol: restore, then re-run
    /// the program on the restored graph with a fresh scheduler seed —
    /// update functions are restartable by contract, so the re-run
    /// converges to the same fixed point an uninterrupted run reaches.
    pub fn restore_into<V: VertexCodec, E>(&self, graph: &mut DataGraph<V, E>) -> u64 {
        let rows = self
            .decode_rows::<V>()
            .expect("snapshot does not decode against this vertex type");
        let n = rows.len() as u64;
        for (vertex, _version, data) in rows {
            *graph.vertex_data(vertex) = data;
        }
        n
    }

    /// Write the snapshot to `path`: `u64 epoch, u64 rows, frames`
    /// (little-endian, same frame bytes as in memory).
    pub fn write_file(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.epoch.to_le_bytes())?;
        f.write_all(&self.rows.to_le_bytes())?;
        f.write_all(&self.frames)?;
        Ok(())
    }

    /// Read a snapshot written by [`Snapshot::write_file`].
    pub fn read_file(path: &Path) -> std::io::Result<Snapshot> {
        let mut f = std::fs::File::open(path)?;
        let mut header = [0u8; 16];
        f.read_exact(&mut header)?;
        let epoch = u64::from_le_bytes(header[..8].try_into().unwrap());
        let rows = u64::from_le_bytes(header[8..].try_into().unwrap());
        let mut frames = Vec::new();
        f.read_to_end(&mut frames)?;
        Ok(Snapshot { epoch, rows, frames })
    }

    /// Assemble a snapshot from already-encoded row frames. The resident
    /// (multi-process) engine uses this to persist a single shard's part
    /// directly — the in-process `SnapshotStore` assembly never sees all
    /// `k` parts when each shard lives in its own process, so each child
    /// writes `snapshot-epoch-<e>-shard-<r>.bin` and recovery treats an
    /// epoch as complete only when every shard's file exists.
    pub(crate) fn from_parts(epoch: u64, rows: u64, frames: Vec<u8>) -> Snapshot {
        Snapshot { epoch, rows, frames }
    }
}

/// File name of one shard's snapshot part in resident (multi-process)
/// runs: recovery considers epoch `e` restorable only when the file
/// exists for every shard.
pub(crate) fn shard_part_name(epoch: u64, shard: usize) -> String {
    format!("snapshot-epoch-{epoch}-shard-{shard}.bin")
}

/// Scan `dir` for the newest epoch whose shard-part files are complete
/// (all `k` present) and read them back in shard order. Returns `None`
/// when no epoch is complete — partially written epochs (a shard died
/// mid-capture) are skipped per the completion rule.
pub(crate) fn latest_complete_parts(dir: &Path, shards: usize) -> Option<(u64, Vec<Snapshot>)> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut epochs: Vec<u64> = entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let rest = name.strip_prefix("snapshot-epoch-")?;
            let (epoch, _) = rest.split_once("-shard-")?;
            epoch.parse::<u64>().ok()
        })
        .collect();
    epochs.sort_unstable();
    epochs.dedup();
    for &epoch in epochs.iter().rev() {
        let paths: Vec<PathBuf> =
            (0..shards).map(|r| dir.join(shard_part_name(epoch, r))).collect();
        if !paths.iter().all(|p| p.exists()) {
            continue;
        }
        let mut parts = Vec::with_capacity(shards);
        let mut ok = true;
        for path in &paths {
            match Snapshot::read_file(path) {
                Ok(part) => parts.push(part),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            return Some((epoch, parts));
        }
    }
    None
}

/// Per-run snapshot controls resolved from the engine config by the
/// codec-bearing engine paths: the capture cadence plus a monomorphic
/// row-encoder function pointer — `run_core` itself only requires
/// `V: Clone`, so the `VertexCodec` bound lives here, at resolution time.
pub(crate) struct SnapshotCtl<V> {
    /// Capture an epoch every this many global updates (> 0 here; a zero
    /// cadence resolves to no controller at all).
    pub(crate) every: u64,
    encode: fn(&V, &mut Vec<u8>),
    dir: Option<PathBuf>,
}

fn encode_row<V: VertexCodec>(data: &V, out: &mut Vec<u8>) {
    data.encode(out);
}

impl<V: VertexCodec> SnapshotCtl<V> {
    /// Resolve the config's snapshot knobs; `None` when snapshots are off.
    pub(crate) fn from_config(config: &super::EngineConfig) -> Option<SnapshotCtl<V>> {
        (config.snapshot_every > 0).then(|| SnapshotCtl {
            every: config.snapshot_every,
            encode: encode_row::<V>,
            dir: config.snapshot_dir.clone(),
        })
    }
}

impl<V> SnapshotCtl<V> {
    /// Append one captured row in the snapshot frame format.
    pub(crate) fn encode_frame(
        &self,
        vertex: VertexId,
        version: u64,
        data: &V,
        frames: &mut Vec<u8>,
    ) {
        put_u32(frames, vertex);
        put_u64(frames, version);
        let len_at = frames.len();
        put_u32(frames, 0);
        (self.encode)(data, frames);
        let len = (frames.len() - len_at - 4) as u32;
        frames[len_at..len_at + 4].copy_from_slice(&len.to_le_bytes());
    }

    /// The configured spill directory, if any — the resident engine
    /// writes its per-shard part files here directly instead of going
    /// through a [`SnapshotStore`].
    pub(crate) fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Build the run's part-assembly store (shares the config's optional
    /// spill directory).
    pub(crate) fn store(&self, shards: usize) -> SnapshotStore {
        SnapshotStore {
            shards,
            dir: self.dir.clone(),
            parts: Mutex::new(HashMap::new()),
            completed: Mutex::new(Vec::new()),
        }
    }
}

/// Assembles per-shard snapshot parts into completed [`Snapshot`]s. An
/// epoch completes when all `shards` parts have arrived; incomplete
/// epochs (crash, run end) are silently discarded per the completion
/// rule.
pub(crate) struct SnapshotStore {
    shards: usize,
    dir: Option<PathBuf>,
    parts: Mutex<HashMap<u64, Vec<Option<(Vec<u8>, u64)>>>>,
    completed: Mutex<Vec<Snapshot>>,
}

impl SnapshotStore {
    /// Contribute shard `shard`'s serialized rows for `epoch`. Returns
    /// `true` when this part completed the epoch (the caller's shard was
    /// the last to arrive); the completed snapshot is retained (and
    /// written to the spill directory, if configured).
    pub(crate) fn add_part(&self, epoch: u64, shard: usize, frames: Vec<u8>, rows: u64) -> bool {
        let assembled = {
            let mut parts = self.parts.lock().unwrap();
            let slots = parts.entry(epoch).or_insert_with(|| vec![None; self.shards]);
            debug_assert!(slots[shard].is_none(), "shard {shard} captured epoch {epoch} twice");
            slots[shard] = Some((frames, rows));
            if slots.iter().all(Option::is_some) {
                parts.remove(&epoch)
            } else {
                None
            }
        };
        let Some(slots) = assembled else { return false };
        let mut frames = Vec::new();
        let mut rows = 0u64;
        for part in slots.into_iter().flatten() {
            frames.extend_from_slice(&part.0);
            rows += part.1;
        }
        let snap = Snapshot { epoch, rows, frames };
        if let Some(dir) = &self.dir {
            let _ = std::fs::create_dir_all(dir);
            let path = dir.join(format!("snapshot-epoch-{epoch}.bin"));
            if let Err(e) = snap.write_file(&path) {
                eprintln!("graphlab snapshot: writing {path:?} failed: {e}");
            }
        }
        self.completed.lock().unwrap().push(snap);
        true
    }

    /// Completed snapshots, oldest epoch first; incomplete epochs are
    /// dropped.
    pub(crate) fn into_completed(self) -> Vec<Snapshot> {
        let mut done = self.completed.into_inner().unwrap();
        done.sort_by_key(Snapshot::epoch);
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn ctl(every: u64, dir: Option<PathBuf>) -> SnapshotCtl<u64> {
        SnapshotCtl { every, encode: encode_row::<u64>, dir }
    }

    #[test]
    fn parts_assemble_in_shard_order_and_round_trip() {
        let c = ctl(10, None);
        let store = c.store(2);
        let mut part1 = Vec::new();
        c.encode_frame(2, 9, &222, &mut part1);
        let mut part0 = Vec::new();
        c.encode_frame(0, 3, &100, &mut part0);
        c.encode_frame(1, 5, &111, &mut part0);
        assert!(!store.add_part(7, 1, part1, 1), "one part does not complete the epoch");
        assert!(store.add_part(7, 0, part0, 2), "the second part completes it");
        let done = store.into_completed();
        assert_eq!(done.len(), 1);
        let snap = &done[0];
        assert_eq!(snap.epoch(), 7);
        assert_eq!(snap.rows(), 3);
        assert!(snap.byte_len() > 0);
        let rows = snap.decode_rows::<u64>().expect("decodes");
        // Parts concatenate in shard order regardless of arrival order.
        assert_eq!(rows, vec![(0, 3, 100), (1, 5, 111), (2, 9, 222)]);
    }

    #[test]
    fn incomplete_epochs_are_discarded() {
        let c = ctl(10, None);
        let store = c.store(2);
        let mut part = Vec::new();
        c.encode_frame(0, 1, &5, &mut part);
        assert!(!store.add_part(3, 0, part, 1));
        assert!(store.into_completed().is_empty(), "a half-captured epoch never surfaces");
    }

    #[test]
    fn restore_rewinds_vertex_rows() {
        let mut b = GraphBuilder::new();
        for i in 0..4u64 {
            b.add_vertex(i * 100);
        }
        b.add_undirected(0, 1, (), ());
        b.add_undirected(2, 3, (), ());
        let mut g = b.build();
        let c = ctl(10, None);
        let store = c.store(1);
        let mut part = Vec::new();
        for v in 0..4u32 {
            c.encode_frame(v, u64::from(v), &(u64::from(v) * 7), &mut part);
        }
        store.add_part(1, 0, part, 4);
        let snap = store.into_completed().pop().unwrap();
        for v in 0..4u32 {
            *g.vertex_data(v) = 9_999;
        }
        assert_eq!(snap.restore_into(&mut g), 4);
        for v in 0..4u32 {
            assert_eq!(*g.vertex_data(v), u64::from(v) * 7, "row {v} rewound to the cut");
        }
    }

    #[test]
    fn file_round_trip_preserves_the_snapshot() {
        let dir = std::env::temp_dir()
            .join(format!("graphlab-snap-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ctl(10, Some(dir.clone()));
        let store = c.store(1);
        let mut part = Vec::new();
        c.encode_frame(0, 2, &42, &mut part);
        c.encode_frame(1, 4, &43, &mut part);
        assert!(store.add_part(5, 0, part, 2));
        let snap = store.into_completed().pop().unwrap();
        let path = dir.join("snapshot-epoch-5.bin");
        assert!(path.exists(), "completed snapshots spill to the configured dir");
        let read = Snapshot::read_file(&path).expect("reads back");
        assert_eq!(read, snap, "disk round-trip is exact");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_part_scan_skips_incomplete_epochs() {
        let dir = std::env::temp_dir()
            .join(format!("graphlab-snap-parts-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let c = ctl(10, None);
        let mut frames = Vec::new();
        c.encode_frame(0, 1, &7, &mut frames);
        // Epoch 3: both shard parts present. Epoch 5: only shard 0's part
        // landed before the "crash" — it must be skipped.
        for (epoch, shard) in [(3u64, 0usize), (3, 1), (5, 0)] {
            Snapshot::from_parts(epoch, 1, frames.clone())
                .write_file(&dir.join(shard_part_name(epoch, shard)))
                .unwrap();
        }
        let (epoch, parts) =
            latest_complete_parts(&dir, 2).expect("epoch 3 is complete");
        assert_eq!(epoch, 3, "the incomplete newer epoch is skipped");
        assert_eq!(parts.len(), 2);
        assert!(parts.iter().all(|p| p.epoch() == 3 && p.rows() == 1));
        assert!(
            latest_complete_parts(&dir, 3).is_none(),
            "a third shard's missing files leave no complete epoch"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_frames_fail_decode_cleanly() {
        let c = ctl(10, None);
        let mut frames = Vec::new();
        c.encode_frame(0, 1, &7, &mut frames);
        frames.pop();
        let snap = Snapshot { epoch: 1, rows: 1, frames };
        assert!(snap.decode_rows::<u64>().is_none(), "truncation is an error, not a panic");
    }
}
