//! Task traces captured by the sequential engine and replayed by the
//! multicore simulator (`crate::sim`). A trace records, for every executed
//! update, its measured cost and the tasks it spawned — the causal structure
//! the simulator needs to model a P-processor execution.

use crate::graph::VertexId;
use crate::scheduler::{FuncId, Task};

/// One executed update.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub vertex: VertexId,
    pub func: FuncId,
    /// Priority the task carried when executed.
    pub priority: f64,
    /// Measured execution cost in nanoseconds (scope-locked region only).
    /// Captured with a [`crate::telemetry::SpanStart`] on the engine's run
    /// clock; when run-level telemetry is enabled the identical
    /// measurement is also recorded as the update's `task` span, so trace
    /// costs and Perfetto slice durations agree exactly.
    pub cost_ns: u64,
    /// Tasks spawned by this update (pre-deduplication).
    pub spawned: Vec<Task>,
}

/// A full sequential execution trace.
#[derive(Debug, Clone, Default)]
pub struct TaskTrace {
    /// Tasks seeded before the run started.
    pub initial: Vec<Task>,
    /// Executed updates in sequential order.
    pub events: Vec<TraceEvent>,
}

impl TaskTrace {
    pub fn new() -> TaskTrace {
        TaskTrace::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total measured work in nanoseconds.
    pub fn total_work_ns(&self) -> u64 {
        self.events.iter().map(|e| e.cost_ns).sum()
    }

    /// Mean per-update cost in nanoseconds.
    pub fn mean_cost_ns(&self) -> f64 {
        if self.events.is_empty() {
            0.0
        } else {
            self.total_work_ns() as f64 / self.events.len() as f64
        }
    }

    /// Index events by vertex: `occurrences[v]` lists the event indices where
    /// vertex `v` was updated, in execution order. The simulator uses this to
    /// look up the cost/spawn set of "the k-th execution of v".
    pub fn occurrences(&self, num_vertices: usize) -> Vec<Vec<u32>> {
        let mut occ = vec![Vec::new(); num_vertices];
        for (i, e) in self.events.iter().enumerate() {
            occ[e.vertex as usize].push(i as u32);
        }
        occ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(v: u32, cost: u64, spawned: &[u32]) -> TraceEvent {
        TraceEvent {
            vertex: v,
            func: 0,
            priority: 0.0,
            cost_ns: cost,
            spawned: spawned.iter().map(|&s| Task::new(s)).collect(),
        }
    }

    #[test]
    fn totals() {
        let trace = TaskTrace {
            initial: vec![Task::new(0)],
            events: vec![event(0, 100, &[1]), event(1, 300, &[])],
        };
        assert_eq!(trace.len(), 2);
        assert_eq!(trace.total_work_ns(), 400);
        assert_eq!(trace.mean_cost_ns(), 200.0);
    }

    #[test]
    fn occurrence_index() {
        let trace = TaskTrace {
            initial: vec![],
            events: vec![event(0, 1, &[]), event(1, 1, &[]), event(0, 1, &[])],
        };
        let occ = trace.occurrences(2);
        assert_eq!(occ[0], vec![0, 2]);
        assert_eq!(occ[1], vec![1]);
    }
}
