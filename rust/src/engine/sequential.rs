//! Single-threaded engine: deterministic execution of a schedule, used for
//! (a) correctness baselines ("any sequential execution" in Def. 3.1),
//! (b) single-processor timing runs, and (c) capturing the task traces the
//! multicore simulator replays.

use super::trace::{TaskTrace, TraceEvent};
use super::{
    ContentionStats, EngineConfig, RunReport, StopReason, TerminationFn, UpdateContext,
    UpdateFn,
};
use crate::consistency::Scope;
use crate::graph::DataGraph;
use crate::scheduler::Scheduler;
use crate::sdt::{Sdt, SyncOp};
use crate::telemetry::{self, EventKind, MonoClock, SampleSources, SpanStart, Telemetry};
use crate::util::Timer;
use std::time::Instant;

/// Sequential engine. See module docs.
pub struct SequentialEngine;

/// Options beyond [`EngineConfig`] for a sequential run.
#[derive(Debug, Clone, Default)]
pub struct SeqOptions {
    /// Capture a [`TaskTrace`] (adds two clock reads per update).
    pub capture_trace: bool,
    /// Run registered on-demand syncs every N updates (0 = only at end).
    pub sync_every: u64,
    /// Cycle `next_task(worker)` over this many virtual worker ids (0/1 =
    /// single worker). Needed for worker-affine schedulers (partitioned)
    /// whose queues are only served by their owning worker id.
    pub virtual_workers: usize,
}

impl SequentialEngine {
    /// Run until the scheduler drains, a termination function fires, or the
    /// update budget is exhausted. Returns the report and (optionally) the
    /// captured trace.
    ///
    /// Crate-internal: external callers go through the [`super::Engine`]
    /// trait / [`super::Program`] builder (`run_on`, `run_traced`) — the
    /// historical public multi-argument signature is folded away.
    pub(crate) fn run<V, E>(
        graph: &mut DataGraph<V, E>,
        scheduler: &dyn Scheduler,
        fns: &[&dyn UpdateFn<V, E>],
        sdt: &Sdt,
        syncs: &[SyncOp<V>],
        terminators: &[TerminationFn],
        config: &EngineConfig,
        opts: &SeqOptions,
    ) -> (RunReport, TaskTrace) {
        let timer = Timer::start();
        let mut trace = TaskTrace::new();
        let mut updates: u64 = 0;
        let mut syncs_run: u64 = 0;
        let mut stop = StopReason::SchedulerEmpty;

        // Telemetry (one track — the engine IS the worker) plus the shared
        // run clock: trace cost capture and telemetry task spans record the
        // same [`SpanStart`] measurement on the same timeline.
        let tel = config
            .telemetry
            .as_ref()
            .map(|cfg| Telemetry::new(cfg.clone(), vec!["worker-0".to_string()]));
        let clock = tel.as_ref().map(Telemetry::clock).unwrap_or_else(MonoClock::start);
        let bind = tel.as_ref().map(|t| t.bind_worker(0));
        let measure_cost = opts.capture_trace || tel.is_some();
        let queue_depth = || scheduler.approx_len() as u64;
        let retry_depth = || 0u64;
        let progress_fn = config.progress_metric.clone();
        let progress = progress_fn.as_ref().map(|f| move || f(sdt));
        let sources = SampleSources {
            queue_depth: &queue_depth,
            retry_depth: &retry_depth,
            progress: progress.as_ref().map(|f| f as &(dyn Fn() -> f64 + Sync)),
        };
        if let Some(t) = &tel {
            t.sample_now(&sources);
        }
        let mut last_sample = Instant::now();

        let vworkers = opts.virtual_workers.max(1);
        let mut worker = 0usize;
        let mut idle_polls = 0u64;
        'outer: loop {
            let next = scheduler.next_task(worker);
            let Some(task) = next else {
                if scheduler.is_done() {
                    break;
                }
                // Worker-affine schedulers only serve their own partition;
                // cycle the virtual worker id before concluding anything.
                worker = (worker + 1) % vworkers;
                idle_polls += 1;
                assert!(
                    idle_polls < 10_000_000,
                    "sequential engine live-locked: scheduler not done but \
                     produced no task in 10M polls (worker-affine scheduler \
                     without enough virtual_workers?)"
                );
                continue;
            };
            idle_polls = 0;

            let mut ctx = UpdateContext::new(sdt, worker);
            ctx.current_priority = task.priority;
            let t0 = measure_cost.then(|| SpanStart::begin(&clock));
            {
                // Externally synchronized: single thread owns the graph.
                let mut scope = Scope::unlocked(graph, task.vertex, config.model);
                fns[task.func as usize].update(&mut scope, &mut ctx);
            }
            // One measurement, two consumers: the trace event's cost and
            // the telemetry task span carry identical numbers.
            let (start_ns, cost_ns) =
                t0.map(|t| t.finish(&clock)).unwrap_or((0, 0));
            if tel.is_some() {
                telemetry::span_at(
                    EventKind::TaskExec,
                    start_ns,
                    cost_ns,
                    task.vertex as u64,
                    task.func as u64,
                );
            }
            let spawned = ctx.take_spawned();
            if opts.capture_trace {
                trace.events.push(TraceEvent {
                    vertex: task.vertex,
                    func: task.func,
                    priority: task.priority,
                    cost_ns,
                    spawned: spawned.clone(),
                });
            }
            for t in spawned {
                scheduler.add_task(t);
            }
            scheduler.task_done(task, worker);
            worker = (worker + 1) % vworkers;
            updates += 1;

            if let Some(t) = &tel {
                // Inline sampling: no threads in this back-end.
                if last_sample.elapsed() >= t.sample_interval() {
                    t.sample_now(&sources);
                    last_sample = Instant::now();
                }
            }

            if let Some(max) = config.max_updates {
                if updates >= max {
                    stop = StopReason::UpdateLimit;
                    break 'outer;
                }
            }
            let do_sync = opts.sync_every > 0 && updates % opts.sync_every == 0;
            if do_sync {
                for op in syncs {
                    Self::run_sync(graph, op, sdt);
                    syncs_run += 1;
                }
            }
            if updates % config.term_check_every == 0 {
                for term in terminators {
                    if term(sdt) {
                        stop = StopReason::TerminationFn;
                        break 'outer;
                    }
                }
            }
        }

        // Final syncs so the SDT reflects the converged state.
        for op in syncs {
            Self::run_sync(graph, op, sdt);
            syncs_run += 1;
        }

        if let Some(t) = &tel {
            t.sample_now(&sources);
        }
        drop(bind);
        let report = RunReport {
            updates,
            wall_secs: timer.elapsed_secs(),
            stop,
            per_worker: vec![updates],
            syncs_run,
            // single thread: scope conflicts cannot occur
            contention: ContentionStats::default(),
            snapshots: Vec::new(),
            telemetry: tel.map(Telemetry::finish),
        };
        (report, trace)
    }

    /// Sequential sync execution (Alg. 1): fold over all vertices, apply.
    pub fn run_sync<V, E>(graph: &mut DataGraph<V, E>, op: &SyncOp<V>, sdt: &Sdt) {
        let mut acc = op.init_acc();
        for v in 0..graph.num_vertices() as u32 {
            acc = op.fold_acc(acc, graph.vertex_data_ref(v));
        }
        op.apply_acc(acc, sdt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::graph::GraphBuilder;
    use crate::scheduler::{FifoScheduler, Task};
    use crate::sdt::SyncOpBuilder;

    /// Token-passing program: each vertex increments itself and schedules its
    /// right neighbor until the counter reaches a bound.
    fn chain_graph(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(0u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, (i + 1) as u32, (), ());
        }
        b.build()
    }

    struct Increment {
        bound: u64,
    }

    impl UpdateFn<u64, ()> for Increment {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < self.bound {
                for &u in scope.neighbors() {
                    if u > scope.center() {
                        ctx.add_task(u, 1.0);
                    }
                }
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }

    #[test]
    fn runs_until_drained_and_traces() {
        let mut g = chain_graph(4);
        let sched = FifoScheduler::new(4);
        sched.add_task(Task::new(0));
        let sdt = Sdt::new();
        let f = Increment { bound: 3 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let (report, trace) = SequentialEngine::run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::sequential(ConsistencyModel::Edge),
            &SeqOptions { capture_trace: true, sync_every: 0, virtual_workers: 1 },
        );
        assert_eq!(report.stop, StopReason::SchedulerEmpty);
        assert!(report.updates > 0);
        assert_eq!(trace.len() as u64, report.updates);
        // every vertex reached the bound
        for v in 0..4 {
            assert_eq!(*g.vertex_data(v), 3);
        }
        // trace causality: first event is the seeded vertex
        assert_eq!(trace.events[0].vertex, 0);
    }

    /// The trace's measured cost and the telemetry task span are the SAME
    /// measurement: one [`SpanStart`] on the shared run clock feeds both,
    /// so the numbers agree exactly, event for event.
    #[test]
    fn trace_cost_and_telemetry_span_agree_exactly() {
        use crate::telemetry::TelemetryConfig;
        let mut g = chain_graph(4);
        let sched = FifoScheduler::new(4);
        sched.add_task(Task::new(0));
        let sdt = Sdt::new();
        let f = Increment { bound: 3 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let mut cfg = EngineConfig::sequential(ConsistencyModel::Edge);
        cfg.telemetry = Some(TelemetryConfig::default());
        let (report, trace) = SequentialEngine::run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &cfg,
            &SeqOptions { capture_trace: true, sync_every: 0, virtual_workers: 1 },
        );
        let tel = report.telemetry.expect("telemetry on");
        let spans = tel.events_of(EventKind::TaskExec);
        assert_eq!(spans.len() as u64, report.updates, "one span per update");
        assert_eq!(spans.len(), trace.len());
        for (span, ev) in spans.iter().zip(&trace.events) {
            assert_eq!(span.dur_ns, ev.cost_ns, "one measurement, two consumers");
            assert_eq!(span.a, ev.vertex as u64);
        }
    }

    #[test]
    fn update_limit_stops_early() {
        let mut g = chain_graph(3);
        let sched = FifoScheduler::new(3);
        sched.add_task(Task::new(0));
        let sdt = Sdt::new();
        let f = Increment { bound: u64::MAX };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let (report, _) = SequentialEngine::run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[],
            &EngineConfig::sequential(ConsistencyModel::Edge).with_max_updates(10),
            &SeqOptions::default(),
        );
        assert_eq!(report.stop, StopReason::UpdateLimit);
        assert_eq!(report.updates, 10);
    }

    #[test]
    fn termination_fn_stops_run() {
        let mut g = chain_graph(3);
        let sched = FifoScheduler::new(3);
        sched.add_task(Task::new(0));
        let sdt = Sdt::new();
        sdt.set("stop", false);
        let f = Increment { bound: u64::MAX };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let term: TerminationFn = Box::new(|_sdt: &Sdt| true);
        let mut cfg = EngineConfig::sequential(ConsistencyModel::Edge);
        cfg.term_check_every = 4;
        let (report, _) = SequentialEngine::run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[],
            &[term],
            &cfg,
            &SeqOptions::default(),
        );
        assert_eq!(report.stop, StopReason::TerminationFn);
        assert_eq!(report.updates, 4);
    }

    #[test]
    fn syncs_run_and_final_sync_always_happens() {
        let mut g = chain_graph(4);
        let sched = FifoScheduler::new(4);
        sched.add_task(Task::new(0));
        let sdt = Sdt::new();
        let f = Increment { bound: 2 };
        let fns: Vec<&dyn UpdateFn<u64, ()>> = vec![&f];
        let sum_op = SyncOpBuilder::<u64, u64>::new("total", 0).build(
            |acc, v| acc + *v,
            |acc, sdt| sdt.set("total", acc),
        );
        let (report, _) = SequentialEngine::run(
            &mut g,
            &sched,
            &fns,
            &sdt,
            &[sum_op],
            &[],
            &EngineConfig::sequential(ConsistencyModel::Edge),
            &SeqOptions { capture_trace: false, sync_every: 3, virtual_workers: 1 },
        );
        assert!(report.syncs_run >= 1);
        assert_eq!(sdt.get::<u64>("total"), Some(8), "4 vertices x bound 2");
    }
}
