//! Greedy parallel graph coloring *as a GraphLab program* (paper §4.2):
//! "an update function which examines the colors of the neighboring vertices
//! of v, and sets v to the first unused color", run under the **edge
//! consistency** model so the parallel execution retains the sequential
//! guarantees. Used to build the chromatic schedule for the parallel Gibbs
//! sampler.

use crate::consistency::Scope;
use crate::engine::{UpdateContext, UpdateFn};

pub const UNCOLORED: u32 = u32::MAX;

/// Vertex state holding a color; embed in larger vertex types via the
/// [`HasColor`] accessor trait.
pub trait HasColor {
    fn color(&self) -> u32;
    fn set_color(&mut self, c: u32);
}

/// The coloring update function. If the vertex's color conflicts with (or is
/// dominated by) a neighbor, pick the smallest color unused in the
/// neighborhood; re-schedules any neighbor left in conflict.
pub struct ColoringUpdate;

impl<V: HasColor, E> UpdateFn<V, E> for ColoringUpdate {
    fn update(&self, scope: &mut Scope<'_, V, E>, ctx: &mut UpdateContext<'_>) {
        let mut used = Vec::new();
        for &u in scope.neighbors() {
            let c = scope.neighbor(u).color();
            if c != UNCOLORED {
                used.push(c);
            }
        }
        used.sort_unstable();
        used.dedup();
        // first free color
        let mut pick = 0u32;
        for &c in &used {
            if c == pick {
                pick += 1;
            } else if c > pick {
                break;
            }
        }
        let mine = scope.vertex().color();
        if mine == UNCOLORED || used.binary_search(&mine).is_ok() {
            scope.vertex_mut().set_color(pick);
            // any neighbor now conflicting must re-run
            for &u in scope.neighbors() {
                if scope.neighbor(u).color() == pick {
                    ctx.add_task(u, 1.0);
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "coloring"
    }
}

/// Validate a coloring: no edge connects same-colored vertices and every
/// vertex is colored. Returns the number of colors used.
pub fn validate_coloring<V: HasColor, E>(
    graph: &mut crate::graph::DataGraph<V, E>,
) -> Result<usize, String> {
    let n = graph.num_vertices();
    let colors: Vec<u32> = (0..n as u32).map(|v| graph.vertex_data(v).color()).collect();
    for (v, &c) in colors.iter().enumerate() {
        if c == UNCOLORED {
            return Err(format!("vertex {v} uncolored"));
        }
        for &u in graph.neighbors(v as u32) {
            if colors[u as usize] == c {
                return Err(format!("edge {v}-{u} shares color {c}"));
            }
        }
    }
    Ok(colors.iter().map(|&c| c as usize + 1).max().unwrap_or(0))
}

/// Group vertices by color: `classes[c]` lists vertices with color `c`
/// (the Gibbs sampler's vertex sets S_1..S_C).
pub fn color_classes<V: HasColor, E>(graph: &mut crate::graph::DataGraph<V, E>) -> Vec<Vec<u32>> {
    let n = graph.num_vertices();
    let mut classes: Vec<Vec<u32>> = Vec::new();
    for v in 0..n as u32 {
        let c = graph.vertex_data(v).color() as usize;
        if classes.len() <= c {
            classes.resize(c + 1, Vec::new());
        }
        classes[c].push(v);
    }
    classes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, ThreadedEngine};
    use crate::graph::{DataGraph, GraphBuilder};
    use crate::scheduler::{FifoScheduler, Scheduler, Task};
    use crate::sdt::Sdt;
    use crate::util::Pcg32;

    #[derive(Clone)]
    struct CV {
        color: u32,
    }
    impl HasColor for CV {
        fn color(&self) -> u32 {
            self.color
        }
        fn set_color(&mut self, c: u32) {
            self.color = c;
        }
    }

    fn random_graph(n: usize, m: usize, seed: u64) -> DataGraph<CV, ()> {
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut b = GraphBuilder::new();
        for _ in 0..n {
            b.add_vertex(CV { color: UNCOLORED });
        }
        let mut seen = std::collections::HashSet::new();
        let mut added = 0;
        while added < m {
            let u = rng.gen_range(n as u32);
            let v = rng.gen_range(n as u32);
            if u != v && seen.insert((u.min(v), u.max(v))) {
                b.add_undirected(u, v, (), ());
                added += 1;
            }
        }
        b.build()
    }

    #[test]
    fn colors_a_random_graph_in_parallel() {
        let mut g = random_graph(300, 900, 9);
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        let report = Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        assert!(report.updates >= 300);
        let ncolors = validate_coloring(&mut g).expect("valid coloring");
        assert!(ncolors >= 2 && ncolors <= g.max_degree() + 1, "greedy bound: {ncolors}");
    }

    #[test]
    fn color_classes_partition_vertices() {
        let mut g = random_graph(100, 250, 5);
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .workers(2)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        let classes = color_classes(&mut g);
        let total: usize = classes.iter().map(|c| c.len()).sum();
        assert_eq!(total, 100);
        // classes are independent sets
        for class in &classes {
            let set: std::collections::HashSet<u32> = class.iter().copied().collect();
            for &v in class {
                for &u in g.neighbors(v) {
                    assert!(!set.contains(&u), "adjacent {v},{u} in same class");
                }
            }
        }
    }

    #[test]
    fn validate_rejects_conflicts() {
        let mut b = GraphBuilder::new();
        b.add_vertex(CV { color: 0 });
        b.add_vertex(CV { color: 0 });
        b.add_undirected(0, 1, (), ());
        let mut g = b.build();
        assert!(validate_coloring(&mut g).is_err());
        *g.vertex_data(1) = CV { color: 1 };
        assert_eq!(validate_coloring(&mut g), Ok(2));
    }
}
