//! 2-D Haar wavelet transform — the sparsifying basis for the compressed
//! sensing pipeline (paper §4.5: "a sparse linear combination of basis
//! functions to represent the image").

/// One level of the 1-D Haar transform (orthonormal): averages in the first
/// half, details in the second.
fn haar1d(data: &mut [f32], len: usize, tmp: &mut [f32]) {
    let half = len / 2;
    let s = std::f32::consts::FRAC_1_SQRT_2;
    for i in 0..half {
        tmp[i] = s * (data[2 * i] + data[2 * i + 1]);
        tmp[half + i] = s * (data[2 * i] - data[2 * i + 1]);
    }
    data[..len].copy_from_slice(&tmp[..len]);
}

fn ihaar1d(data: &mut [f32], len: usize, tmp: &mut [f32]) {
    let half = len / 2;
    let s = std::f32::consts::FRAC_1_SQRT_2;
    for i in 0..half {
        tmp[2 * i] = s * (data[i] + data[half + i]);
        tmp[2 * i + 1] = s * (data[i] - data[half + i]);
    }
    data[..len].copy_from_slice(&tmp[..len]);
}

/// Full multi-level 2-D Haar transform in place. `size` must be a power of
/// two; `img` is `size * size`, row-major.
pub fn haar2d(img: &mut [f32], size: usize) {
    assert!(size.is_power_of_two());
    assert_eq!(img.len(), size * size);
    let mut tmp = vec![0.0f32; size];
    let mut len = size;
    let mut col = vec![0.0f32; size];
    while len > 1 {
        // rows
        for r in 0..len {
            haar1d(&mut img[r * size..r * size + len], len, &mut tmp);
        }
        // columns
        for c in 0..len {
            for r in 0..len {
                col[r] = img[r * size + c];
            }
            haar1d(&mut col, len, &mut tmp);
            for r in 0..len {
                img[r * size + c] = col[r];
            }
        }
        len /= 2;
    }
}

/// Inverse multi-level 2-D Haar transform in place.
pub fn ihaar2d(img: &mut [f32], size: usize) {
    assert!(size.is_power_of_two());
    assert_eq!(img.len(), size * size);
    let mut tmp = vec![0.0f32; size];
    let mut col = vec![0.0f32; size];
    let mut len = 2;
    while len <= size {
        for c in 0..len {
            for r in 0..len {
                col[r] = img[r * size + c];
            }
            ihaar1d(&mut col, len, &mut tmp);
            for r in 0..len {
                img[r * size + c] = col[r];
            }
        }
        for r in 0..len {
            ihaar1d(&mut img[r * size..r * size + len], len, &mut tmp);
        }
        len *= 2;
    }
}

/// Hard-threshold small coefficients (keep the `keep` largest magnitudes).
pub fn sparsify(coeffs: &mut [f32], keep: usize) {
    if keep >= coeffs.len() {
        return;
    }
    let mut mags: Vec<f32> = coeffs.iter().map(|c| c.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let cut = mags[keep];
    for c in coeffs.iter_mut() {
        if c.abs() <= cut {
            *c = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip_identity() {
        let mut rng = Pcg32::seed_from_u64(2);
        let size = 32;
        let orig: Vec<f32> = (0..size * size).map(|_| rng.next_f32()).collect();
        let mut img = orig.clone();
        haar2d(&mut img, size);
        ihaar2d(&mut img, size);
        for (a, b) in img.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn transform_is_orthonormal() {
        // energy preservation (Parseval)
        let mut rng = Pcg32::seed_from_u64(3);
        let size = 16;
        let orig: Vec<f32> = (0..size * size).map(|_| rng.next_f32() - 0.5).collect();
        let energy: f32 = orig.iter().map(|x| x * x).sum();
        let mut img = orig;
        haar2d(&mut img, size);
        let energy2: f32 = img.iter().map(|x| x * x).sum();
        assert!((energy - energy2).abs() / energy < 1e-4);
    }

    #[test]
    fn constant_image_compacts_to_dc() {
        let size = 8;
        let mut img = vec![1.0f32; size * size];
        haar2d(&mut img, size);
        // all energy in the DC coefficient
        assert!((img[0] - size as f32).abs() < 1e-4);
        let rest: f32 = img[1..].iter().map(|x| x.abs()).sum();
        assert!(rest < 1e-4);
    }

    #[test]
    fn smooth_images_are_sparse() {
        let size = 32;
        let mut img: Vec<f32> = (0..size * size)
            .map(|i| {
                let (x, y) = ((i % size) as f32, (i / size) as f32);
                (x / size as f32) + 0.5 * (y / size as f32)
            })
            .collect();
        let orig = img.clone();
        haar2d(&mut img, size);
        sparsify(&mut img, size * size / 10); // keep 10%
        ihaar2d(&mut img, size);
        let err: f32 = img.iter().zip(&orig).map(|(a, b)| (a - b).powi(2)).sum::<f32>()
            / (size * size) as f32;
        assert!(err < 1e-3, "10% of Haar coeffs reconstruct a smooth ramp, mse={err}");
    }
}
