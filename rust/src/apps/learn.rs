//! **MRF parameter learning** for 3-D retinal-scan denoising (paper §4.1,
//! Alg. 3, Fig. 4) — the complete ML "pipeline": composite statistics via
//! sync, simultaneous gradient learning of the three axis-aligned Laplace
//! smoothing parameters λ = (λx, λy, λz), and Loopy BP inference.
//!
//! The gradient is the exponential-family moment match: for each axis `a`,
//! the sufficient statistic is the expected absolute level difference
//! `E|x_v − x_u|` along that axis. Before learning, a sync pass over the
//! *proxy ground truth* (axis-aligned smoothed observations — the paper's
//! "axis-aligned averages as a proxy for ground-truth smoothed images")
//! fixes target statistics `T_a`; during learning, the background sync
//! (Alg. 3) folds the model statistics `S_a` cached on the vertices by the
//! BP update, and Apply takes the gradient step
//! `λ_a ← λ_a + η (S_a − T_a)` (more smoothing while the model is rougher
//! than the target), writing λ back to the SDT that the BP updates read —
//! learning and inference run *concurrently*.

use super::bp::LAMBDA_KEY;
use super::mrf::BpVertex;
use crate::sdt::{Sdt, SyncOp, SyncOpBuilder};
use std::time::Duration;

/// SDT key for the per-axis target statistics ([f64; 3]).
pub const TARGET_KEY: &str = "lambda_target_stats";
/// SDT key tracking the number of gradient steps taken (u64).
pub const STEPS_KEY: &str = "lambda_steps";

/// Accumulator for per-axis statistics: (sum, count) per axis.
type AxisAcc = ([f64; 3], [f64; 3]);

/// The Alg. 3 sync operation: Fold accumulates the per-vertex cached axis
/// statistics, Apply performs one projected-gradient step on λ.
///
/// `interval` — background period ("time between gradient steps", the Fig 4b/c
/// x-axis); `None` = on-demand.
pub fn learning_sync(
    learning_rate: f64,
    interval: Option<Duration>,
) -> SyncOp<BpVertex> {
    let builder = SyncOpBuilder::<BpVertex, AxisAcc>::new("lambda_sync", ([0.0; 3], [0.0; 3]));
    let builder = match interval {
        Some(iv) => builder.every(iv),
        None => builder,
    };
    builder.build_with_merge(
        |(mut s, mut c), v| {
            for a in 0..3 {
                if v.axis_stats[a] > 0.0 {
                    s[a] += v.axis_stats[a] as f64;
                    c[a] += 1.0;
                }
            }
            (s, c)
        },
        |(mut s1, mut c1), (s2, c2)| {
            for a in 0..3 {
                s1[a] += s2[a];
                c1[a] += c2[a];
            }
            (s1, c1)
        },
        move |(s, c), sdt: &Sdt| {
            let target = sdt.get_or::<[f64; 3]>(TARGET_KEY, [0.0; 3]);
            let mut lambda = sdt.get_or::<[f64; 3]>(LAMBDA_KEY, [1.0; 3]);
            for a in 0..3 {
                if c[a] > 0.0 {
                    let model_stat = s[a] / c[a];
                    // more smoothing while the model is rougher than target
                    lambda[a] = (lambda[a] + learning_rate * (model_stat - target[a]))
                        .clamp(0.01, 20.0);
                }
            }
            sdt.set(LAMBDA_KEY, lambda);
            sdt.update::<u64>(STEPS_KEY, |n| n.unwrap_or(0) + 1);
        },
    )
}

/// Compute the target statistics from the proxy ground truth: the mean
/// absolute level difference of axis-smoothed observations along each axis.
/// `observed(v)` = noisy level of voxel v, `smoothed` = window-averaged
/// volume (see [`crate::datagen::retina`]).
pub fn target_stats(
    dims: super::mrf::GridDims,
    smoothed: &[f32],
) -> [f64; 3] {
    let mut sums = [0.0f64; 3];
    let mut counts = [0.0f64; 3];
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            for x in 0..dims.nx {
                let v = dims.index(x, y, z) as usize;
                if x + 1 < dims.nx {
                    sums[0] += (smoothed[v] - smoothed[dims.index(x + 1, y, z) as usize]).abs()
                        as f64;
                    counts[0] += 1.0;
                }
                if y + 1 < dims.ny {
                    sums[1] += (smoothed[v] - smoothed[dims.index(x, y + 1, z) as usize]).abs()
                        as f64;
                    counts[1] += 1.0;
                }
                if z + 1 < dims.nz {
                    sums[2] += (smoothed[v] - smoothed[dims.index(x, y, z + 1) as usize]).abs()
                        as f64;
                    counts[2] += 1.0;
                }
            }
        }
    }
    [
        if counts[0] > 0.0 { sums[0] / counts[0] } else { 0.0 },
        if counts[1] > 0.0 { sums[1] / counts[1] } else { 0.0 },
        if counts[2] > 0.0 { sums[2] / counts[2] } else { 0.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::mrf::GridDims;
    use crate::engine::SequentialEngine;
    use crate::graph::GraphBuilder;

    fn vertex_with_stats(stats: [f32; 3]) -> BpVertex {
        let mut v = BpVertex::uniform(3);
        v.axis_stats = stats;
        v
    }

    #[test]
    fn gradient_step_moves_lambda_toward_target() {
        // Model stats (1.0) rougher than target (0.4): λ must increase.
        let mut b = GraphBuilder::<BpVertex, ()>::new();
        for _ in 0..4 {
            b.add_vertex(vertex_with_stats([1.0, 1.0, 0.0]));
        }
        let mut g = b.build();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [1.0f64; 3]);
        sdt.set(TARGET_KEY, [0.4f64, 2.0, 0.0]);
        let op = learning_sync(0.5, None);
        SequentialEngine::run_sync(&mut g, &op, &sdt);
        let lambda = sdt.get::<[f64; 3]>(LAMBDA_KEY).unwrap();
        assert!(lambda[0] > 1.0, "x-axis rougher than target: {lambda:?}");
        assert!(lambda[1] < 1.0, "y-axis smoother than target: {lambda:?}");
        assert_eq!(lambda[2], 1.0, "no z stats: unchanged");
        assert_eq!(sdt.get::<u64>(STEPS_KEY), Some(1));
    }

    #[test]
    fn lambda_stays_in_bounds() {
        let mut b = GraphBuilder::<BpVertex, ()>::new();
        b.add_vertex(vertex_with_stats([100.0, 0.0, 0.0]));
        let mut g = b.build();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [19.9f64; 3]);
        sdt.set(TARGET_KEY, [0.0f64; 3]);
        let op = learning_sync(10.0, None);
        SequentialEngine::run_sync(&mut g, &op, &sdt);
        let lambda = sdt.get::<[f64; 3]>(LAMBDA_KEY).unwrap();
        assert!(lambda[0] <= 20.0);
    }

    #[test]
    fn target_stats_measure_axis_roughness() {
        // volume varying along x only
        let dims = GridDims::new(4, 3, 2);
        let vol: Vec<f32> = (0..dims.len())
            .map(|v| {
                let (x, _, _) = dims.coords(v as u32);
                x as f32
            })
            .collect();
        let t = target_stats(dims, &vol);
        assert!((t[0] - 1.0).abs() < 1e-6);
        assert_eq!(t[1], 0.0);
        assert_eq!(t[2], 0.0);
    }
}
