//! The chromatic **parallel Gibbs sampler** (paper §4.2, Fig. 5).
//!
//! For any fixed-length Gauss–Seidel schedule there is an equivalent
//! parallel execution derived from a coloring of the dependency graph
//! (Bertsekas & Tsitsiklis 1989). The pipeline:
//!
//! 1. color the MRF with the GraphLab [coloring update](super::coloring);
//! 2. build the set-scheduler sequence `S_1..S_C` (one set per color,
//!    repeated per sweep);
//! 3. sample with the **vertex consistency** model — the coloring already
//!    guarantees no two adjacent vertices sample simultaneously, so vertex
//!    consistency suffices for full sequential consistency (paper §4.2).
//!
//! NOTE: the execution *plan* is compiled with **edge-model** read/write
//! sets (a sample reads its neighbors' values), which is what orders
//! consecutive color classes; only the runtime *locking* relaxes to the
//! vertex model — the plan's partial order already excludes adjacent
//! concurrency.

use super::coloring::HasColor;
use super::mrf::{EdgePotential, FlatTables};
use crate::consistency::Scope;
use crate::engine::{UpdateContext, UpdateFn};
use crate::graph::FlatVertex;
use crate::scheduler::FuncId;
use crate::transport::{put_u32, put_u32s, put_u8, ByteReader, VertexCodec};
use crate::util::Pcg32;
use std::cell::RefCell;
use std::sync::Mutex;

/// Vertex state for the sampler.
#[derive(Debug)]
pub struct GibbsVertex {
    /// Unnormalized unary potential (length K).
    pub potential: Vec<f32>,
    /// Current sample x_v.
    pub value: u8,
    /// Per-level visit counts (the marginal estimate).
    pub counts: Vec<u32>,
    /// Graph color (assigned by the coloring phase).
    pub color: u32,
}

impl GibbsVertex {
    pub fn new(potential: Vec<f32>) -> GibbsVertex {
        let k = potential.len();
        GibbsVertex { potential, value: 0, counts: vec![0; k], color: super::coloring::UNCOLORED }
    }

    /// Empirical marginal from the visit counts.
    pub fn marginal(&self) -> Vec<f32> {
        let total: u32 = self.counts.iter().sum();
        if total == 0 {
            return vec![1.0 / self.counts.len() as f32; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f32 / total as f32).collect()
    }
}

/// Manual `Clone` so `clone_from` reuses the destination's `Vec` buffers:
/// ghost-table writes and delta-batcher captures copy Gibbs state on every
/// boundary sample, and the derive would reallocate both vectors each time.
impl Clone for GibbsVertex {
    fn clone(&self) -> GibbsVertex {
        GibbsVertex {
            potential: self.potential.clone(),
            value: self.value,
            counts: self.counts.clone(),
            color: self.color,
        }
    }

    fn clone_from(&mut self, src: &GibbsVertex) {
        self.potential.clone_from(&src.potential);
        self.value = src.value;
        self.counts.clone_from(&src.counts);
        self.color = src.color;
    }
}

/// SoA view of a Gibbs vertex: floats are `[potential(K)]`, words are
/// `[value, color, counts(K)]`. See [`crate::graph::FlatVertexStore`].
impl FlatVertex for GibbsVertex {
    fn f32_lanes(arity: usize) -> usize {
        arity
    }

    fn u32_lanes(arity: usize) -> usize {
        arity + 2
    }

    fn write_flat(&self, floats: &mut [f32], words: &mut [u32]) {
        debug_assert_eq!(self.potential.len(), floats.len());
        floats.copy_from_slice(&self.potential);
        words[0] = self.value as u32;
        words[1] = self.color;
        words[2..].copy_from_slice(&self.counts);
    }

    fn read_flat(_arity: usize, floats: &[f32], words: &[u32]) -> GibbsVertex {
        GibbsVertex {
            potential: floats.to_vec(),
            value: words[0] as u8,
            color: words[1],
            counts: words[2..].to_vec(),
        }
    }
}

impl HasColor for GibbsVertex {
    fn color(&self) -> u32 {
        self.color
    }
    fn set_color(&mut self, c: u32) {
        self.color = c;
    }
}

/// Ghost-sync wire encoding of a Gibbs vertex: the unary potential, the
/// current sample, the visit counts, and the color. Lets the chromatic
/// sampler run on the sharded engine's serializing transport backends.
impl VertexCodec for GibbsVertex {
    fn encode(&self, buf: &mut Vec<u8>) {
        crate::transport::put_f32s(buf, &self.potential);
        put_u8(buf, self.value);
        put_u32s(buf, &self.counts);
        put_u32(buf, self.color);
    }

    fn decode(bytes: &[u8]) -> Option<GibbsVertex> {
        let mut r = ByteReader::new(bytes);
        let potential = r.f32s()?;
        let value = r.u8()?;
        let counts = r.u32s()?;
        let color = r.u32()?;
        r.is_empty().then_some(GibbsVertex { potential, value, counts, color })
    }
}

/// Edge data: pairwise potential reference (tables shared via the update fn).
#[derive(Debug, Clone, Copy)]
pub struct GibbsEdge {
    pub potential: EdgePotential,
}

/// The Gibbs update: sample x_v from P(x_v | x_{N(v)}) and record the visit.
pub struct GibbsUpdate {
    pub arity: usize,
    /// Shared K×K tables for `EdgePotential::Table`, flattened into one
    /// slab + offsets so the conditional's inner loop is a single slab
    /// index (see [`FlatTables`]).
    pub tables: FlatTables,
    /// Laplace λ per axis (fixed during sampling).
    pub lambda: [f64; 3],
    /// Per-worker RNG streams (uncontended: each worker uses its own slot).
    pub rngs: Vec<Mutex<Pcg32>>,
}

thread_local! {
    /// Reused per-thread conditional-distribution buffer: one fresh
    /// `Vec<f64>` per sample was pure allocator traffic on the sweep path.
    static GIBBS_SCRATCH: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

impl GibbsUpdate {
    pub fn new(
        arity: usize,
        tables: std::sync::Arc<Vec<Vec<f32>>>,
        workers: usize,
        seed: u64,
    ) -> GibbsUpdate {
        let mut root = Pcg32::seed_from_u64(seed);
        GibbsUpdate {
            arity,
            tables: FlatTables::from_nested(&tables, arity),
            lambda: [1.0; 3],
            rngs: (0..workers.max(1)).map(|w| Mutex::new(root.fork(w as u64))).collect(),
        }
    }

    #[inline]
    fn psi(&self, pot: EdgePotential, i: usize, j: usize) -> f32 {
        match pot {
            EdgePotential::Laplace { axis } => {
                let d = (i as f64 - j as f64).abs();
                (-self.lambda[axis as usize] * d).exp() as f32
            }
            EdgePotential::Table(t) => self.tables.at(t, i, j),
        }
    }
}

impl UpdateFn<GibbsVertex, GibbsEdge> for GibbsUpdate {
    fn update(&self, scope: &mut Scope<'_, GibbsVertex, GibbsEdge>, ctx: &mut UpdateContext<'_>) {
        let k = self.arity;
        let sample = GIBBS_SCRATCH.with(|scratch| {
            // conditional: φ_v(x) · Π_{u∈N(v)} ψ(x, x_u)
            let cond = &mut *scratch.borrow_mut();
            cond.clear();
            cond.extend(scope.vertex().potential.iter().map(|&p| p as f64));
            for &e in scope.out_edges() {
                let u = scope.edge(e).dst;
                let xu = scope.neighbor(u).value as usize;
                let pot = scope.edge_data(e).potential;
                for (x, c) in cond.iter_mut().enumerate() {
                    *c *= self.psi(pot, x, xu) as f64;
                }
            }
            let mut rng = self.rngs[ctx.worker % self.rngs.len()].lock().unwrap();
            rng.sample_discrete(cond)
        });
        debug_assert!(sample < k);
        let vd = scope.vertex_mut();
        vd.value = sample as u8;
        vd.counts[sample] += 1;
    }

    fn name(&self) -> &'static str {
        "gibbs"
    }
}

/// Build the chromatic set-scheduler sequence: `sweeps` repetitions of the
/// color classes, each paired with update function `func`.
pub fn chromatic_sets(classes: &[Vec<u32>], sweeps: usize, func: FuncId) -> Vec<(Vec<u32>, FuncId)> {
    let mut sets = Vec::with_capacity(classes.len() * sweeps);
    for _ in 0..sweeps {
        for class in classes {
            if !class.is_empty() {
                sets.push((class.clone(), func));
            }
        }
    }
    sets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, SequentialEngine, ShardedEngine, ThreadedEngine};
    use crate::graph::{DataGraph, GraphBuilder};
    use crate::scheduler::{FifoScheduler, Scheduler, SetScheduler, Task};
    use crate::sdt::Sdt;
    use std::sync::Arc;

    /// Two-vertex attractive Potts model: exact marginals computable by hand.
    fn two_spin(coupling: f32) -> (DataGraph<GibbsVertex, GibbsEdge>, Vec<Vec<f32>>) {
        let mut b = GraphBuilder::new();
        b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
        b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
        let tables = vec![vec![1.0, 1.0 - coupling, 1.0 - coupling, 1.0]];
        let e = GibbsEdge { potential: EdgePotential::Table(0) };
        b.add_undirected(0, 1, e, e);
        (b.build(), tables)
    }

    fn color_graph(g: &mut DataGraph<GibbsVertex, GibbsEdge>) {
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .workers(2)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, g, &sched, &sdt);
    }

    #[test]
    fn chromatic_gibbs_estimates_pair_correlation() {
        let (mut g, tables) = two_spin(0.8);
        color_graph(&mut g);
        assert!(validate_coloring(&mut g).is_ok());
        let classes = color_classes(&mut g);
        let sets = chromatic_sets(&classes, 4000, 0);
        let sched = SetScheduler::planned(&sets, 2, |v| g.neighbors(v), ConsistencyModel::Edge);
        let upd = GibbsUpdate::new(2, Arc::new(tables), 2, 123);
        let sdt = Sdt::new();
        let report = Program::new()
            .update_fn(&upd)
            .workers(2)
            .model(ConsistencyModel::Vertex)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        assert_eq!(report.updates, 2 * 4000);
        // symmetric model: marginals are uniform
        let m0 = g.vertex_data(0).marginal();
        assert!((m0[0] - 0.5).abs() < 0.05, "marginal {m0:?}");
    }

    #[test]
    fn gibbs_prefers_high_potential_state() {
        // single-ish chain with a strongly biased unary on vertex 0
        let mut b = GraphBuilder::new();
        b.add_vertex(GibbsVertex::new(vec![10.0, 1.0]));
        b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
        let tables = vec![vec![2.0, 0.5, 0.5, 2.0]]; // attractive
        let e = GibbsEdge { potential: EdgePotential::Table(0) };
        b.add_undirected(0, 1, e, e);
        let mut g = b.build();
        color_graph(&mut g);
        let classes = color_classes(&mut g);
        let sets = chromatic_sets(&classes, 3000, 0);
        let sched = SetScheduler::planned(&sets, 2, |v| g.neighbors(v), ConsistencyModel::Edge);
        let upd = GibbsUpdate::new(2, Arc::new(tables), 1, 7);
        let sdt = Sdt::new();
        Program::new()
            .update_fn(&upd)
            .workers(1)
            .model(ConsistencyModel::Vertex)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        let m0 = g.vertex_data(0).marginal();
        assert!(m0[0] > 0.75, "vertex 0 must prefer state 0: {m0:?}");
        // attraction pulls vertex 1 toward state 0 as well
        let m1 = g.vertex_data(1).marginal();
        assert!(m1[0] > 0.55, "vertex 1 pulled by attraction: {m1:?}");
    }

    /// Conservation on the sharded engine: under Full consistency every
    /// vertex must be sampled exactly once per sweep — the same totals the
    /// sequential engine produces — for every shard count, with ghost
    /// traffic reported on a cut chain (k >= 2).
    #[test]
    fn sharded_gibbs_conserves_sweeps() {
        let sweeps = 400usize;
        // 8-vertex chain, attractive pairwise table
        let build = || {
            let mut b = GraphBuilder::new();
            for _ in 0..8 {
                b.add_vertex(GibbsVertex::new(vec![1.0, 1.0]));
            }
            let e = GibbsEdge { potential: EdgePotential::Table(0) };
            for i in 0..7u32 {
                b.add_undirected(i, i + 1, e, e);
            }
            b.build()
        };
        let tables = vec![vec![1.5, 0.5, 0.5, 1.5]];

        // sequential baseline
        let mut seq = build();
        color_graph(&mut seq);
        let classes = color_classes(&mut seq);
        let sets = chromatic_sets(&classes, sweeps, 0);
        let sched = SetScheduler::planned(
            &sets,
            seq.num_vertices(),
            |v| seq.neighbors(v),
            ConsistencyModel::Edge,
        );
        let upd = GibbsUpdate::new(2, Arc::new(tables.clone()), 1, 9);
        let seq_report = Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Full)
            .run_on(&SequentialEngine, &mut seq, &sched, &Sdt::new());
        assert_eq!(seq_report.updates, 8 * sweeps as u64);
        for v in 0..8u32 {
            let total: u32 = seq.vertex_data(v).counts.iter().sum();
            assert_eq!(total as usize, sweeps, "sequential vertex {v}");
        }

        for k in [1usize, 2, 4] {
            let mut g = build();
            color_graph(&mut g);
            let classes = color_classes(&mut g);
            let sets = chromatic_sets(&classes, sweeps, 0);
            let sched = SetScheduler::planned(
                &sets,
                g.num_vertices(),
                |v| g.neighbors(v),
                ConsistencyModel::Edge,
            );
            let upd = GibbsUpdate::new(2, Arc::new(tables.clone()), 4, 9);
            let report = Program::new()
                .update_fn(&upd)
                .workers(4)
                .model(ConsistencyModel::Full)
                .run_on(&ShardedEngine::new(k), &mut g, &sched, &Sdt::new());
            assert_eq!(
                report.updates, seq_report.updates,
                "k={k}: sharded run must conserve the sequential update total"
            );
            assert_eq!(report.contention.shards, k);
            for v in 0..8u32 {
                let total: u32 = g.vertex_data(v).counts.iter().sum();
                assert_eq!(
                    total as usize, sweeps,
                    "k={k} vertex {v}: exactly one sample per sweep"
                );
            }
            if k >= 2 {
                assert!(report.contention.boundary_updates > 0, "k={k}");
                assert!(report.contention.ghost_syncs > 0, "k={k}");
            } else {
                assert_eq!(report.contention.ghost_syncs, 0);
            }
            // symmetric model: marginals stay near-uniform
            let m0 = g.vertex_data(0).marginal();
            assert!((m0[0] - 0.5).abs() < 0.2, "k={k} marginal {m0:?}");
        }
    }

    #[test]
    fn chromatic_sets_shape() {
        let classes = vec![vec![0, 2], vec![1], vec![]];
        let sets = chromatic_sets(&classes, 3, 0);
        assert_eq!(sets.len(), 6, "empty classes dropped, 2 classes x 3 sweeps");
        assert_eq!(sets[0].0, vec![0, 2]);
        assert_eq!(sets[1].0, vec![1]);
    }
}
