//! Loopy Belief Propagation update function (paper Alg. 2) — the running
//! example of the GraphLab abstraction.
//!
//! The update at vertex `v` recomputes the local belief from the inbound
//! messages, then for every out-edge `(v -> t)` computes the new message
//! from the cavity distribution (belief with `t`'s contribution divided
//! out), writes it to the edge, and — residual scheduling — re-schedules `t`
//! with the message's L1 change as priority when it exceeds the termination
//! bound. Under the **edge consistency** model this update is sequentially
//! consistent (Prop. 3.1: it modifies only `v` and its adjacent edges).

use super::mrf::{normalize, BpEdge, BpVertex, EdgePotential, FlatTables};
use crate::engine::{UpdateContext, UpdateFn};
use crate::consistency::Scope;
use crate::transport::{put_f32, put_f32s, put_u32, ByteReader, VertexCodec};
use std::cell::RefCell;
use std::sync::Arc;

/// Ghost-sync wire encoding of a BP vertex: both distributions
/// length-prefixed, then the observation and the per-axis learning stats.
/// Lets BP run on the sharded engine's serializing transport backends.
impl VertexCodec for BpVertex {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_f32s(buf, &self.potential);
        put_f32s(buf, &self.belief);
        put_u32(buf, self.observed);
        for &s in &self.axis_stats {
            put_f32(buf, s);
        }
    }

    fn decode(bytes: &[u8]) -> Option<BpVertex> {
        let mut r = ByteReader::new(bytes);
        let potential = r.f32s()?;
        let belief = r.f32s()?;
        let observed = r.u32()?;
        let mut axis_stats = [0.0f32; 3];
        for s in axis_stats.iter_mut() {
            *s = r.f32()?;
        }
        r.is_empty().then_some(BpVertex { potential, belief, observed, axis_stats })
    }
}

/// SDT key for the learnable Laplace smoothing parameters ([f64; 3]).
pub const LAMBDA_KEY: &str = "lambda";

/// The BP update function (Alg. 2). One instance is shared by all workers.
pub struct BpUpdate {
    pub arity: usize,
    /// Termination bound on the message residual (Alg. 2).
    pub bound: f32,
    /// Damping factor in [0, 1): new = (1-d)·computed + d·old.
    pub damping: f32,
    /// Shared K×K potential tables for `EdgePotential::Table` edges,
    /// flattened into one contiguous slab + offsets so the ψ lookup in
    /// the message inner loop is a single slab index instead of two
    /// pointer hops through `Vec<Vec<f32>>`.
    pub tables: FlatTables,
    /// Cache per-axis smoothness statistics on the vertex for the
    /// parameter-learning sync (§4.1, Alg. 3).
    pub learn_stats: bool,
}

thread_local! {
    /// Reused per-thread inner-loop buffers (belief, cavity, outbound
    /// message): the update runs millions of times per run, and three
    /// fresh `vec![]`s per call were pure allocator traffic.
    static BP_SCRATCH: RefCell<(Vec<f32>, Vec<f32>, Vec<f32>)> =
        RefCell::new((Vec::new(), Vec::new(), Vec::new()));
}

impl BpUpdate {
    pub fn new(arity: usize, bound: f32, tables: Arc<Vec<Vec<f32>>>) -> BpUpdate {
        BpUpdate {
            arity,
            bound,
            damping: 0.0,
            tables: FlatTables::from_nested(&tables, arity),
            learn_stats: false,
        }
    }

    /// ψ(x_src = i, x_dst = j) for the given edge potential.
    #[inline]
    fn psi(&self, pot: EdgePotential, lambda: &[f64; 3], i: usize, j: usize) -> f32 {
        match pot {
            EdgePotential::Laplace { axis } => {
                let d = (i as f64 - j as f64).abs();
                (-lambda[axis as usize] * d).exp() as f32
            }
            EdgePotential::Table(t) => self.tables.at(t, i, j),
        }
    }
}

impl UpdateFn<BpVertex, BpEdge> for BpUpdate {
    fn update(&self, scope: &mut Scope<'_, BpVertex, BpEdge>, ctx: &mut UpdateContext<'_>) {
        let k = self.arity;
        let lambda = ctx.sdt.get_or::<[f64; 3]>(LAMBDA_KEY, [1.0, 1.0, 1.0]);
        BP_SCRATCH.with(|scratch| {
            let (belief, cavity, new_msg) = &mut *scratch.borrow_mut();

            // 1. Local belief b(x_v) ∝ φ_v(x) · Π_{u->v} m_{u->v}(x).
            belief.clear();
            belief.extend_from_slice(&scope.vertex().potential);
            for &e in scope.in_edges() {
                let msg = &scope.edge_data(e).message;
                for (b, m) in belief.iter_mut().zip(msg) {
                    *b *= *m;
                }
            }
            normalize(belief);

            // 2. Outbound messages from cavity distributions.
            new_msg.clear();
            new_msg.resize(k, 0.0);
            for &e in scope.out_edges() {
                let t = scope.edge(e).dst;
                // cavity: divide out t's inbound contribution m_{t->v}
                cavity.clear();
                cavity.extend_from_slice(belief);
                if let Some(rev) = scope.reverse_edge(e) {
                    let m_in = &scope.edge_data(rev).message;
                    for (c, m) in cavity.iter_mut().zip(m_in) {
                        *c = if *m > 1e-30 { *c / *m } else { 0.0 };
                    }
                }
                normalize(cavity);

                let edge = scope.edge_data(e);
                let pot = edge.potential;
                for (j, out) in new_msg.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for (i, c) in cavity.iter().enumerate() {
                        acc += self.psi(pot, &lambda, i, j) * c;
                    }
                    *out = acc;
                }
                normalize(new_msg);

                let edge = scope.edge_data_mut(e);
                let mut residual = 0.0f32;
                for (m_old, &m_new) in edge.message.iter_mut().zip(new_msg.iter()) {
                    let blended = self.damping * *m_old + (1.0 - self.damping) * m_new;
                    residual += (blended - *m_old).abs();
                    *m_old = blended;
                }

                // Residual scheduling (Alg. 2): AddTask(t, residual).
                if residual > self.bound {
                    ctx.add_task(t, residual as f64);
                }
            }

            // 3. Learning statistics: E|x_v - x_u| per axis under the
            // mean-field pairwise approximation b_v(i)·b_u(j) (cached for
            // Alg. 3's fold).
            if self.learn_stats {
                let mut stats = [0.0f32; 3];
                let mut counts = [0.0f32; 3];
                for &e in scope.out_edges() {
                    let edge = scope.edge_data(e);
                    if let EdgePotential::Laplace { axis } = edge.potential {
                        let u = scope.edge(e).dst;
                        let nb = &scope.neighbor(u).belief;
                        let mut exp_absdiff = 0.0f32;
                        for (i, bi) in belief.iter().enumerate() {
                            for (j, bj) in nb.iter().enumerate() {
                                exp_absdiff += bi * bj * (i as f32 - j as f32).abs();
                            }
                        }
                        stats[axis as usize] += exp_absdiff;
                        counts[axis as usize] += 1.0;
                    }
                }
                let vd = scope.vertex_mut();
                for a in 0..3 {
                    vd.axis_stats[a] =
                        if counts[a] > 0.0 { stats[a] / counts[a] } else { 0.0 };
                }
            }

            // Write back into the vertex's existing belief buffer.
            let vd = scope.vertex_mut();
            vd.belief.clear();
            vd.belief.extend_from_slice(belief);
        });
    }

    fn name(&self) -> &'static str {
        "bp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::mrf::{grid3d, random_mrf, GridDims, Mrf};
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, SequentialEngine, ShardedEngine, ThreadedEngine};
    use crate::scheduler::{FifoScheduler, PriorityScheduler, Scheduler, Task};
    use crate::sdt::Sdt;
    use crate::util::Pcg32;

    /// Exact marginals by brute-force enumeration (tiny models only). Each
    /// undirected pair contributes its ψ once (messages live on both
    /// directions but the model has one potential per pair).
    fn enumerate_marginals(mrf: &mut Mrf, lambda: [f64; 3]) -> Vec<Vec<f32>> {
        let n = mrf.graph.num_vertices();
        let k = mrf.arity;
        assert!(k.pow(n as u32) <= 1 << 20, "enumeration too large");
        let upd = BpUpdate::new(k, 1e-3, Arc::new(mrf.tables.clone()));
        // collect undirected pairs (src < dst)
        let mut pairs = Vec::new();
        for e in 0..mrf.graph.num_edges() as u32 {
            let edge = mrf.graph.edge(e);
            if edge.src < edge.dst {
                pairs.push((edge.src, edge.dst, mrf.graph.edge_data(e).potential));
            }
        }
        let pots: Vec<Vec<f32>> =
            (0..n as u32).map(|v| mrf.graph.vertex_data(v).potential.clone()).collect();
        let mut marg = vec![vec![0.0f32; k]; n];
        let total_assignments = k.pow(n as u32);
        for code in 0..total_assignments {
            let mut x = vec![0usize; n];
            let mut c = code;
            for xi in x.iter_mut() {
                *xi = c % k;
                c /= k;
            }
            let mut p = 1.0f64;
            for (v, &xv) in x.iter().enumerate() {
                p *= pots[v][xv] as f64;
            }
            for &(u, v, pot) in &pairs {
                p *= upd.psi(pot, &lambda, x[u as usize], x[v as usize]) as f64;
            }
            for (v, &xv) in x.iter().enumerate() {
                marg[v][xv] += p as f32;
            }
        }
        for m in marg.iter_mut() {
            normalize(m);
        }
        marg
    }

    fn run_bp_sequential(mrf: &mut Mrf, lambda: [f64; 3], bound: f32) -> u64 {
        let n = mrf.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, lambda);
        let sched = PriorityScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::with_priority(v, 1.0));
        }
        let upd = BpUpdate::new(mrf.arity, bound, Arc::new(mrf.tables.clone()));
        let report = Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .max_updates(200_000)
            .run_on(&SequentialEngine, &mut mrf.graph, &sched, &sdt);
        report.updates
    }

    #[test]
    fn bp_exact_on_tree() {
        // 4-vertex chain with table potentials: BP on a tree is exact.
        let mut rng = Pcg32::seed_from_u64(11);
        let k = 3;
        let mut b = crate::graph::GraphBuilder::new();
        for _ in 0..4 {
            let pot: Vec<f32> = (0..k).map(|_| 0.3 + rng.next_f32()).collect();
            b.add_vertex(BpVertex::with_potential(pot));
        }
        // Pairwise tables must be symmetric: both directed edges of a pair
        // share one table (undirected model semantics).
        let mut tables = Vec::new();
        for _ in 0..3 {
            let mut tab = vec![0.0f32; k * k];
            for i in 0..k {
                for j in i..k {
                    let v = 0.2 + rng.next_f32();
                    tab[i * k + j] = v;
                    tab[j * k + i] = v;
                }
            }
            tables.push(tab);
        }
        for (i, t) in [(0u32, 0u32), (1, 1), (2, 2)].iter().enumerate() {
            let _ = t;
            b.add_undirected(
                i as u32,
                i as u32 + 1,
                BpEdge::uniform(k, EdgePotential::Table(i as u32)),
                BpEdge::uniform(k, EdgePotential::Table(i as u32)),
            );
        }
        let mut mrf = Mrf { graph: b.build(), tables, arity: k };
        let exact = enumerate_marginals(&mut mrf, [1.0; 3]);
        run_bp_sequential(&mut mrf, [1.0; 3], 1e-7);
        for v in 0..4u32 {
            let got = &mrf.graph.vertex_data(v).belief;
            for (g, e) in got.iter().zip(&exact[v as usize]) {
                assert!((g - e).abs() < 1e-4, "vertex {v}: {got:?} vs {:?}", exact[v as usize]);
            }
        }
    }

    #[test]
    fn bp_close_on_small_loopy_graph() {
        // 2x2x1 grid (a 4-cycle): loopy BP approximates but should be close
        // for weak couplings.
        let mut rng = Pcg32::seed_from_u64(3);
        let dims = GridDims::new(2, 2, 1);
        let mut mrf = grid3d(dims, 3, |_| (0..3).map(|_| 0.5 + rng.next_f32()).collect());
        let lambda = [0.3, 0.3, 0.3];
        let exact = enumerate_marginals(&mut mrf, lambda);
        run_bp_sequential(&mut mrf, lambda, 1e-7);
        for v in 0..4u32 {
            let got = &mrf.graph.vertex_data(v).belief;
            for (g, e) in got.iter().zip(&exact[v as usize]) {
                assert!((g - e).abs() < 0.05, "vertex {v}: {got:?} vs {:?}", exact[v as usize]);
            }
        }
    }

    #[test]
    fn residual_scheduling_converges() {
        let mut rng = Pcg32::seed_from_u64(7);
        let mut mrf = random_mrf(60, 120, 3, &mut rng);
        let updates = run_bp_sequential(&mut mrf, [1.0; 3], 1e-4);
        assert!(updates > 60, "must iterate beyond the seed sweep");
        assert!(updates < 200_000, "must converge before the update cap");
        // beliefs are normalized distributions
        for v in 0..60u32 {
            let b = &mrf.graph.vertex_data(v).belief;
            let sum: f32 = b.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
            assert!(b.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn threaded_bp_matches_sequential_beliefs() {
        let mk = || {
            let mut rng = Pcg32::seed_from_u64(42);
            random_mrf(80, 160, 3, &mut rng)
        };
        let mut seq = mk();
        run_bp_sequential(&mut seq, [1.0; 3], 1e-6);

        let mut par = mk();
        let n = par.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [1.0f64; 3]);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let upd = BpUpdate::new(par.arity, 1e-6, Arc::new(par.tables.clone()));
        let report = Program::new()
            .update_fn(&upd)
            .workers(4)
            .model(ConsistencyModel::Edge)
            .max_updates(500_000)
            .run_on(&ThreadedEngine, &mut par.graph, &sched, &sdt);
        assert!(report.updates > 0);
        // Both executions converge to the same fixed point.
        for v in 0..n as u32 {
            let a = &seq.graph.vertex_data(v).belief.clone();
            let b = &par.graph.vertex_data(v).belief;
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 5e-3, "vertex {v}: seq={a:?} par={b:?}");
            }
        }
    }

    /// Conservation on the sharded engine: BP under Full consistency must
    /// converge to the sequential fixed point for every shard count, and a
    /// cut graph (k >= 2) must report ghost traffic.
    #[test]
    fn sharded_bp_matches_sequential_beliefs() {
        let mk = || {
            let mut rng = Pcg32::seed_from_u64(42);
            random_mrf(80, 160, 3, &mut rng)
        };
        let mut seq = mk();
        run_bp_sequential(&mut seq, [1.0; 3], 1e-6);
        let reference: Vec<Vec<f32>> = (0..80u32)
            .map(|v| seq.graph.vertex_data(v).belief.clone())
            .collect();

        for k in [1usize, 2, 4] {
            let mut par = mk();
            let n = par.graph.num_vertices();
            let sdt = Sdt::new();
            sdt.set(LAMBDA_KEY, [1.0f64; 3]);
            let sched = FifoScheduler::new(n);
            for v in 0..n as u32 {
                sched.add_task(Task::new(v));
            }
            let upd = BpUpdate::new(par.arity, 1e-6, Arc::new(par.tables.clone()));
            let report = Program::new()
                .update_fn(&upd)
                .workers(4)
                .model(ConsistencyModel::Full)
                .max_updates(500_000)
                .run_on(&ShardedEngine::new(k), &mut par.graph, &sched, &sdt);
            assert!(report.updates > 0, "k={k}");
            assert_eq!(report.contention.shards, k);
            if k >= 2 {
                assert!(
                    report.contention.boundary_updates > 0,
                    "k={k}: a random MRF cut into shards has boundary work"
                );
                assert!(report.contention.ghost_syncs > 0, "k={k}");
            } else {
                assert_eq!(report.contention.ghost_syncs, 0);
            }
            for v in 0..n as u32 {
                let b = &par.graph.vertex_data(v).belief;
                for (x, y) in reference[v as usize].iter().zip(b.iter()) {
                    assert!(
                        (x - y).abs() < 5e-3,
                        "k={k} vertex {v}: seq={:?} sharded={b:?}",
                        reference[v as usize]
                    );
                }
            }
        }
    }

    #[test]
    fn learn_stats_cached_on_vertices() {
        let dims = GridDims::new(3, 3, 1);
        let mut mrf = grid3d(dims, 3, |v| {
            let mut p = vec![0.1; 3];
            p[(v % 3) as usize] = 1.0;
            p
        });
        let n = mrf.graph.num_vertices();
        let sdt = Sdt::new();
        sdt.set(LAMBDA_KEY, [0.5f64; 3]);
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let mut upd = BpUpdate::new(3, 1e-3, Arc::new(Vec::new()));
        upd.learn_stats = true;
        Program::new()
            .update_fn(&upd)
            .model(ConsistencyModel::Edge)
            .max_updates(10_000)
            .run_on(&SequentialEngine, &mut mrf.graph, &sched, &sdt);
        // interior vertices must have x- and y-axis stats populated
        let center = dims.index(1, 1, 0);
        let stats = mrf.graph.vertex_data(center).axis_stats;
        assert!(stats[0] > 0.0 && stats[1] > 0.0);
        assert_eq!(stats[2], 0.0, "flat volume has no z edges");
    }
}
