//! **Gaussian Belief Propagation** (GaBP) linear solver (paper §4.5;
//! Bickson 2008): solve `A x = b` for sparse symmetric diagonally-dominant
//! `A` by message passing on the graph whose edges are the non-zeros of `A`.
//!
//! Messages are scalar Gaussians in information form `(P, h)` (precision and
//! precision-mean). The update at vertex `i`:
//!
//! ```text
//! P_i  = A_ii + Σ_k P_{k→i}          h_i  = b_i + Σ_k h_{k→i}
//! x_i  = h_i / P_i                    (current belief mean)
//! for each neighbor j:
//!   P_{i\j} = P_i − P_{j→i}          h_{i\j} = h_i − h_{j→i}
//!   P_{i→j} = −A_ij² / P_{i\j}       h_{i→j} = −A_ij · h_{i\j} / P_{i\j}
//! ```
//!
//! The GraphLab mapping mirrors Loopy BP (§4.1): potentials and messages are
//! Gaussian instead of tabular; edge consistency gives sequential
//! consistency. Used as the inner solver of the compressed-sensing interior
//! point loop ([`super::cs`]), where "the graph structure is fixed across
//! iterations [so] we can leverage data persistency ... and resume from the
//! converged state of the previous iteration".

use crate::consistency::Scope;
use crate::engine::{UpdateContext, UpdateFn};
use crate::graph::{DataGraph, GraphBuilder, VertexId};

/// Vertex: one variable of the linear system.
#[derive(Debug, Clone)]
pub struct GabpVertex {
    /// Diagonal entry A_ii (prior precision).
    pub a_diag: f64,
    /// Right-hand side b_i (prior precision-mean).
    pub b: f64,
    /// Current belief mean (the solution estimate x_i).
    pub mean: f64,
    /// Current belief precision.
    pub precision: f64,
}

impl GabpVertex {
    pub fn new(a_diag: f64, b: f64) -> GabpVertex {
        GabpVertex { a_diag, b, mean: 0.0, precision: a_diag }
    }
}

/// Directed edge `i -> j`: the off-diagonal A_ij plus the message state.
#[derive(Debug, Clone, Copy)]
pub struct GabpEdge {
    pub a: f64,
    /// Message precision P_{i→j}.
    pub p: f64,
    /// Message precision-mean h_{i→j}.
    pub h: f64,
}

impl GabpEdge {
    pub fn new(a: f64) -> GabpEdge {
        GabpEdge { a, p: 0.0, h: 0.0 }
    }
}

/// Build the GaBP graph from a sparse symmetric matrix given as
/// `(i, j, A_ij)` upper-triangle entries plus the diagonal and rhs.
pub fn build_system(
    diag: &[f64],
    b: &[f64],
    off_diag: &[(u32, u32, f64)],
) -> DataGraph<GabpVertex, GabpEdge> {
    assert_eq!(diag.len(), b.len());
    let mut builder: GraphBuilder<GabpVertex, GabpEdge> =
        GraphBuilder::with_capacity(diag.len(), off_diag.len() * 2);
    for (d, rhs) in diag.iter().zip(b) {
        builder.add_vertex(GabpVertex::new(*d, *rhs));
    }
    for &(i, j, a) in off_diag {
        assert!(i != j, "diagonal entries belong in `diag`");
        builder.add_undirected(i, j, GabpEdge::new(a), GabpEdge::new(a));
    }
    builder.build()
}

/// The GaBP update function.
pub struct GabpUpdate {
    /// Residual bound: neighbors are rescheduled while the belief mean moves
    /// by more than this.
    pub bound: f64,
}

impl GabpUpdate {
    pub fn new(bound: f64) -> GabpUpdate {
        GabpUpdate { bound }
    }
}

impl UpdateFn<GabpVertex, GabpEdge> for GabpUpdate {
    fn update(&self, scope: &mut Scope<'_, GabpVertex, GabpEdge>, ctx: &mut UpdateContext<'_>) {
        // Aggregate inbound messages.
        let (a_diag, b) = {
            let v = scope.vertex();
            (v.a_diag, v.b)
        };
        let mut p_total = a_diag;
        let mut h_total = b;
        for &e in scope.in_edges() {
            let m = scope.edge_data(e);
            p_total += m.p;
            h_total += m.h;
        }
        let old_mean = scope.vertex().mean;
        let new_mean = if p_total.abs() > 1e-300 { h_total / p_total } else { 0.0 };

        // Outbound messages from cavity distributions.
        for &e in scope.out_edges() {
            let a_ij = scope.edge_data(e).a;
            let rev = scope.reverse_edge(e).expect("GaBP edges are symmetric pairs");
            let (p_in, h_in) = {
                let m = scope.edge_data(rev);
                (m.p, m.h)
            };
            let p_cav = p_total - p_in;
            let h_cav = h_total - h_in;
            if p_cav.abs() < 1e-300 {
                continue;
            }
            let out = scope.edge_data_mut(e);
            out.p = -a_ij * a_ij / p_cav;
            out.h = -a_ij * h_cav / p_cav;
        }

        let vd = scope.vertex_mut();
        vd.mean = new_mean;
        vd.precision = p_total;

        let moved = (new_mean - old_mean).abs();
        if moved > self.bound {
            for &u in scope.neighbors() {
                ctx.add_task(u, moved);
            }
        }
    }

    fn name(&self) -> &'static str {
        "gabp"
    }
}

/// Extract the current solution estimate (exclusive access).
pub fn solution(graph: &mut DataGraph<GabpVertex, GabpEdge>) -> Vec<f64> {
    (0..graph.num_vertices() as VertexId).map(|v| graph.vertex_data(v).mean).collect()
}

/// Reset the right-hand side (and optionally the diagonal) for a re-solve,
/// *keeping* the converged message state — the data-persistence trick of
/// Alg. 5's inner loop.
pub fn update_system(
    graph: &mut DataGraph<GabpVertex, GabpEdge>,
    diag: Option<&[f64]>,
    b: &[f64],
) {
    for v in 0..graph.num_vertices() as VertexId {
        let vd = graph.vertex_data(v);
        vd.b = b[v as usize];
        if let Some(d) = diag {
            vd.a_diag = d[v as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, ThreadedEngine};
    use crate::scheduler::{FifoScheduler, Scheduler, Task};
    use crate::sdt::Sdt;
    use crate::util::linalg::solve_dense;
    use crate::util::Pcg32;

    fn run_gabp(g: &mut DataGraph<GabpVertex, GabpEdge>, workers: usize) -> u64 {
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = GabpUpdate::new(1e-10);
        Program::new()
            .update_fn(&upd)
            .workers(workers)
            .model(ConsistencyModel::Edge)
            .max_updates(500_000)
            .run_on(&ThreadedEngine, g, &sched, &sdt)
            .updates
    }

    /// Random diagonally-dominant sparse symmetric system.
    fn random_system(
        n: usize,
        extra_edges: usize,
        seed: u64,
    ) -> (Vec<f64>, Vec<f64>, Vec<(u32, u32, f64)>) {
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut off = Vec::new();
        let mut seen = std::collections::HashSet::new();
        // chain for connectivity + random extras
        for i in 0..n - 1 {
            off.push((i as u32, (i + 1) as u32, rng.range_f64(-1.0, 1.0)));
            seen.insert((i as u32, (i + 1) as u32));
        }
        while off.len() < n - 1 + extra_edges {
            let i = rng.gen_range(n as u32);
            let j = rng.gen_range(n as u32);
            if i == j {
                continue;
            }
            let key = (i.min(j), i.max(j));
            if seen.insert(key) {
                off.push((key.0, key.1, rng.range_f64(-1.0, 1.0)));
            }
        }
        // diagonal dominance: A_ii > Σ|A_ij|
        let mut row_sum = vec![0.0f64; n];
        for &(i, j, a) in &off {
            row_sum[i as usize] += a.abs();
            row_sum[j as usize] += a.abs();
        }
        let diag: Vec<f64> = row_sum.iter().map(|s| s + 1.0 + rng.next_f64()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
        (diag, b, off)
    }

    fn dense_from(diag: &[f64], off: &[(u32, u32, f64)]) -> Vec<f64> {
        let n = diag.len();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            a[i * n + i] = diag[i];
        }
        for &(i, j, v) in off {
            a[i as usize * n + j as usize] = v;
            a[j as usize * n + i as usize] = v;
        }
        a
    }

    #[test]
    fn solves_diagonal_system_exactly() {
        let diag = vec![2.0, 4.0, 8.0];
        let b = vec![2.0, 8.0, 4.0];
        let mut g = build_system(&diag, &b, &[]);
        run_gabp(&mut g, 1);
        let x = solution(&mut g);
        assert_eq!(x, vec![1.0, 2.0, 0.5]);
    }

    #[test]
    fn matches_dense_solver_on_tree() {
        // GaBP is exact on trees
        let (diag, b, _) = random_system(8, 0, 1);
        let off: Vec<(u32, u32, f64)> =
            (0..7).map(|i| (i as u32, i as u32 + 1, 0.5 + 0.1 * i as f64)).collect();
        let mut g = build_system(&diag, &b, &off);
        run_gabp(&mut g, 2);
        let x = solution(&mut g);
        let x_ref = solve_dense(&dense_from(&diag, &off), &b);
        for (got, want) in x.iter().zip(&x_ref) {
            assert!((got - want).abs() < 1e-6, "{x:?} vs {x_ref:?}");
        }
    }

    #[test]
    fn converges_on_loopy_dd_system() {
        let (diag, b, off) = random_system(40, 60, 9);
        let mut g = build_system(&diag, &b, &off);
        let updates = run_gabp(&mut g, 4);
        assert!(updates < 500_000, "converged before cap");
        let x = solution(&mut g);
        let x_ref = solve_dense(&dense_from(&diag, &off), &b);
        for (i, (got, want)) in x.iter().zip(&x_ref).enumerate() {
            assert!((got - want).abs() < 1e-4, "x[{i}]: {got} vs {want}");
        }
    }

    #[test]
    fn warm_restart_is_cheaper_than_cold() {
        let (diag, b, off) = random_system(60, 80, 17);
        let mut g = build_system(&diag, &b, &off);
        let cold = run_gabp(&mut g, 2);
        // perturb rhs slightly, keep message state (data persistence, Alg 5)
        let b2: Vec<f64> = b.iter().map(|x| x + 0.01).collect();
        update_system(&mut g, None, &b2);
        let warm = run_gabp(&mut g, 2);
        assert!(
            warm < cold,
            "warm restart ({warm} updates) should beat cold start ({cold})"
        );
        // and it still solves the perturbed system
        let x = solution(&mut g);
        let x_ref = solve_dense(&dense_from(&diag, &off), &b2);
        for (got, want) in x.iter().zip(&x_ref) {
            assert!((got - want).abs() < 1e-4);
        }
    }
}
