//! **CoEM** — semi-supervised named-entity recognition (paper §4.3, Fig. 6).
//!
//! The graph is bipartite: noun phrases (NP) and contexts (CT) are vertices,
//! edges carry co-occurrence counts. Each vertex holds a belief over entity
//! classes; the update recomputes the belief as the weighted average of the
//! adjacent vertices' beliefs and re-schedules the neighbors when the belief
//! moved more than a threshold (paper: 1e-5). Seed vertices are pinned.
//!
//! The update "is relatively fast, requiring only a few floating point
//! operations" — it stresses scheduler overhead, which is why the paper runs
//! it with the relaxed MultiQueue FIFO / Partitioned schedulers, and uses
//! vertex consistency (racy neighbor reads are benign for this fixed-point
//! iteration).

use crate::consistency::Scope;
use crate::engine::{UpdateContext, UpdateFn};
use crate::util::stats::l1_distance;

/// Vertex: NP or CT entity with a class-probability estimate.
#[derive(Debug, Clone)]
pub struct CoemVertex {
    /// Belief over entity classes (length = #classes, sums to 1).
    pub belief: Vec<f32>,
    /// Seed vertices keep their label fixed (the supervised anchors).
    pub seed: bool,
    /// True for noun phrases, false for contexts.
    pub is_np: bool,
}

impl CoemVertex {
    pub fn unlabeled(classes: usize, is_np: bool) -> CoemVertex {
        CoemVertex { belief: vec![1.0 / classes as f32; classes], seed: false, is_np }
    }

    pub fn seeded(classes: usize, label: usize, is_np: bool) -> CoemVertex {
        let mut belief = vec![0.0; classes];
        belief[label] = 1.0;
        CoemVertex { belief, seed: true, is_np }
    }
}

/// Edge: NP–CT co-occurrence count.
#[derive(Debug, Clone, Copy)]
pub struct CoemEdge {
    pub weight: f32,
}

/// The CoEM update function.
pub struct CoemUpdate {
    pub classes: usize,
    /// Reschedule neighbors when the belief moves more than this (1e-5).
    pub threshold: f32,
}

impl CoemUpdate {
    pub fn new(classes: usize) -> CoemUpdate {
        CoemUpdate { classes, threshold: 1e-5 }
    }
}

impl UpdateFn<CoemVertex, CoemEdge> for CoemUpdate {
    fn update(&self, scope: &mut Scope<'_, CoemVertex, CoemEdge>, ctx: &mut UpdateContext<'_>) {
        if scope.vertex().seed {
            return; // labels of seed vertices are fixed
        }
        let mut new_belief = vec![0.0f32; self.classes];
        let mut total_w = 0.0f32;
        for &e in scope.out_edges() {
            let u = scope.edge(e).dst;
            let w = scope.edge_data(e).weight;
            let nb = &scope.neighbor(u).belief;
            for (nbf, b) in new_belief.iter_mut().zip(nb) {
                *nbf += w * *b;
            }
            total_w += w;
        }
        if total_w <= 0.0 {
            return;
        }
        for b in new_belief.iter_mut() {
            *b /= total_w;
        }
        let moved = l1_distance(&new_belief, &scope.vertex().belief);
        // In-place write (not a Vec replacement): under the vertex model
        // neighbors read this buffer concurrently — the paper's contract
        // tolerates *value* races, but the storage must stay stable.
        scope.vertex_mut().belief.copy_from_slice(&new_belief);
        if moved > self.threshold {
            for &u in scope.neighbors() {
                ctx.add_task(u, moved as f64);
            }
        }
    }

    fn name(&self) -> &'static str {
        "coem"
    }
}

/// L1 distance of all beliefs to a reference fixed point — the Fig 6c
/// quality metric ("L1 parameter distance to an empirical estimate of the
/// fixed point x*").
pub fn belief_distance(
    graph: &mut crate::graph::DataGraph<CoemVertex, CoemEdge>,
    reference: &[Vec<f32>],
) -> f64 {
    let mut total = 0.0f64;
    for v in 0..graph.num_vertices() as u32 {
        total += l1_distance(&graph.vertex_data(v).belief, &reference[v as usize]) as f64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, ThreadedEngine};
    use crate::graph::{DataGraph, GraphBuilder};
    use crate::scheduler::{MultiQueueFifo, Scheduler, Task};
    use crate::sdt::Sdt;

    /// Tiny bipartite instance: NP {0: seed class 0, 1}, CT {2, 3}.
    fn tiny() -> DataGraph<CoemVertex, CoemEdge> {
        let mut b = GraphBuilder::new();
        b.add_vertex(CoemVertex::seeded(2, 0, true)); // 0: seed NP
        b.add_vertex(CoemVertex::unlabeled(2, true)); // 1: NP
        b.add_vertex(CoemVertex::unlabeled(2, false)); // 2: CT
        b.add_vertex(CoemVertex::unlabeled(2, false)); // 3: CT
        let w = |w: f32| CoemEdge { weight: w };
        b.add_undirected(0, 2, w(3.0), w(3.0));
        b.add_undirected(1, 2, w(1.0), w(1.0));
        b.add_undirected(1, 3, w(1.0), w(1.0));
        b.add_undirected(0, 3, w(2.0), w(2.0));
        b.build()
    }

    fn run(g: &mut DataGraph<CoemVertex, CoemEdge>, workers: usize) -> u64 {
        let n = g.num_vertices();
        let sched = MultiQueueFifo::new(n, workers);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = CoemUpdate::new(2);
        let report = Program::new()
            .update_fn(&upd)
            .workers(workers)
            .model(ConsistencyModel::Vertex)
            .max_updates(1_000_000)
            .run_on(&ThreadedEngine, g, &sched, &sdt);
        report.updates
    }

    #[test]
    fn seed_propagates_labels() {
        let mut g = tiny();
        let updates = run(&mut g, 2);
        assert!(updates >= 4);
        // everything should converge to class 0 (the only seed)
        for v in 1..4u32 {
            let b = &g.vertex_data(v).belief;
            assert!(b[0] > 0.99, "vertex {v}: {b:?}");
        }
        // seed itself untouched
        assert_eq!(g.vertex_data(0).belief[0], 1.0);
    }

    #[test]
    fn converges_and_terminates() {
        let mut g = tiny();
        let updates = run(&mut g, 1);
        assert!(updates < 1_000_000, "must converge, used {updates}");
    }

    #[test]
    fn competing_seeds_split_mass() {
        let mut b = GraphBuilder::new();
        b.add_vertex(CoemVertex::seeded(2, 0, true)); // class 0 seed
        b.add_vertex(CoemVertex::seeded(2, 1, true)); // class 1 seed
        b.add_vertex(CoemVertex::unlabeled(2, false)); // CT between them
        let w = |x: f32| CoemEdge { weight: x };
        b.add_undirected(0, 2, w(1.0), w(1.0));
        b.add_undirected(1, 2, w(3.0), w(3.0));
        let mut g = b.build();
        run(&mut g, 2);
        let belief = g.vertex_data(2).belief.clone();
        // class 1 has 3x the evidence
        assert!((belief[1] - 0.75).abs() < 1e-4, "{belief:?}");
    }

    #[test]
    fn belief_distance_zero_at_fixed_point() {
        let mut g = tiny();
        run(&mut g, 1);
        let reference: Vec<Vec<f32>> =
            (0..4u32).map(|v| g.vertex_data(v).belief.clone()).collect();
        assert_eq!(belief_distance(&mut g, &reference), 0.0);
    }
}
