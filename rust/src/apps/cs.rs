//! **Compressed sensing** by an interior-point / Newton outer loop with
//! GaBP inner solves (paper §4.5, Alg. 5; Kim et al. 2007) — GraphLab as a
//! subcomponent of a larger *sequential* algorithm.
//!
//! Problem: recover sparse wavelet coefficients `w` from random linear
//! measurements `y = M w` by minimizing the elastic-net-regularized
//! objective (the paper: "sparsity is achieved through the use of elastic
//! net regularization")
//!
//! ```text
//! f(w) = ‖Mw − y‖² + λ Σ_i sqrt(w_i² + ε) + (ρ/2)‖w‖²
//! ```
//!
//! (the `sqrt(w²+ε)` term is the standard smoothed L1 barrier of the
//! interior-point formulation). The double loop of Alg. 5:
//!
//! * **outer (sequential)**: assemble the Newton system `H d = −g`,
//!   update the *persistent* GaBP data graph (structure never changes:
//!   `H`'s sparsity is the co-occurrence pattern of `MᵀM`), take a
//!   backtracking Newton step, and compute the **duality gap** of the
//!   underlying L1 problem for termination;
//! * **inner (GraphLab)**: GaBP solves the sparse SPD system, warm-started
//!   from the previous iteration's converged messages (data persistence).
//!
//! GaBP convergence note: `H` is made strictly diagonally dominant by
//! diagonal loading (`H_ii ← max(H_ii, 1.05·Σ_j|H_ij|)`), a standard
//! modified-Newton device — directions remain descent directions; see
//! DESIGN.md §Testbed-substitutions.

use super::gabp::{build_system, solution, GabpEdge, GabpVertex};
use crate::graph::DataGraph;
use crate::util::linalg::{norm1, norm_inf};
use std::collections::HashMap;

/// A compressed-sensing instance: sparse measurement matrix + observations.
pub struct CsProblem {
    /// Number of coefficients (variables).
    pub n: usize,
    /// Sparse measurement rows: `rows[m]` lists `(i, M_{m,i})`.
    pub rows: Vec<Vec<(u32, f32)>>,
    /// Observations y.
    pub y: Vec<f64>,
    /// L1 strength λ.
    pub lambda: f64,
    /// Ridge strength ρ (elastic net).
    pub rho: f64,
    /// L1 smoothing ε.
    pub eps: f64,
}

impl CsProblem {
    /// `M w`.
    pub fn forward(&self, w: &[f64]) -> Vec<f64> {
        self.rows
            .iter()
            .map(|row| row.iter().map(|&(i, x)| x as f64 * w[i as usize]).sum())
            .collect()
    }

    /// `Mᵀ v`.
    pub fn adjoint(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        for (row, &vm) in self.rows.iter().zip(v) {
            for &(i, x) in row {
                out[i as usize] += x as f64 * vm;
            }
        }
        out
    }

    /// Full smoothed objective f(w).
    pub fn objective(&self, w: &[f64]) -> f64 {
        let r: f64 = self
            .forward(w)
            .iter()
            .zip(&self.y)
            .map(|(p, y)| (p - y) * (p - y))
            .sum();
        let l1s: f64 = w.iter().map(|x| (x * x + self.eps).sqrt()).sum();
        let ridge: f64 = w.iter().map(|x| x * x).sum();
        r + self.lambda * l1s + 0.5 * self.rho * ridge
    }

    /// Gradient of the smoothed objective.
    pub fn gradient(&self, w: &[f64]) -> Vec<f64> {
        let r: Vec<f64> =
            self.forward(w).iter().zip(&self.y).map(|(p, y)| p - y).collect();
        let mut g = self.adjoint(&r);
        for (gi, &wi) in g.iter_mut().zip(w) {
            *gi = 2.0 * *gi + self.lambda * wi / (wi * wi + self.eps).sqrt() + self.rho * wi;
        }
        g
    }

    /// Duality gap of the underlying L1-regularized LS problem
    /// (Kim et al. 2007): ν = 2(Mw−y) scaled into the dual-feasible set;
    /// gap = ‖Mw−y‖² + λ‖w‖₁ − G(ν), G(ν) = −¼‖ν‖² − νᵀy.
    pub fn duality_gap(&self, w: &[f64]) -> f64 {
        let r: Vec<f64> =
            self.forward(w).iter().zip(&self.y).map(|(p, y)| p - y).collect();
        let nu: Vec<f64> = r.iter().map(|x| 2.0 * x).collect();
        let mtv = self.adjoint(&nu);
        let inf = norm_inf(&mtv);
        let s = if inf > self.lambda { self.lambda / inf } else { 1.0 };
        let nu_s: Vec<f64> = nu.iter().map(|x| s * x).collect();
        let g_dual: f64 = -0.25 * nu_s.iter().map(|x| x * x).sum::<f64>()
            - nu_s.iter().zip(&self.y).map(|(a, b)| a * b).sum::<f64>();
        let primal: f64 = r.iter().map(|x| x * x).sum::<f64>() + self.lambda * norm1(w);
        primal - g_dual
    }
}

/// Statistics of one [`CsSolver::solve`] run.
#[derive(Debug, Clone)]
pub struct CsStats {
    pub outer_iterations: usize,
    pub inner_updates: u64,
    pub final_gap: f64,
    pub final_objective: f64,
    /// (gap, objective) after each outer iteration.
    pub history: Vec<(f64, f64)>,
}

/// The interior-point solver: owns the persistent GaBP graph for `H`.
pub struct CsSolver {
    pub problem: CsProblem,
    pub graph: DataGraph<GabpVertex, GabpEdge>,
    pub w: Vec<f64>,
    /// Base diagonal of 2MᵀM.
    base_diag: Vec<f64>,
    /// Σ_j |H_ij| per row (for diagonal loading).
    offdiag_rowsum: Vec<f64>,
}

impl CsSolver {
    /// Build the persistent GaBP graph from the sparsity of `2MᵀM`.
    pub fn new(problem: CsProblem) -> CsSolver {
        let n = problem.n;
        let mut base_diag = vec![0.0f64; n];
        let mut pairs: HashMap<(u32, u32), f64> = HashMap::new();
        for row in &problem.rows {
            for (a, &(i, xi)) in row.iter().enumerate() {
                base_diag[i as usize] += 2.0 * (xi as f64) * (xi as f64);
                for &(j, xj) in &row[a + 1..] {
                    let key = (i.min(j), i.max(j));
                    *pairs.entry(key).or_insert(0.0) += 2.0 * (xi as f64) * (xj as f64);
                }
            }
        }
        let off: Vec<(u32, u32, f64)> = pairs
            .into_iter()
            .filter(|&(_, v)| v.abs() > 1e-12)
            .map(|((i, j), v)| (i, j, v))
            .collect();
        let mut offdiag_rowsum = vec![0.0f64; n];
        for &(i, j, v) in &off {
            offdiag_rowsum[i as usize] += v.abs();
            offdiag_rowsum[j as usize] += v.abs();
        }
        let graph = build_system(&base_diag, &vec![0.0; n], &off);
        CsSolver { problem, graph, w: vec![0.0; n], base_diag, offdiag_rowsum }
    }

    /// Load the Newton system for the current iterate into the GaBP graph:
    /// diagonal = barrier-augmented (and loaded) H_ii, rhs = −g.
    pub fn prepare_newton(&mut self) {
        let g = self.problem.gradient(&self.w);
        for v in 0..self.problem.n {
            let wi = self.w[v];
            let barrier = self.problem.lambda * self.problem.eps
                / (wi * wi + self.problem.eps).powf(1.5)
                + self.problem.rho;
            let h_ii = self.base_diag[v] + barrier;
            // diagonal loading => strict diagonal dominance => GaBP converges
            let loaded = h_ii.max(1.05 * self.offdiag_rowsum[v] + 1e-9);
            let vd = self.graph.vertex_data(v as u32);
            vd.a_diag = loaded;
            vd.b = -g[v];
        }
    }

    /// Read the GaBP solution as the Newton direction and take a
    /// backtracking step. Returns the accepted step length (0 = no progress).
    pub fn apply_direction(&mut self) -> f64 {
        let d = solution(&mut self.graph);
        let f0 = self.problem.objective(&self.w);
        // Diagonal loading shortens the Newton direction; search from an
        // overshoot so the accepted step recovers the lost length.
        let mut alpha = 32.0f64;
        for _ in 0..36 {
            let cand: Vec<f64> =
                self.w.iter().zip(&d).map(|(w, di)| w + alpha * di).collect();
            if self.problem.objective(&cand) < f0 {
                self.w = cand;
                return alpha;
            }
            alpha *= 0.5;
        }
        0.0
    }

    /// Full Alg. 5 loop with the engine as the inner solver.
    pub fn solve(&mut self, workers: usize, max_outer: usize, gap_tol: f64) -> CsStats {
        use crate::consistency::{ConsistencyModel, LockTable};
        use crate::engine::Program;
        use crate::scheduler::RoundRobinScheduler;
        use crate::sdt::Sdt;

        let n = self.problem.n;
        // One lock table reused across all outer iterations: the graph is
        // fixed, and rebuilding n lock words per Newton step is pure waste.
        let locks = LockTable::new(n);
        let sdt = Sdt::new();
        let upd = super::gabp::GabpUpdate::new(1e-9);
        let program = Program::new()
            .update_fn(&upd)
            .workers(workers)
            .model(ConsistencyModel::Edge);
        let mut stats = CsStats {
            outer_iterations: 0,
            inner_updates: 0,
            final_gap: f64::INFINITY,
            final_objective: f64::INFINITY,
            history: Vec::new(),
        };
        for _ in 0..max_outer {
            self.prepare_newton();
            // round-robin sweeps (the paper's §4.5 scheduling choice), warm
            // messages persisted from the previous outer iteration.
            let sched = RoundRobinScheduler::new(n, 60);
            let report = program.run_with_locks(&self.graph, &locks, &sched, &sdt);
            stats.inner_updates += report.updates;
            self.apply_direction();
            stats.outer_iterations += 1;
            let gap = self.problem.duality_gap(&self.w);
            let obj = self.problem.objective(&self.w);
            sdt.set("duality_gap", gap);
            stats.history.push((gap, obj));
            stats.final_gap = gap;
            stats.final_objective = obj;
            if gap <= gap_tol {
                break;
            }
        }
        stats
    }
}

/// Generate a sparse random measurement ensemble: `m` rows, each sampling
/// `per_row` distinct coefficients with ±1/√per_row entries.
pub fn sparse_measurements(
    n: usize,
    m: usize,
    per_row: usize,
    rng: &mut crate::util::Pcg32,
) -> Vec<Vec<(u32, f32)>> {
    let scale = 1.0 / (per_row as f32).sqrt();
    (0..m)
        .map(|_| {
            let mut idx = std::collections::HashSet::new();
            while idx.len() < per_row.min(n) {
                idx.insert(rng.gen_range(n as u32));
            }
            idx.into_iter()
                .map(|i| (i, if rng.next_u32() & 1 == 1 { scale } else { -scale }))
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn small_problem(seed: u64) -> (CsProblem, Vec<f64>) {
        let mut rng = Pcg32::seed_from_u64(seed);
        let n = 64;
        // sparse ground truth
        let mut w_true = vec![0.0f64; n];
        for _ in 0..6 {
            w_true[rng.gen_range(n as u32) as usize] = rng.range_f64(-2.0, 2.0);
        }
        let rows = sparse_measurements(n, 96, 6, &mut rng);
        let y = CsProblem { n, rows: rows.clone(), y: vec![], lambda: 0.0, rho: 0.0, eps: 1.0 }
            .forward(&w_true);
        let problem = CsProblem { n, rows, y, lambda: 0.05, rho: 0.01, eps: 1e-6 };
        (problem, w_true)
    }

    #[test]
    fn forward_adjoint_consistency() {
        let (p, _) = small_problem(1);
        let mut rng = Pcg32::seed_from_u64(99);
        let w: Vec<f64> = (0..p.n).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..p.rows.len()).map(|_| rng.next_gaussian()).collect();
        // <Mw, v> == <w, Mᵀv>
        let lhs: f64 = p.forward(&w).iter().zip(&v).map(|(a, b)| a * b).sum();
        let rhs: f64 = p.adjoint(&v).iter().zip(&w).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()));
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (p, _) = small_problem(2);
        let mut rng = Pcg32::seed_from_u64(5);
        let w: Vec<f64> = (0..p.n).map(|_| 0.3 * rng.next_gaussian()).collect();
        let g = p.gradient(&w);
        let h = 1e-6;
        for i in [0usize, 7, 33, 63] {
            let mut wp = w.clone();
            wp[i] += h;
            let mut wm = w.clone();
            wm[i] -= h;
            let fd = (p.objective(&wp) - p.objective(&wm)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4 * (1.0 + fd.abs()), "coord {i}: {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn solver_reduces_gap_and_recovers_signal() {
        let (p, w_true) = small_problem(3);
        let mut solver = CsSolver::new(p);
        let stats = solver.solve(2, 15, 1e-3);
        assert!(stats.outer_iterations >= 1);
        // gap decreases over iterations (monotone-ish: check first vs last)
        assert!(
            stats.final_gap < stats.history[0].0,
            "gap history: {:?}",
            stats.history
        );
        // recovered signal close to ground truth
        let err: f64 = solver
            .w
            .iter()
            .zip(&w_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let scale: f64 = w_true.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(err / scale < 0.25, "relative error {}", err / scale);
    }

    #[test]
    fn duality_gap_nonnegative_and_small_at_optimum() {
        let (p, _) = small_problem(4);
        let mut solver = CsSolver::new(p);
        let stats = solver.solve(1, 25, 1e-4);
        assert!(stats.final_gap >= -1e-9, "gap must be ≥ 0: {}", stats.final_gap);
        // the smoothed/elastic-net optimum leaves a small residual L1 gap;
        // require an order-of-magnitude reduction from the first iterate.
        assert!(
            stats.final_gap < 0.5 && stats.final_gap < 0.2 * stats.history[0].0.max(1e-9),
            "should approach optimality: {} (initial {})",
            stats.final_gap,
            stats.history[0].0
        );
    }
}
