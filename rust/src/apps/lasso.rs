//! The **Shooting algorithm** for the Lasso (paper §4.4, Alg. 4; Fu 1998):
//! coordinate descent on `L(w) = Σ_j (wᵀx_j − y_j)² + λ‖w‖₁`.
//!
//! GraphLab formulation: a bipartite graph with a vertex per weight `w_i`
//! and per observation `y_j`, and an edge `(w_i, y_j)` with weight `X_{j,i}`
//! wherever the design matrix is non-zero. The update function runs on
//! weight vertices only and performs one exact coordinate minimization;
//! when the weight moves it revises the residuals on the adjacent
//! observation vertices (a *neighbor write* — sequentially consistent only
//! under the **full consistency** model, Prop. 3.1 cond. 1) and schedules
//! the two-hop weight vertices.
//!
//! The paper's experiment: full consistency gives an automatically
//! parallelized *sequentially consistent* shooting algorithm; relaxing to
//! **vertex consistency** is no longer provably safe yet "still converges,
//! obtaining solutions with only 0.5% higher loss" — Fig 7 measures both.

use crate::consistency::Scope;
use crate::engine::{UpdateContext, UpdateFn};
use crate::graph::{DataGraph, GraphBuilder, VertexId};
use crate::util::linalg::soft_threshold;

/// Bipartite vertex: a weight coordinate or an observation.
#[derive(Debug, Clone)]
pub enum LassoVertex {
    Weight {
        /// Current value w_i.
        w: f32,
        /// Cached a_i = Σ_j X_{j,i}² (constant).
        a: f32,
    },
    Obs {
        /// Target y_j.
        y: f32,
        /// Current residual r_j = y_j − x_jᵀ w.
        residual: f32,
    },
}

impl LassoVertex {
    pub fn weight(&self) -> f32 {
        match self {
            LassoVertex::Weight { w, .. } => *w,
            _ => panic!("not a weight vertex"),
        }
    }
    pub fn residual(&self) -> f32 {
        match self {
            LassoVertex::Obs { residual, .. } => *residual,
            _ => panic!("not an observation vertex"),
        }
    }
}

/// Edge: the design-matrix entry X_{j,i} between weight i and observation j.
#[derive(Debug, Clone, Copy)]
pub struct LassoEdge {
    pub x: f32,
}

/// A Lasso problem instance as a GraphLab graph. Weight vertices come first
/// (ids `0..d`), observation vertices after (ids `d..d+n`).
pub struct LassoProblem {
    pub graph: DataGraph<LassoVertex, LassoEdge>,
    pub num_weights: usize,
    pub num_obs: usize,
}

impl LassoProblem {
    /// Build from a sparse design matrix: `rows[j]` lists `(i, X_{j,i})` for
    /// observation j with target `y[j]`.
    pub fn from_sparse(d: usize, rows: &[Vec<(u32, f32)>], y: &[f32]) -> LassoProblem {
        assert_eq!(rows.len(), y.len());
        let n = rows.len();
        let mut a = vec![0.0f32; d];
        for row in rows {
            for &(i, x) in row {
                a[i as usize] += x * x;
            }
        }
        let mut b: GraphBuilder<LassoVertex, LassoEdge> = GraphBuilder::with_capacity(d + n, 0);
        for &ai in a.iter().take(d) {
            b.add_vertex(LassoVertex::Weight { w: 0.0, a: ai });
        }
        for &yj in y {
            b.add_vertex(LassoVertex::Obs { y: yj, residual: yj });
        }
        for (j, row) in rows.iter().enumerate() {
            let obs = (d + j) as VertexId;
            for &(i, x) in row {
                assert!((i as usize) < d);
                b.add_undirected(i, obs, LassoEdge { x }, LassoEdge { x });
            }
        }
        LassoProblem { graph: b.build(), num_weights: d, num_obs: n }
    }

    /// Current objective `Σ r_j² + λ‖w‖₁` (exclusive access).
    pub fn loss(&mut self, lambda: f32) -> f64 {
        let mut loss = 0.0f64;
        for v in 0..self.graph.num_vertices() as u32 {
            match self.graph.vertex_data(v) {
                LassoVertex::Weight { w, .. } => loss += lambda as f64 * w.abs() as f64,
                LassoVertex::Obs { residual, .. } => {
                    loss += (*residual as f64) * (*residual as f64)
                }
            }
        }
        loss
    }

    /// Extract the weight vector.
    pub fn weights(&mut self) -> Vec<f32> {
        (0..self.num_weights as u32).map(|v| self.graph.vertex_data(v).weight()).collect()
    }
}

/// The shooting update (Alg. 4). Runs on weight vertices; no-op on
/// observation vertices (guarded, so sweep schedulers over all vertices are
/// also safe).
pub struct ShootingUpdate {
    pub lambda: f32,
    /// Movement threshold ε below which the update is considered converged.
    pub epsilon: f32,
}

impl ShootingUpdate {
    pub fn new(lambda: f32) -> ShootingUpdate {
        ShootingUpdate { lambda, epsilon: 1e-5 }
    }
}

impl UpdateFn<LassoVertex, LassoEdge> for ShootingUpdate {
    fn update(&self, scope: &mut Scope<'_, LassoVertex, LassoEdge>, ctx: &mut UpdateContext<'_>) {
        let (w_old, a) = match scope.vertex() {
            LassoVertex::Weight { w, a } => (*w, *a),
            LassoVertex::Obs { .. } => return,
        };
        if a <= 0.0 {
            return; // unused feature
        }
        // ρ = Σ_j X_{j,i} (r_j + X_{j,i} w_i): correlation with the partial
        // residual that excludes w_i's own contribution.
        let mut rho = 0.0f32;
        for &e in scope.out_edges() {
            let obs = scope.edge(e).dst;
            let x = scope.edge_data(e).x;
            rho += x * (scope.neighbor(obs).residual() + x * w_old);
        }
        // minimize r² term + λ|w_i|: w = soft(ρ, λ/2) / a
        let w_new = soft_threshold(rho as f64, self.lambda as f64 / 2.0) as f32 / a;
        let delta = w_new - w_old;
        if delta.abs() <= self.epsilon {
            return;
        }
        match scope.vertex_mut() {
            LassoVertex::Weight { w, .. } => *w = w_new,
            _ => unreachable!(),
        }
        // Revise residuals on adjacent observations (neighbor writes: needs
        // full consistency for sequential consistency) and schedule the
        // two-hop weights (Alg. 4).
        for &e in scope.out_edges().to_vec().iter() {
            let obs = scope.edge(e).dst;
            let x = scope.edge_data(e).x;
            match scope.neighbor_mut(obs) {
                LassoVertex::Obs { residual, .. } => *residual -= x * delta,
                _ => unreachable!("weight connected to weight"),
            }
            for &w2 in scope.neighbors_of(obs) {
                if w2 != scope.center() {
                    ctx.add_task(w2, delta.abs() as f64);
                }
            }
        }
        // keep refining this coordinate while it moves
        ctx.add_task(scope.center(), delta.abs() as f64);
    }

    fn name(&self) -> &'static str {
        "shooting"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, ThreadedEngine};
    use crate::scheduler::{FifoScheduler, Scheduler, Task};
    use crate::sdt::Sdt;
    use crate::util::linalg::{matvec, solve_dense};
    use crate::util::Pcg32;

    fn run_shooting(
        p: &mut LassoProblem,
        lambda: f32,
        model: ConsistencyModel,
        workers: usize,
    ) -> u64 {
        let n = p.graph.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..p.num_weights as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ShootingUpdate::new(lambda);
        let report = Program::new()
            .update_fn(&upd)
            .workers(workers)
            .model(model)
            .max_updates(2_000_000)
            .run_on(&ThreadedEngine, &mut p.graph, &sched, &sdt);
        report.updates
    }

    /// Random (n x d) dense problem as sparse rows.
    fn random_problem(n: usize, d: usize, seed: u64) -> (LassoProblem, Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut rows = Vec::new();
        let mut dense_rows = Vec::new();
        let mut y = Vec::new();
        let w_true: Vec<f64> = (0..d).map(|i| if i % 3 == 0 { 1.5 } else { 0.0 }).collect();
        for _ in 0..n {
            let xs: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
            let target: f64 = xs.iter().zip(&w_true).map(|(x, w)| x * w).sum::<f64>()
                + 0.01 * rng.next_gaussian();
            rows.push(xs.iter().enumerate().map(|(i, &x)| (i as u32, x as f32)).collect());
            dense_rows.push(xs);
            y.push(target);
        }
        let prob =
            LassoProblem::from_sparse(d, &rows, &y.iter().map(|&v| v as f32).collect::<Vec<_>>());
        (prob, dense_rows, y)
    }

    #[test]
    fn lambda_zero_recovers_least_squares() {
        let (prob, rows, y) = random_problem(24, 6, 3);
        let mut prob = prob;
        run_shooting(&mut prob, 0.0, ConsistencyModel::Full, 2);
        // normal equations: (XᵀX) w = Xᵀ y
        let d = 6;
        let mut xtx = vec![0.0f64; d * d];
        let mut xty = vec![0.0f64; d];
        for (row, &target) in rows.iter().zip(&y) {
            for i in 0..d {
                xty[i] += row[i] * target;
                for j in 0..d {
                    xtx[i * d + j] += row[i] * row[j];
                }
            }
        }
        let w_ls = solve_dense(&xtx, &xty);
        let w_got = prob.weights();
        for (g, e) in w_got.iter().zip(&w_ls) {
            assert!((*g as f64 - e).abs() < 1e-3, "{w_got:?} vs {w_ls:?}");
        }
    }

    #[test]
    fn huge_lambda_zeroes_everything() {
        let (prob, _, _) = random_problem(20, 5, 7);
        let mut prob = prob;
        run_shooting(&mut prob, 1e6, ConsistencyModel::Full, 1);
        for w in prob.weights() {
            assert_eq!(w, 0.0);
        }
        // residuals must equal y (w = 0)
        for j in 0..prob.num_obs as u32 {
            let v = prob.num_weights as u32 + j;
            match prob.graph.vertex_data(v) {
                LassoVertex::Obs { y, residual } => assert!((*y - *residual).abs() < 1e-5),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn sparsity_increases_with_lambda() {
        let (mut p1, _, _) = random_problem(40, 12, 11);
        run_shooting(&mut p1, 0.5, ConsistencyModel::Full, 2);
        let nz_small = p1.weights().iter().filter(|w| w.abs() > 1e-6).count();
        let (mut p2, _, _) = random_problem(40, 12, 11);
        run_shooting(&mut p2, 50.0, ConsistencyModel::Full, 2);
        let nz_large = p2.weights().iter().filter(|w| w.abs() > 1e-6).count();
        assert!(nz_large <= nz_small, "{nz_large} > {nz_small}");
    }

    #[test]
    fn vertex_consistency_converges_close_to_full() {
        // the paper's §4.4 relaxation experiment: vertex consistency still
        // converges, with loss within a fraction of a percent.
        let (mut full, _, _) = random_problem(60, 16, 21);
        run_shooting(&mut full, 2.0, ConsistencyModel::Full, 4);
        let loss_full = full.loss(2.0);
        let (mut vtx, _, _) = random_problem(60, 16, 21);
        run_shooting(&mut vtx, 2.0, ConsistencyModel::Vertex, 4);
        let loss_vtx = vtx.loss(2.0);
        let rel = (loss_vtx - loss_full).abs() / loss_full.max(1e-12);
        assert!(rel < 0.02, "relaxed loss {loss_vtx} vs full {loss_full} (rel {rel})");
    }

    #[test]
    fn residual_invariant_holds_after_convergence() {
        let (mut prob, rows, _) = random_problem(30, 8, 5);
        run_shooting(&mut prob, 1.0, ConsistencyModel::Full, 2);
        let w: Vec<f64> = prob.weights().iter().map(|&x| x as f64).collect();
        for (j, row) in rows.iter().enumerate() {
            let pred: f64 = row.iter().zip(&w).map(|(x, wi)| x * wi).sum();
            let v = (prob.num_weights + j) as u32;
            match prob.graph.vertex_data(v) {
                LassoVertex::Obs { y, residual } => {
                    let expect = *y as f64 - pred;
                    assert!(
                        (*residual as f64 - expect).abs() < 1e-3,
                        "obs {j}: stored {residual}, expected {expect}"
                    );
                }
                _ => unreachable!(),
            }
        }
        let _ = matvec; // referenced to keep oracle helpers in scope
    }
}
