//! The paper's five case studies (§4), each written against the public
//! GraphLab API (data graph + update functions + sync + schedulers):
//!
//! * [`mrf`] / [`bp`] — pairwise Markov Random Fields and Loopy Belief
//!   Propagation (the running example; Alg. 2).
//! * [`learn`] — MRF parameter learning for 3-D retinal-scan denoising with
//!   simultaneous learning and inference (§4.1, Alg. 3, Fig. 4).
//! * [`coloring`] / [`gibbs`] — greedy parallel graph coloring and the
//!   chromatic (set-scheduled) parallel Gibbs sampler (§4.2, Fig. 5).
//! * [`coem`] — CoEM semi-supervised NER (§4.3, Fig. 6).
//! * [`lasso`] — the Shooting algorithm under full vs vertex consistency
//!   (§4.4, Alg. 4, Fig. 7).
//! * [`gabp`] — Gaussian Belief Propagation linear solver (Bickson 2008).
//! * [`cs`] / [`wavelet`] — compressed sensing by an interior-point outer
//!   loop with GaBP inner solves (§4.5, Alg. 5, Fig. 8).

pub mod bp;
pub mod coem;
pub mod coloring;
pub mod cs;
pub mod gabp;
pub mod gibbs;
pub mod lasso;
pub mod learn;
pub mod mrf;
pub mod wavelet;
