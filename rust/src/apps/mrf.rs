//! Pairwise Markov Random Fields over the data graph (paper §3, §4.1).
//!
//! Vertex data holds node potentials and the current belief; directed edge
//! data holds the BP message `m_{u->v}` — exactly the paper's mapping of
//! Loopy BP onto the GraphLab data model.

use crate::graph::{DataGraph, FlatVertex, GraphBuilder, VertexId};
use crate::util::Pcg32;
use std::sync::Arc;

/// Per-vertex BP state: unnormalized node potential and current belief,
/// plus the fields used by the parameter-learning pipeline (§4.1).
#[derive(Debug)]
pub struct BpVertex {
    /// Node potential φ_v(x) (length K).
    pub potential: Vec<f32>,
    /// Current belief b_v(x) (length K, normalized).
    pub belief: Vec<f32>,
    /// Observed (noisy) level for denoising tasks; u32::MAX = unobserved.
    pub observed: u32,
    /// Per-axis local smoothness statistic E|x_v - x_u| cached by the BP
    /// update for the learning sync (Alg. 3 folds over vertex data only).
    pub axis_stats: [f32; 3],
}

impl BpVertex {
    pub fn uniform(k: usize) -> BpVertex {
        BpVertex {
            potential: vec![1.0; k],
            belief: vec![1.0 / k as f32; k],
            observed: u32::MAX,
            axis_stats: [0.0; 3],
        }
    }

    pub fn with_potential(potential: Vec<f32>) -> BpVertex {
        let k = potential.len();
        BpVertex { potential, belief: vec![1.0 / k as f32; k], observed: u32::MAX, axis_stats: [0.0; 3] }
    }

    /// Expected level under the current belief.
    pub fn expectation(&self) -> f32 {
        self.belief.iter().enumerate().map(|(i, b)| i as f32 * b).sum()
    }
}

/// Manual `Clone` so `clone_from` reuses the destination's existing
/// `Vec` buffers — the ghost tables and the delta batcher capture vertex
/// state via `clone_from` on every boundary write, and with the derive
/// each capture would reallocate both distributions.
impl Clone for BpVertex {
    fn clone(&self) -> BpVertex {
        BpVertex {
            potential: self.potential.clone(),
            belief: self.belief.clone(),
            observed: self.observed,
            axis_stats: self.axis_stats,
        }
    }

    fn clone_from(&mut self, src: &BpVertex) {
        self.potential.clone_from(&src.potential);
        self.belief.clone_from(&src.belief);
        self.observed = src.observed;
        self.axis_stats = src.axis_stats;
    }
}

/// SoA view of a BP vertex: floats are `[potential(K), belief(K),
/// axis_stats(3)]`, words are `[observed]`. See
/// [`crate::graph::FlatVertexStore`].
impl FlatVertex for BpVertex {
    fn f32_lanes(arity: usize) -> usize {
        2 * arity + 3
    }

    fn u32_lanes(_arity: usize) -> usize {
        1
    }

    fn write_flat(&self, floats: &mut [f32], words: &mut [u32]) {
        let k = (floats.len() - 3) / 2;
        debug_assert_eq!(self.potential.len(), k);
        debug_assert_eq!(self.belief.len(), k);
        floats[..k].copy_from_slice(&self.potential);
        floats[k..2 * k].copy_from_slice(&self.belief);
        floats[2 * k..].copy_from_slice(&self.axis_stats);
        words[0] = self.observed;
    }

    fn read_flat(arity: usize, floats: &[f32], words: &[u32]) -> BpVertex {
        let mut axis_stats = [0.0f32; 3];
        axis_stats.copy_from_slice(&floats[2 * arity..2 * arity + 3]);
        BpVertex {
            potential: floats[..arity].to_vec(),
            belief: floats[arity..2 * arity].to_vec(),
            observed: words[0],
            axis_stats,
        }
    }
}

/// Shared K×K factor tables flattened into one contiguous `Arc<[f32]>`
/// slab plus an offset table. The nested `Arc<Vec<Vec<f32>>>` form costs
/// two pointer hops per ψ lookup (outer Vec, inner Vec) on the BP/Gibbs
/// inner loops; here a lookup is one offset add and one slab index.
#[derive(Debug, Clone)]
pub struct FlatTables {
    data: Arc<[f32]>,
    offsets: Vec<u32>,
    arity: usize,
}

impl FlatTables {
    /// Flatten row-major K×K `tables` (arity `arity`) into one slab.
    pub fn from_nested(tables: &[Vec<f32>], arity: usize) -> FlatTables {
        let mut offsets = Vec::with_capacity(tables.len() + 1);
        let mut data = Vec::with_capacity(tables.iter().map(Vec::len).sum());
        offsets.push(0u32);
        for t in tables {
            data.extend_from_slice(t);
            offsets.push(data.len() as u32);
        }
        FlatTables { data: data.into(), offsets, arity }
    }

    /// ψ(i, j) of table `t`: one offset add + one slab index.
    #[inline]
    pub fn at(&self, t: u32, i: usize, j: usize) -> f32 {
        self.data[self.offsets[t as usize] as usize + i * self.arity + j]
    }

    /// Borrow table `t` as its row-major K×K slice.
    pub fn table(&self, t: u32) -> &[f32] {
        &self.data[self.offsets[t as usize] as usize..self.offsets[t as usize + 1] as usize]
    }

    /// Number of tables.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    /// No tables at all (Laplace-only models)?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Edge potential family for a directed edge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EdgePotential {
    /// Laplace smoothing ψ(x_u, x_v) = exp(-λ_axis |x_u - x_v|); λ read from
    /// the SDT key `"lambda"` ([f64; 3]) — the learnable parameters of §4.1.
    Laplace { axis: u8 },
    /// Index into a shared table of K×K potentials (protein MRF etc.).
    Table(u32),
}

/// Per-directed-edge BP state.
#[derive(Debug, Clone)]
pub struct BpEdge {
    /// Message m_{src->dst}(x_dst), normalized (length K).
    pub message: Vec<f32>,
    pub potential: EdgePotential,
}

impl BpEdge {
    pub fn uniform(k: usize, potential: EdgePotential) -> BpEdge {
        BpEdge { message: vec![1.0 / k as f32; k], potential }
    }
}

/// A pairwise MRF: the data graph plus shared edge-potential tables.
pub struct Mrf {
    pub graph: DataGraph<BpVertex, BpEdge>,
    /// K×K row-major tables referenced by `EdgePotential::Table`.
    pub tables: Vec<Vec<f32>>,
    pub arity: usize,
}

/// Dimensions of a 3-D grid.
#[derive(Debug, Clone, Copy)]
pub struct GridDims {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
}

impl GridDims {
    pub fn new(nx: usize, ny: usize, nz: usize) -> GridDims {
        GridDims { nx, ny, nz }
    }
    pub fn len(&self) -> usize {
        self.nx * self.ny * self.nz
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    #[inline]
    pub fn index(&self, x: usize, y: usize, z: usize) -> VertexId {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        ((z * self.ny + y) * self.nx + x) as VertexId
    }
    #[inline]
    pub fn coords(&self, v: VertexId) -> (usize, usize, usize) {
        let v = v as usize;
        let x = v % self.nx;
        let y = (v / self.nx) % self.ny;
        let z = v / (self.nx * self.ny);
        (x, y, z)
    }
}

/// Build a 6-connected 3-D grid MRF with Laplace edge potentials labelled by
/// axis (x=0, y=1, z=2) and node potentials from `node_potential(v)`.
pub fn grid3d(dims: GridDims, k: usize, mut node_potential: impl FnMut(VertexId) -> Vec<f32>) -> Mrf {
    let n = dims.len();
    let mut b: GraphBuilder<BpVertex, BpEdge> = GraphBuilder::with_capacity(n, 6 * n);
    for v in 0..n as VertexId {
        let pot = node_potential(v);
        assert_eq!(pot.len(), k);
        b.add_vertex(BpVertex::with_potential(pot));
    }
    let mut link = |u: VertexId, v: VertexId, axis: u8| {
        b.add_undirected(
            u,
            v,
            BpEdge::uniform(k, EdgePotential::Laplace { axis }),
            BpEdge::uniform(k, EdgePotential::Laplace { axis }),
        );
    };
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            for x in 0..dims.nx {
                let v = dims.index(x, y, z);
                if x + 1 < dims.nx {
                    link(v, dims.index(x + 1, y, z), 0);
                }
                if y + 1 < dims.ny {
                    link(v, dims.index(x, y + 1, z), 1);
                }
                if z + 1 < dims.nz {
                    link(v, dims.index(x, y, z + 1), 2);
                }
            }
        }
    }
    Mrf { graph: b.build(), tables: Vec::new(), arity: k }
}

/// Build a random sparse MRF with tabular attractive/repulsive potentials —
/// the protein–protein-interaction-network stand-in (§4.2; see DESIGN.md).
/// `n` vertices, ~`m` undirected edges with a skewed (hub-heavy) degree
/// profile, arity `k`.
pub fn random_mrf(n: usize, m: usize, k: usize, rng: &mut Pcg32) -> Mrf {
    let mut b: GraphBuilder<BpVertex, BpEdge> = GraphBuilder::with_capacity(n, 2 * m);
    for _ in 0..n {
        let pot: Vec<f32> = (0..k).map(|_| 0.2 + rng.next_f32()).collect();
        b.add_vertex(BpVertex::with_potential(pot));
    }
    // A few shared tables: attractive (Potts-like) and repulsive.
    let mut tables = Vec::new();
    for t in 0..8 {
        let strength = 0.3 + 0.2 * (t as f32 % 4.0);
        let attract = t % 2 == 0;
        let mut tab = vec![0.0f32; k * k];
        for i in 0..k {
            for j in 0..k {
                let same = i == j;
                tab[i * k + j] = if same == attract { 1.0 } else { (1.0 - strength).max(0.05) };
            }
        }
        tables.push(tab);
    }
    // Skewed endpoints: hub-biased choice via zipf, with a degree cap —
    // real interaction networks have hubs in the tens, not hundreds, and
    // unbounded hubs would serialize edge-consistency scheduling in a way
    // the paper's graphs do not.
    let mut seen = std::collections::HashSet::new();
    let mut degree = vec![0usize; n];
    let cap = (8 * m / n).clamp(12, 64);
    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < m && attempts < m * 20 {
        attempts += 1;
        let u = rng.next_zipf(n, 0.8) as u32;
        let v = rng.gen_range(n as u32);
        if u == v || degree[u as usize] >= cap || degree[v as usize] >= cap {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if !seen.insert(key) {
            continue;
        }
        degree[u as usize] += 1;
        degree[v as usize] += 1;
        let t = rng.gen_range(tables.len() as u32);
        b.add_undirected(
            u,
            v,
            BpEdge::uniform(k, EdgePotential::Table(t)),
            BpEdge::uniform(k, EdgePotential::Table(t)),
        );
        added += 1;
    }
    Mrf { graph: b.build(), tables, arity: k }
}

/// Normalize a distribution in place (L1); uniform fallback on zero mass.
pub fn normalize(dist: &mut [f32]) {
    let total: f32 = dist.iter().sum();
    if total > 1e-30 {
        for d in dist.iter_mut() {
            *d /= total;
        }
    } else {
        let u = 1.0 / dist.len() as f32;
        dist.iter_mut().for_each(|d| *d = u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_dims_roundtrip() {
        let dims = GridDims::new(4, 3, 2);
        assert_eq!(dims.len(), 24);
        for v in 0..24u32 {
            let (x, y, z) = dims.coords(v);
            assert_eq!(dims.index(x, y, z), v);
        }
    }

    #[test]
    fn grid3d_structure() {
        let dims = GridDims::new(3, 3, 3);
        let mrf = grid3d(dims, 4, |_| vec![1.0; 4]);
        assert_eq!(mrf.graph.num_vertices(), 27);
        // 6-connectivity: 2*(edges) directed; edges = 3 * 2*3*3 axes... count:
        // x-edges: 2*3*3=18, y: 18, z: 18 => 54 undirected => 108 directed.
        assert_eq!(mrf.graph.num_edges(), 108);
        // center vertex has 6 neighbors
        assert_eq!(mrf.graph.degree(dims.index(1, 1, 1)), 6);
        // corner has 3
        assert_eq!(mrf.graph.degree(dims.index(0, 0, 0)), 3);
    }

    #[test]
    fn grid_axis_labels() {
        let dims = GridDims::new(2, 2, 2);
        let mut mrf = grid3d(dims, 2, |_| vec![1.0; 2]);
        let e = mrf.graph.find_edge(dims.index(0, 0, 0), dims.index(1, 0, 0)).unwrap();
        assert_eq!(mrf.graph.edge_data(e).potential, EdgePotential::Laplace { axis: 0 });
        let e = mrf.graph.find_edge(dims.index(0, 0, 0), dims.index(0, 0, 1)).unwrap();
        assert_eq!(mrf.graph.edge_data(e).potential, EdgePotential::Laplace { axis: 2 });
    }

    #[test]
    fn random_mrf_size_and_tables() {
        let mut rng = Pcg32::seed_from_u64(5);
        let mrf = random_mrf(200, 600, 3, &mut rng);
        assert_eq!(mrf.graph.num_vertices(), 200);
        assert!(mrf.graph.num_edges() >= 1000, "got {}", mrf.graph.num_edges());
        assert_eq!(mrf.tables.len(), 8);
        for t in &mrf.tables {
            assert_eq!(t.len(), 9);
            assert!(t.iter().all(|&p| p > 0.0));
        }
        // hubs exist (skewed degree)
        let max_deg = (0..200u32).map(|v| mrf.graph.degree(v)).max().unwrap();
        assert!(max_deg > 15, "expected hubs, max degree {max_deg}");
    }

    #[test]
    fn normalize_handles_zero() {
        let mut d = vec![0.0f32; 4];
        normalize(&mut d);
        assert_eq!(d, vec![0.25; 4]);
        let mut d = vec![2.0, 6.0];
        normalize(&mut d);
        assert_eq!(d, vec![0.25, 0.75]);
    }

    #[test]
    fn flat_tables_match_nested() {
        let nested = vec![vec![1.0f32, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0, 8.0]];
        let flat = FlatTables::from_nested(&nested, 2);
        assert_eq!(flat.len(), 2);
        assert!(!flat.is_empty());
        for (t, tab) in nested.iter().enumerate() {
            assert_eq!(flat.table(t as u32), tab.as_slice());
            for i in 0..2 {
                for j in 0..2 {
                    assert_eq!(flat.at(t as u32, i, j), tab[i * 2 + j]);
                }
            }
        }
        assert!(FlatTables::from_nested(&[], 2).is_empty());
    }

    #[test]
    fn bp_vertex_flat_round_trip_and_clone_from() {
        let v = BpVertex {
            potential: vec![0.2, 0.5, 0.3],
            belief: vec![0.1, 0.6, 0.3],
            observed: 7,
            axis_stats: [1.5, -2.0, 0.25],
        };
        let mut floats = vec![0.0f32; BpVertex::f32_lanes(3)];
        let mut words = vec![0u32; BpVertex::u32_lanes(3)];
        v.write_flat(&mut floats, &mut words);
        let back = BpVertex::read_flat(3, &floats, &words);
        assert_eq!(back.potential, v.potential);
        assert_eq!(back.belief, v.belief);
        assert_eq!(back.observed, v.observed);
        assert_eq!(back.axis_stats, v.axis_stats);

        // clone_from copies into the existing buffers (no length change
        // needed here, and capacity is reused)
        let mut dst = BpVertex::uniform(3);
        let cap = dst.belief.capacity();
        dst.clone_from(&v);
        assert_eq!(dst.belief, v.belief);
        assert_eq!(dst.potential, v.potential);
        assert!(dst.belief.capacity() >= cap);
    }

    #[test]
    fn expectation() {
        let v = BpVertex { potential: vec![], belief: vec![0.5, 0.0, 0.5], observed: 0, axis_stats: [0.0; 3] };
        assert_eq!(v.expectation(), 1.0);
    }
}
