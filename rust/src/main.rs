//! `graphlab` — the command-line launcher for the GraphLab reproduction.
//!
//! Subcommands:
//!   info        print build/artifact/registry status
//!   smoke       run a fast end-to-end self-check across every subsystem
//!   artifacts   list and compile-check the AOT artifacts (PJRT)
//!   examples    list the runnable examples and benches
//!   shard       run one resident shard of a multi-process fleet (spawned
//!               by `engine::ProcessHarness`, not meant for manual use)
//!
//! The full experiment drivers live in `examples/` (runnable scenarios) and
//! `rust/benches/` (per-figure reproduction harnesses, `cargo bench`).

use graphlab::consistency::ConsistencyModel;
use graphlab::consistency::Scope;
use graphlab::engine::{Program, UpdateContext, UpdateFn};
use graphlab::graph::GraphBuilder;
use graphlab::scheduler::{MultiQueueFifo, Scheduler, Task};
use graphlab::sdt::Sdt;
use graphlab::util::Timer;

fn usage() -> ! {
    eprintln!(
        "graphlab — GraphLab (UAI 2010) reproduction\n\n\
         USAGE: graphlab <subcommand>\n\n\
         SUBCOMMANDS:\n  \
         info        build/artifact status\n  \
         smoke       fast end-to-end self check\n  \
         artifacts   compile-check every AOT artifact via PJRT\n  \
         examples    list runnable examples and figure benches\n  \
         shard       one resident shard of a multi-process fleet (internal)"
    );
    std::process::exit(2);
}

fn info() {
    println!(
        "graphlab {} — three-layer Rust + JAX + Pallas reproduction",
        env!("CARGO_PKG_VERSION")
    );
    let dir = graphlab::runtime::default_artifact_dir();
    match graphlab::runtime::read_manifest(&dir) {
        Ok(metas) => {
            println!("artifacts ({}): {} entries", dir.display(), metas.len());
            for m in metas {
                println!(
                    "  {:<28} in:{:?} out:{:?}",
                    m.name,
                    m.inputs.iter().map(|s| s.dims.clone()).collect::<Vec<_>>(),
                    m.outputs.iter().map(|s| s.dims.clone()).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("artifacts: {e:#}"),
    }
}

fn artifacts() {
    let dir = graphlab::runtime::default_artifact_dir();
    let mut reg = match graphlab::runtime::ArtifactRegistry::open(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cannot open registry: {e:#}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", reg.platform());
    for name in reg.names() {
        let t = Timer::start();
        match reg.load(&name) {
            Ok(_) => println!("  {:<28} compiled in {:.0} ms", name, t.elapsed_secs() * 1e3),
            Err(e) => println!("  {:<28} FAILED: {e:#}", name),
        }
    }
}

fn smoke() {
    // A fast cross-subsystem sanity check: graph + engine + sync + sched.
    struct Bump;
    impl UpdateFn<u64, ()> for Bump {
        fn update(&self, scope: &mut Scope<'_, u64, ()>, ctx: &mut UpdateContext<'_>) {
            *scope.vertex_mut() += 1;
            if *scope.vertex() < 8 {
                ctx.add_task(scope.center(), 1.0);
            }
        }
    }
    let n = 10_000;
    let mut b: GraphBuilder<u64, ()> = GraphBuilder::new();
    for _ in 0..n {
        b.add_vertex(0);
    }
    for i in 0..n - 1 {
        b.add_undirected(i as u32, i as u32 + 1, (), ());
    }
    let mut g = b.build();
    let sched = MultiQueueFifo::new(n, 4);
    for v in 0..n as u32 {
        sched.add_task(Task::new(v));
    }
    let sdt = Sdt::new();
    let f = Bump;
    let t = Timer::start();
    let report = Program::new()
        .update_fn(&f)
        .workers(4)
        .model(ConsistencyModel::Edge)
        .run(&mut g, &sched, &sdt);
    assert_eq!(report.updates, n as u64 * 8, "engine executed the full program");
    println!(
        "engine: {} updates / {:.3}s = {:.2}M updates/s — OK",
        report.updates,
        t.elapsed_secs(),
        report.updates_per_sec() / 1e6
    );
    print!("{}", graphlab::metrics::run_summary(&report));

    let dir = graphlab::runtime::default_artifact_dir();
    if dir.join("manifest.tsv").exists() {
        let mut reg = graphlab::runtime::ArtifactRegistry::open(&dir).expect("registry");
        let exe = reg.load("gabp_batch_b1024").expect("artifact");
        let p = vec![2.0f32; 1024];
        let h = vec![1.0f32; 1024];
        let a = vec![0.5f32; 1024];
        let out = exe.run_f32(&[&p, &h, &a]).expect("execute");
        assert!((out[0][0] + 0.125).abs() < 1e-6);
        println!("pjrt: gabp_batch_b1024 numerics — OK");
    } else {
        println!("pjrt: skipped (run `make artifacts`)");
    }
    println!("smoke OK");
}

fn examples() {
    println!("examples (cargo run --release --example <name>):");
    for (name, what) in [
        ("quickstart", "the GraphLab programming model in ~100 lines"),
        ("denoise_pipeline", "END-TO-END: learn MRF params + denoise a 3-D volume (+ --accel)"),
        ("gibbs_sampling", "chromatic parallel Gibbs on a protein-like MRF"),
        ("coem_ner", "CoEM semi-supervised NER"),
        ("lasso_shooting", "shooting algorithm, full vs vertex consistency"),
        ("compressed_sensing", "interior-point CS with GaBP inner solves"),
    ] {
        println!("  {name:<22} {what}");
    }
    println!("figure benches (cargo bench --bench <name>):");
    for (name, what) in [
        ("fig4_denoise", "Fig 4a/b/c — param-learning schedules + sync interval"),
        ("fig5_gibbs", "Fig 5a-e — chromatic Gibbs + splash BP"),
        ("fig6_coem", "Fig 6a-d + Hadoop comparison"),
        ("fig7_lasso", "Fig 7a/b — consistency-model contention"),
        ("fig8_cs", "Fig 8a — interior-point speedup"),
        ("micro", "framework hot-path micro-benchmarks (§Perf)"),
    ] {
        println!("  {name:<22} {what}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("info") => info(),
        Some("smoke") => smoke(),
        Some("artifacts") => artifacts(),
        Some("examples") => examples(),
        Some("shard") => {
            std::process::exit(graphlab::engine::process::shard_child_main(&args[1..]))
        }
        _ => usage(),
    }
}
