//! # GraphLab — A New Framework for Parallel Machine Learning
//!
//! A from-scratch reproduction of the GraphLab abstraction
//! (Low, Bickson, Gonzalez, Guestrin, Kyrola, Hellerstein — UAI 2010) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the GraphLab coordination framework: the
//!   [data graph](graph), the [shared data table & sync mechanism](sdt),
//!   the three [consistency models](consistency) (word-per-vertex atomic
//!   try-locks + pipelined split acquisition), the
//!   [scheduler collection](scheduler), the threaded (non-blocking,
//!   deferral-based), sharded (ghost-replicated partitions,
//!   distributed-style locking, pluggable ghost-sync
//!   [transport](transport) with delta batching and bounded staleness)
//!   and sequential [engines](engine) behind
//!   the [`engine::Program`] front-end, the runtime-gated [telemetry]
//!   layer (per-worker event rings, time-series sampler, Perfetto/JSONL
//!   export), the [multicore simulator](sim), and
//!   the paper's five
//!   case-study [applications](apps) with synthetic [workloads](datagen) and
//!   [baselines](baselines).
//! * **Layer 2/1 (build time, `python/`)** — batched vertex-program kernels
//!   (grid BP, GaBP, CoEM) written in JAX + Pallas, AOT-lowered to HLO text
//!   and executed from the [runtime] via PJRT. Python never runs on the
//!   request path.
//!
//! See `examples/quickstart.rs` for a complete program and `DESIGN.md` for
//! the system inventory and the experiment index.

pub mod apps;
pub mod baselines;
pub mod consistency;
pub mod datagen;
pub mod engine;
pub mod graph;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod sdt;
pub mod sim;
pub mod telemetry;
pub mod transport;
pub mod util;
