//! MapReduce-style execution model of CoEM — the paper's Hadoop comparison
//! (§4.3): "a comparable Hadoop implementation took approximately 7.5 hours
//! ... on an average of 95 cpus. Our large performance gain can be
//! attributed to data persistence in the GraphLab framework. Data
//! persistence allows us to avoid the extensive data copying and
//! synchronization required by the Hadoop implementation of MapReduce."
//!
//! This module is a *cost model with measured inputs*, not a Hadoop cluster:
//! we execute the same Jacobi CoEM sweeps the MapReduce program would run,
//! measure the pure compute time, and charge each iteration the data-motion
//! costs MapReduce cannot avoid — materializing the graph + belief state to
//! the distributed FS, the shuffle, and per-job startup latency — using
//! published Hadoop-era constants. The GraphLab side keeps state in shared
//! memory across iterations (data persistence), paying the compute cost
//! only. The output is the runtime ratio on equal work.

use crate::apps::coem::{CoemEdge, CoemVertex};
use crate::baselines::sequential::coem_jacobi;
use crate::graph::DataGraph;
use crate::util::Timer;

/// Hadoop-era cost constants (defaults from published MapReduce
/// measurements of the 2010 time frame; overridable by benches).
#[derive(Debug, Clone)]
pub struct MapReduceCosts {
    /// Per-job startup + scheduling latency (seconds). Hadoop ~10-30 s.
    pub job_startup_s: f64,
    /// Sustained materialize+shuffle bandwidth per node (bytes/sec).
    pub io_bandwidth: f64,
    /// Replication factor for intermediate writes.
    pub replication: f64,
    /// Number of worker nodes (the paper's comparison used ~95 CPUs).
    pub nodes: usize,
}

impl Default for MapReduceCosts {
    fn default() -> Self {
        MapReduceCosts {
            job_startup_s: 15.0,
            io_bandwidth: 50e6, // 50 MB/s HDFS-era effective per node
            replication: 3.0,
            nodes: 95,
        }
    }
}

/// Estimated per-entry bytes of the serialized graph + state (key, value,
/// belief vector, edge list entries).
fn state_bytes(graph: &DataGraph<CoemVertex, CoemEdge>, classes: usize) -> f64 {
    let per_vertex = 16.0 + 4.0 * classes as f64;
    let per_edge = 12.0;
    graph.num_vertices() as f64 * per_vertex + graph.num_edges() as f64 * per_edge
}

/// Result of the comparison.
#[derive(Debug, Clone)]
pub struct MapReduceComparison {
    /// Measured GraphLab-side compute time for the sweeps (s).
    pub graphlab_s: f64,
    /// Modeled MapReduce runtime for the same sweeps (s).
    pub mapreduce_s: f64,
    /// Per-iteration data-motion cost charged to MapReduce (s).
    pub per_iteration_io_s: f64,
    pub iterations: usize,
}

impl MapReduceComparison {
    pub fn ratio(&self) -> f64 {
        self.mapreduce_s / self.graphlab_s.max(1e-9)
    }
}

/// Run `sweeps` Jacobi CoEM iterations measuring compute, then model the
/// MapReduce runtime for the identical work.
pub fn compare(
    graph: &mut DataGraph<CoemVertex, CoemEdge>,
    classes: usize,
    sweeps: usize,
    costs: &MapReduceCosts,
) -> MapReduceComparison {
    let timer = Timer::start();
    coem_jacobi(graph, classes, sweeps, 0.0);
    let compute_s = timer.elapsed_secs();

    let bytes = state_bytes(graph, classes);
    // Each iteration: map reads the full state, shuffle moves messages,
    // reduce writes the state back with replication. Aggregate cluster
    // bandwidth = per-node bandwidth × nodes.
    let cluster_bw = costs.io_bandwidth * costs.nodes as f64;
    let io_per_iter = (bytes * (2.0 + costs.replication)) / cluster_bw + costs.job_startup_s;
    // MapReduce compute: same FLOPs spread over the cluster, but against the
    // single-node measurement here we conservatively grant perfect scaling.
    let mr_compute = compute_s / costs.nodes as f64;
    MapReduceComparison {
        graphlab_s: compute_s,
        mapreduce_s: (mr_compute + io_per_iter * sweeps as f64),
        per_iteration_io_s: io_per_iter,
        iterations: sweeps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::ner;
    use crate::util::Pcg32;

    #[test]
    fn persistence_advantage_shows_up() {
        let mut rng = Pcg32::seed_from_u64(5);
        let mut g = ner::generate(&ner::NerConfig::small(0.02), &mut rng);
        let cmp = compare(&mut g, 2, 3, &MapReduceCosts::default());
        assert!(cmp.graphlab_s > 0.0);
        assert!(
            cmp.ratio() > 5.0,
            "barrier+copy model must dominate on small iterations: ratio {}",
            cmp.ratio()
        );
        assert!(cmp.per_iteration_io_s > costs_floor());
    }

    fn costs_floor() -> f64 {
        MapReduceCosts::default().job_startup_s * 0.9
    }

    #[test]
    fn io_cost_scales_with_graph_size() {
        let mut rng = Pcg32::seed_from_u64(6);
        let mut small = ner::generate(&ner::NerConfig::small(0.01), &mut rng);
        let mut rng = Pcg32::seed_from_u64(6);
        let mut large = ner::generate(&ner::NerConfig::small(0.04), &mut rng);
        let costs = MapReduceCosts { job_startup_s: 0.0, ..Default::default() };
        let a = compare(&mut small, 2, 1, &costs);
        let b = compare(&mut large, 2, 1, &costs);
        assert!(b.per_iteration_io_s > 2.0 * a.per_iteration_io_s);
    }
}
