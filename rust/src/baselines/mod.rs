//! Baselines the paper compares against:
//!
//! * [`sequential`] — plain single-threaded reference implementations of the
//!   case-study algorithms, independent of the GraphLab engine. Used both as
//!   correctness oracles (the engine must match them) and as the
//!   single-processor timing baseline the speedup figures normalize to.
//! * [`mapreduce`] — an iteration-barrier MapReduce-style execution model of
//!   CoEM (the paper's Hadoop comparison, §4.3): every iteration pays full
//!   data materialization + shuffle costs because MapReduce has no data
//!   persistence, which is exactly where the paper locates its 15× advantage.

pub mod mapreduce;
pub mod sequential;
