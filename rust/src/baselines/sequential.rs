//! Plain sequential reference implementations (no GraphLab machinery) used
//! as correctness oracles and single-processor baselines.

use crate::apps::coem::{CoemEdge, CoemVertex};
use crate::apps::lasso::{LassoProblem, LassoVertex};
use crate::graph::DataGraph;
use crate::util::linalg::soft_threshold;

/// Jacobi CoEM: synchronous sweeps with double buffering, `sweeps` times.
/// `damping` (0 = undamped) suppresses the period-2 Jacobi mode that pure
/// synchronous iteration exhibits on bipartite graphs; the fixed point is
/// unchanged. Returns the final beliefs.
pub fn coem_jacobi(
    graph: &mut DataGraph<CoemVertex, CoemEdge>,
    classes: usize,
    sweeps: usize,
    damping: f32,
) -> Vec<Vec<f32>> {
    let n = graph.num_vertices();
    let mut beliefs: Vec<Vec<f32>> =
        (0..n as u32).map(|v| graph.vertex_data(v).belief.clone()).collect();
    for _ in 0..sweeps {
        let mut next = beliefs.clone();
        for v in 0..n as u32 {
            if graph.vertex_data(v).seed {
                continue;
            }
            let mut acc = vec![0.0f32; classes];
            let mut total = 0.0f32;
            for &e in graph.out_edges(v).to_vec().iter() {
                let u = graph.edge(e).dst;
                let w = graph.edge_data(e).weight;
                for (a, b) in acc.iter_mut().zip(&beliefs[u as usize]) {
                    *a += w * *b;
                }
                total += w;
            }
            if total > 0.0 {
                for (a, old) in acc.iter_mut().zip(&beliefs[v as usize]) {
                    *a = damping * *old + (1.0 - damping) * (*a / total);
                }
                next[v as usize] = acc;
            }
        }
        beliefs = next;
    }
    // write back
    for v in 0..n as u32 {
        graph.vertex_data(v).belief = beliefs[v as usize].clone();
    }
    beliefs
}

/// Textbook sequential shooting algorithm on dense-ish data: cyclic
/// coordinate descent until no coordinate moves more than `eps`.
/// Returns (weights, sweeps used).
pub fn shooting_reference(
    problem: &mut LassoProblem,
    lambda: f32,
    eps: f32,
    max_sweeps: usize,
) -> (Vec<f32>, usize) {
    let d = problem.num_weights;
    for sweep in 0..max_sweeps {
        let mut max_move = 0.0f32;
        for i in 0..d as u32 {
            let (w_old, a) = match problem.graph.vertex_data(i) {
                LassoVertex::Weight { w, a } => (*w, *a),
                _ => unreachable!(),
            };
            if a <= 0.0 {
                continue;
            }
            let mut rho = 0.0f32;
            let edges = problem.graph.out_edges(i).to_vec();
            for &e in &edges {
                let obs = problem.graph.edge(e).dst;
                let x = problem.graph.edge_data(e).x;
                let r = problem.graph.vertex_data(obs).residual();
                rho += x * (r + x * w_old);
            }
            let w_new = soft_threshold(rho as f64, lambda as f64 / 2.0) as f32 / a;
            let delta = w_new - w_old;
            if delta.abs() > eps {
                match problem.graph.vertex_data(i) {
                    LassoVertex::Weight { w, .. } => *w = w_new,
                    _ => unreachable!(),
                }
                for &e in &edges {
                    let obs = problem.graph.edge(e).dst;
                    let x = problem.graph.edge_data(e).x;
                    match problem.graph.vertex_data(obs) {
                        LassoVertex::Obs { residual, .. } => *residual -= x * delta,
                        _ => unreachable!(),
                    }
                }
                max_move = max_move.max(delta.abs());
            }
        }
        if max_move <= eps {
            return (problem.weights(), sweep + 1);
        }
    }
    (problem.weights(), max_sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::coem::CoemUpdate;
    use crate::consistency::ConsistencyModel;
    use crate::datagen::{finance, ner};
    use crate::engine::{Program, ThreadedEngine};
    use crate::scheduler::{MultiQueueFifo, Scheduler, Task};
    use crate::sdt::Sdt;
    use crate::util::Pcg32;

    #[test]
    fn engine_coem_matches_jacobi_fixed_point() {
        // High seed fraction => fast mixing, so both methods actually reach
        // the (unique, well-conditioned) fixed point within their stopping
        // rules and the comparison is meaningful.
        let mut cfg = ner::NerConfig::small(0.01);
        cfg.seed_fraction = 0.25;
        let mut rng = Pcg32::seed_from_u64(11);
        let mut ref_graph = ner::generate(&cfg, &mut rng);
        let mut rng = Pcg32::seed_from_u64(11);
        let mut engine_graph = ner::generate(&cfg, &mut rng);

        let reference = coem_jacobi(&mut ref_graph, cfg.classes, 2000, 0.5);

        let n = engine_graph.num_vertices();
        let sched = MultiQueueFifo::new(n, 2);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = CoemUpdate::new(cfg.classes);
        Program::new()
            .update_fn(&upd)
            .workers(2)
            .model(ConsistencyModel::Vertex)
            .max_updates(5_000_000)
            .run_on(&ThreadedEngine, &mut engine_graph, &sched, &sdt);
        // both reach the same fixed point (within tolerance)
        let mut max_diff = 0.0f32;
        for v in 0..n as u32 {
            let got = &engine_graph.vertex_data(v).belief;
            for (g, r) in got.iter().zip(&reference[v as usize]) {
                max_diff = max_diff.max((g - r).abs());
            }
        }
        assert!(max_diff < 0.05, "fixed points differ by {max_diff}");
    }

    #[test]
    fn shooting_reference_converges() {
        let mut rng = Pcg32::seed_from_u64(21);
        let (mut p, _) = finance::generate(&finance::FinanceConfig::sparser(0.02), &mut rng);
        let (w, sweeps) = shooting_reference(&mut p, 1.0, 1e-5, 500);
        assert!(sweeps < 500, "did not converge");
        assert_eq!(w.len(), p.num_weights);
        // objective should beat the all-zeros solution
        let loss = p.loss(1.0);
        let mut rng = Pcg32::seed_from_u64(21);
        let (mut zero, _) = finance::generate(&finance::FinanceConfig::sparser(0.02), &mut rng);
        assert!(loss < zero.loss(1.0));
    }
}
