//! Reporting helpers: speedup tables, TSV emission for figures, and run
//! summaries shared by the benchmark harness (`benches/`) and the CLI.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A labelled series of (x, y) points — one line in a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: &str) -> Series {
        Series { label: label.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn from_points(label: &str, pts: impl IntoIterator<Item = (f64, f64)>) -> Series {
        Series { label: label.to_string(), points: pts.into_iter().collect() }
    }
}

/// A figure: several series over a shared x-axis, renderable as an aligned
/// text table and writable as TSV (one column per series).
#[derive(Debug, Clone)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(id: &str, title: &str, x_label: &str, y_label: &str) -> Figure {
        Figure {
            id: id.to_string(),
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
        }
    }

    pub fn add(&mut self, s: Series) -> &mut Self {
        self.series.push(s);
        self
    }

    /// Union of x values across series, sorted.
    fn xs(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        xs
    }

    fn lookup(s: &Series, x: f64) -> Option<f64> {
        s.points.iter().find(|p| (p.0 - x).abs() < 1e-12).map(|p| p.1)
    }

    /// Render as an aligned text table (printed by the bench harness).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} — {} ==", self.id, self.title);
        let _ = write!(out, "{:>12}", self.x_label);
        for s in &self.series {
            let _ = write!(out, " {:>18}", s.label);
        }
        let _ = writeln!(out, "    ({})", self.y_label);
        for x in self.xs() {
            let _ = write!(out, "{x:>12.3}");
            for s in &self.series {
                match Self::lookup(s, x) {
                    Some(y) => {
                        let _ = write!(out, " {y:>18.4}");
                    }
                    None => {
                        let _ = write!(out, " {:>18}", "-");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Write TSV: header `x<TAB>label1<TAB>label2...`, one row per x.
    pub fn write_tsv(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.tsv", self.id));
        let mut f = std::fs::File::create(&path)?;
        write!(f, "{}", self.x_label)?;
        for s in &self.series {
            write!(f, "\t{}", s.label)?;
        }
        writeln!(f)?;
        for x in self.xs() {
            write!(f, "{x}")?;
            for s in &self.series {
                match Self::lookup(s, x) {
                    Some(y) => write!(f, "\t{y}")?,
                    None => write!(f, "\t")?,
                }
            }
            writeln!(f)?;
        }
        Ok(path)
    }
}

/// Render a [`crate::engine::RunReport`] as an aligned text block:
/// headline throughput plus the per-worker update/conflict/deferral table
/// the non-blocking engine records (all zeros on uncontended or sequential
/// runs). Lines whose counters the run could not have produced are
/// omitted: the affinity rate only renders when the scheduler actually
/// advertised an `owner_of` routing map (otherwise the 0% is structural,
/// not informative), and the ghost/boundary counters only render for
/// sharded-engine runs.
pub fn run_summary(report: &crate::engine::RunReport) -> String {
    let mut out = String::new();
    let c = &report.contention;
    let _ = writeln!(
        out,
        "{} updates in {:.3}s ({:.2}M/s), stop: {:?}, syncs: {}",
        report.updates,
        report.wall_secs,
        report.updates_per_sec() / 1e6,
        report.stop,
        report.syncs_run
    );
    let _ = writeln!(
        out,
        "contention: {} conflicts ({:.4}/update), {} deferrals, {} retries \
         ({} stolen, {} escalated)",
        c.conflicts,
        c.conflict_rate(report.updates),
        c.deferrals,
        c.retries,
        c.steals,
        c.escalations
    );
    if c.has_owner_map {
        let _ = writeln!(
            out,
            "affinity: {} owner-worker hits ({:.1}% of updates)",
            c.affinity_hits,
            100.0 * c.affinity_hits as f64 / report.updates.max(1) as f64
        );
    }
    if c.shards > 0 {
        let _ = writeln!(
            out,
            "sharding: {} shards, {} ghost syncs, {} boundary updates \
             ({:.1}% of updates), {} handoffs, {} pipelined stalls",
            c.shards,
            c.ghost_syncs,
            c.boundary_updates,
            100.0 * c.boundary_updates as f64 / report.updates.max(1) as f64,
            c.handoffs,
            c.pipelined_stalls
        );
        let _ = writeln!(
            out,
            "transport: {} deltas sent ({} coalesced), {} bytes shipped, \
             {} staleness pulls ({} wire-served, max replica lag {})",
            c.deltas_sent,
            c.deltas_coalesced,
            c.bytes_shipped,
            c.staleness_pulls,
            c.pulls_served,
            c.max_ghost_staleness
        );
        if c.backpressure_stalls > 0 {
            let _ = writeln!(
                out,
                "backpressure: {} sends stalled on a full transport window",
                c.backpressure_stalls
            );
        }
        if c.faults_injected > 0
            || c.pull_retries > 0
            || c.pull_timeouts > 0
            || c.reconnect_backoffs > 0
        {
            let _ = writeln!(
                out,
                "faults: {} injected, {} pull retries, {} pull timeouts, \
                 {} reconnect backoffs",
                c.faults_injected, c.pull_retries, c.pull_timeouts, c.reconnect_backoffs
            );
        }
        if c.snapshots_taken > 0 {
            let _ = writeln!(out, "snapshots: {} epochs captured", c.snapshots_taken);
        }
    }
    if c.auto_steal_half_flips > 0 {
        let _ = writeln!(
            out,
            "steal policy: {} workers auto-flipped to steal-half",
            c.auto_steal_half_flips
        );
    }
    if c.pinned_workers > 0 {
        let _ = writeln!(out, "pinning: {} workers pinned to cores", c.pinned_workers);
    }
    if let Some(t) = &report.telemetry {
        let _ = writeln!(
            out,
            "telemetry: {} events recorded ({} dropped), {} samples, {} tracks",
            t.events_recorded,
            t.events_dropped,
            t.samples.len(),
            t.tracks.len()
        );
        if let Some(p) = &t.trace_path {
            let _ = writeln!(out, "telemetry: chrome trace written to {}", p.display());
        }
        if let Some(p) = &t.metrics_path {
            let _ = writeln!(out, "telemetry: metric samples written to {}", p.display());
        }
    }
    let _ = writeln!(out, "{:>8} {:>12} {:>12} {:>12}", "worker", "updates", "conflicts", "deferrals");
    for (w, &u) in report.per_worker.iter().enumerate() {
        let conflicts = c.per_worker_conflicts.get(w).copied().unwrap_or(0);
        let deferrals = c.per_worker_deferrals.get(w).copied().unwrap_or(0);
        let _ = writeln!(out, "{w:>8} {u:>12} {conflicts:>12} {deferrals:>12}");
    }
    out
}

/// Write a grayscale image (f32 in [0,1]) as a binary PGM — used for the
/// Fig 4d/e and Fig 8b/c image outputs.
pub fn write_pgm(path: &Path, pixels: &[f32], width: usize, height: usize) -> std::io::Result<()> {
    assert_eq!(pixels.len(), width * height);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P5\n{width} {height}\n255")?;
    let bytes: Vec<u8> =
        pixels.iter().map(|&p| (p.clamp(0.0, 1.0) * 255.0).round() as u8).collect();
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_renders_all_series() {
        let mut fig = Figure::new("fig_test", "demo", "procs", "speedup");
        fig.add(Series::from_points("a", [(1.0, 1.0), (2.0, 1.9)]));
        fig.add(Series::from_points("b", [(1.0, 1.0), (4.0, 3.1)]));
        let text = fig.render();
        assert!(text.contains("fig_test"));
        assert!(text.contains('a') && text.contains('b'));
        assert!(text.contains("1.9000"));
        // x=4 missing from series a -> dash
        assert!(text.lines().last().unwrap().contains('-'));
    }

    #[test]
    fn tsv_roundtrip() {
        let dir = std::env::temp_dir().join("graphlab_metrics_test");
        let mut fig = Figure::new("fig_tsv", "demo", "x", "y");
        fig.add(Series::from_points("s", [(1.0, 2.0), (2.0, 4.0)]));
        let path = fig.write_tsv(&dir).unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = content.lines().collect();
        assert_eq!(lines[0], "x\ts");
        assert_eq!(lines[1], "1\t2");
        assert_eq!(lines[2], "2\t4");
    }

    #[test]
    fn run_summary_includes_contention_table() {
        let report = crate::engine::RunReport {
            updates: 1000,
            wall_secs: 0.5,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![600, 400],
            syncs_run: 2,
            contention: crate::engine::ContentionStats {
                conflicts: 30,
                deferrals: 10,
                retries: 10,
                steals: 3,
                escalations: 2,
                affinity_hits: 800,
                has_owner_map: true,
                per_worker_conflicts: vec![20, 10],
                per_worker_deferrals: vec![7, 3],
                ..Default::default()
            },
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        assert!(text.contains("1000 updates"));
        assert!(text.contains("30 conflicts"));
        assert!(text.contains("10 deferrals"));
        assert!(text.contains("2 escalated"));
        assert!(text.contains("800 owner-worker hits"));
        assert!(text.contains("80.0% of updates"));
        assert!(!text.contains("sharding:"), "unsharded run hides shard counters");
        assert!(text.lines().count() >= 6, "per-worker rows present");
    }

    /// Schedulers without an `owner_of` routing map must not render a
    /// (structurally zero, meaningless) affinity rate.
    #[test]
    fn run_summary_gates_affinity_on_owner_map() {
        let report = crate::engine::RunReport {
            updates: 100,
            wall_secs: 0.1,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![100],
            syncs_run: 0,
            contention: crate::engine::ContentionStats {
                has_owner_map: false,
                ..Default::default()
            },
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        assert!(
            !text.contains("affinity"),
            "no owner map -> no affinity line:\n{text}"
        );
    }

    #[test]
    fn run_summary_renders_shard_counters_for_sharded_runs() {
        let report = crate::engine::RunReport {
            updates: 500,
            wall_secs: 0.2,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![250, 250],
            syncs_run: 0,
            contention: crate::engine::ContentionStats {
                shards: 4,
                ghost_syncs: 120,
                boundary_updates: 100,
                handoffs: 7,
                pipelined_stalls: 3,
                deltas_sent: 60,
                deltas_coalesced: 40,
                bytes_shipped: 4800,
                staleness_pulls: 5,
                pulls_served: 3,
                max_ghost_staleness: 2,
                ..Default::default()
            },
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        assert!(text.contains("4 shards"));
        assert!(text.contains("120 ghost syncs"));
        assert!(text.contains("100 boundary updates"));
        assert!(text.contains("20.0% of updates"));
        assert!(text.contains("7 handoffs"));
        assert!(text.contains("3 pipelined stalls"));
        assert!(text.contains("60 deltas sent (40 coalesced)"));
        assert!(text.contains("4800 bytes shipped"));
        assert!(text.contains("5 staleness pulls (3 wire-served, max replica lag 2)"));
        assert!(!text.contains("backpressure"), "no stalls, no line");
    }

    /// The transport line is shard-gated, and the steal-policy line only
    /// renders when a worker actually auto-flipped.
    #[test]
    fn run_summary_gates_transport_and_steal_lines() {
        let mut report = crate::engine::RunReport {
            updates: 100,
            wall_secs: 0.1,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![100],
            syncs_run: 0,
            contention: crate::engine::ContentionStats::default(),
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        assert!(!text.contains("transport:"), "unsharded run hides transport line");
        assert!(!text.contains("steal policy"), "no flips, no line");
        report.contention.auto_steal_half_flips = 2;
        let text = run_summary(&report);
        assert!(text.contains("2 workers auto-flipped to steal-half"));
        // the backpressure line renders only for sharded runs that stalled
        report.contention.shards = 2;
        report.contention.backpressure_stalls = 9;
        let text = run_summary(&report);
        assert!(text.contains("9 sends stalled on a full transport window"));
    }

    /// The fault and snapshot lines only render for sharded runs whose
    /// counters are actually nonzero — a clean run's summary is unchanged.
    #[test]
    fn run_summary_gates_fault_and_snapshot_lines() {
        let mut report = crate::engine::RunReport {
            updates: 100,
            wall_secs: 0.1,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![100],
            syncs_run: 0,
            contention: crate::engine::ContentionStats {
                shards: 2,
                ..Default::default()
            },
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        assert!(!text.contains("faults:"), "clean run hides the fault line");
        assert!(!text.contains("snapshots:"), "no epochs, no line");
        report.contention.faults_injected = 17;
        report.contention.pull_retries = 4;
        report.contention.pull_timeouts = 1;
        report.contention.reconnect_backoffs = 2;
        report.contention.snapshots_taken = 3;
        let text = run_summary(&report);
        assert!(text.contains(
            "faults: 17 injected, 4 pull retries, 1 pull timeouts, 2 reconnect backoffs"
        ));
        assert!(text.contains("snapshots: 3 epochs captured"));
        // pull retries alone are enough to surface the fault line
        report.contention.faults_injected = 0;
        report.contention.pull_timeouts = 0;
        report.contention.reconnect_backoffs = 0;
        report.contention.snapshots_taken = 0;
        let text = run_summary(&report);
        assert!(text.contains("faults: 0 injected, 4 pull retries"));
        // but never outside a sharded run
        report.contention.shards = 0;
        let text = run_summary(&report);
        assert!(!text.contains("faults:"), "fault line is shard-gated");
    }

    /// Every numeric `ContentionStats` counter must surface in the
    /// summary text once its gating lines are open: seed each field with
    /// a distinct magic value, open every gate, and require each value
    /// verbatim in the rendered block. A counter the engines maintain but
    /// the summary never prints would fail here — that is how the
    /// fault-transport counters (`pull_timeouts`, `reconnect_backoffs`)
    /// stay visible.
    #[test]
    fn run_summary_renders_every_nonzero_contention_field() {
        let c = crate::engine::ContentionStats {
            conflicts: 4001,
            deferrals: 4002,
            retries: 4003,
            steals: 4004,
            escalations: 4005,
            affinity_hits: 4006,
            has_owner_map: true,
            shards: 4007,
            ghost_syncs: 4008,
            boundary_updates: 4009,
            handoffs: 4010,
            pipelined_stalls: 4011,
            deltas_sent: 4012,
            deltas_coalesced: 4013,
            bytes_shipped: 4014,
            staleness_pulls: 4015,
            pulls_served: 4016,
            backpressure_stalls: 4017,
            max_ghost_staleness: 4018,
            auto_steal_half_flips: 4019,
            faults_injected: 4020,
            pull_retries: 4021,
            pull_timeouts: 4022,
            reconnect_backoffs: 4023,
            snapshots_taken: 4024,
            pinned_workers: 4025,
            per_worker_conflicts: vec![4026, 4027],
            per_worker_deferrals: vec![4028, 4029],
        };
        let report = crate::engine::RunReport {
            updates: 10000,
            wall_secs: 0.5,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![6000, 4000],
            syncs_run: 1,
            contention: c,
            snapshots: Vec::new(),
            telemetry: None,
        };
        let text = run_summary(&report);
        for magic in 4001..=4029u64 {
            assert!(
                text.contains(&magic.to_string()),
                "counter value {magic} missing from summary:\n{text}"
            );
        }
    }

    /// The telemetry block renders only when the run carried a report,
    /// and names the export files it actually wrote.
    #[test]
    fn run_summary_renders_telemetry_section_when_present() {
        let mut report = crate::engine::RunReport {
            updates: 10,
            wall_secs: 0.1,
            stop: crate::engine::StopReason::SchedulerEmpty,
            per_worker: vec![10],
            syncs_run: 0,
            contention: crate::engine::ContentionStats::default(),
            snapshots: Vec::new(),
            telemetry: None,
        };
        assert!(!run_summary(&report).contains("telemetry:"), "off -> no line");
        let tel = crate::telemetry::Telemetry::new(
            crate::telemetry::TelemetryConfig::default(),
            vec!["worker-0".into()],
        );
        {
            let _bind = tel.bind_worker(0);
            crate::telemetry::instant(crate::telemetry::EventKind::TaskExec, 0, 0);
        }
        report.telemetry = Some(tel.finish());
        let text = run_summary(&report);
        assert!(text.contains("telemetry: 1 events recorded (0 dropped)"));
        assert!(!text.contains("chrome trace"), "no export configured");
    }

    #[test]
    fn pgm_header_and_size() {
        let dir = std::env::temp_dir().join("graphlab_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("img.pgm");
        write_pgm(&path, &[0.0, 0.5, 1.0, 0.25], 2, 2).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n2 2\n255\n"));
        assert_eq!(bytes.len(), "P5\n2 2\n255\n".len() + 4);
    }
}
