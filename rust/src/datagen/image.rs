//! Procedural grayscale test image — the Lenna stand-in for the compressed
//! sensing experiment (paper §4.5, Fig 8b: 256×256). Smooth gradients plus
//! sharp-edged shapes give an image that is genuinely sparse in the Haar
//! wavelet basis (the property the experiment needs).

use crate::util::Pcg32;

/// Generate a `size × size` image in [0, 1] (row-major). `size` must be a
/// power of two (Haar requirement).
pub fn generate(size: usize, rng: &mut Pcg32) -> Vec<f32> {
    assert!(size.is_power_of_two());
    let s = size as f32;
    let mut img = vec![0.0f32; size * size];
    // smooth background gradient + soft vignette
    for y in 0..size {
        for x in 0..size {
            let (fx, fy) = (x as f32 / s, y as f32 / s);
            let g = 0.35 + 0.3 * fx + 0.15 * (fy * std::f32::consts::PI).sin();
            img[y * size + x] = g;
        }
    }
    // sharp-edged random rectangles and disks ("objects")
    for obj in 0..6 {
        let cx = rng.range_f64(0.15, 0.85) as f32 * s;
        let cy = rng.range_f64(0.15, 0.85) as f32 * s;
        let r = rng.range_f64(0.05, 0.18) as f32 * s;
        let level = rng.next_f32() * 0.8 + 0.1;
        let disk = obj % 2 == 0;
        for y in 0..size {
            for x in 0..size {
                let (dx, dy) = (x as f32 - cx, y as f32 - cy);
                let inside = if disk {
                    dx * dx + dy * dy < r * r
                } else {
                    dx.abs() < r && dy.abs() < r * 0.7
                };
                if inside {
                    img[y * size + x] = level;
                }
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::wavelet::haar2d;

    #[test]
    fn image_in_range_and_varied() {
        let mut rng = Pcg32::seed_from_u64(4);
        let img = generate(64, &mut rng);
        assert_eq!(img.len(), 64 * 64);
        assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        let mean = img.iter().sum::<f32>() / img.len() as f32;
        let var = img.iter().map(|p| (p - mean).powi(2)).sum::<f32>() / img.len() as f32;
        assert!(var > 0.005, "image must have structure, var={var}");
    }

    #[test]
    fn image_is_wavelet_sparse() {
        let mut rng = Pcg32::seed_from_u64(5);
        let size = 64;
        let mut img = generate(size, &mut rng);
        haar2d(&mut img, size);
        let total_energy: f32 = img.iter().map(|c| c * c).sum();
        let mut mags: Vec<f32> = img.iter().map(|c| c * c).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let top10: f32 = mags.iter().take(size * size / 10).sum();
        assert!(
            top10 / total_energy > 0.97,
            "10% of Haar coefficients must carry >97% of energy: {}",
            top10 / total_energy
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(32, &mut Pcg32::seed_from_u64(9));
        let b = generate(32, &mut Pcg32::seed_from_u64(9));
        assert_eq!(a, b);
    }
}
