//! Synthetic workload generators standing in for the paper's proprietary /
//! unavailable datasets (see DESIGN.md §Testbed-substitutions). Each
//! generator preserves the *structural* properties that drive the paper's
//! results: graph topology, degree skew, size ratios, and noise character.

pub mod finance;
pub mod image;
pub mod ner;
pub mod protein;
pub mod retina;
