//! Synthetic NER co-occurrence graphs for CoEM (paper §4.3) — power-law
//! bipartite NP–CT graphs matching the structure of the paper's web-crawl
//! datasets:
//!
//! | name  | classes | vertices | edges  |
//! |-------|---------|----------|--------|
//! | small | 1 (+neg)| 0.2M     | 20M    |
//! | large | 135     | 2M       | 200M   |
//!
//! Scaled-down defaults keep the shape (edge:vertex ratio ~100:1 is reduced
//! to ~10:1 to fit the testbed; the `scale` parameter lets benches sweep
//! size — Fig 6d). Degree skew follows a Zipf profile as in web text.

use crate::apps::coem::{CoemEdge, CoemVertex};
use crate::graph::{DataGraph, GraphBuilder};
use crate::util::Pcg32;

/// Configuration for a synthetic CoEM dataset.
#[derive(Debug, Clone)]
pub struct NerConfig {
    pub num_np: usize,
    pub num_ct: usize,
    pub num_edges: usize,
    pub classes: usize,
    /// Fraction of NPs seeded with a known label.
    pub seed_fraction: f64,
    /// Zipf skew for context popularity.
    pub skew: f64,
}

impl NerConfig {
    /// "small"-shaped dataset, scaled by `scale` (1.0 = 20K vertices, 200K
    /// edges — 1/10 of the paper's small dataset).
    pub fn small(scale: f64) -> NerConfig {
        NerConfig {
            num_np: (16_000.0 * scale) as usize,
            num_ct: (4_000.0 * scale) as usize,
            num_edges: (200_000.0 * scale) as usize,
            classes: 2,
            seed_fraction: 0.05,
            skew: 1.1,
        }
    }

    /// "large"-shaped dataset (more classes, more edges per vertex).
    pub fn large(scale: f64) -> NerConfig {
        NerConfig {
            num_np: (60_000.0 * scale) as usize,
            num_ct: (15_000.0 * scale) as usize,
            num_edges: (1_200_000.0 * scale) as usize,
            classes: 16,
            seed_fraction: 0.03,
            skew: 1.05,
        }
    }
}

/// Generate the bipartite graph: NPs are vertices `0..num_np`, CTs are
/// `num_np..num_np+num_ct`.
pub fn generate(cfg: &NerConfig, rng: &mut Pcg32) -> DataGraph<CoemVertex, CoemEdge> {
    let n = cfg.num_np + cfg.num_ct;
    let mut b: GraphBuilder<CoemVertex, CoemEdge> =
        GraphBuilder::with_capacity(n, cfg.num_edges * 2);
    // Ground-truth class per NP drives seed labels and edge affinity so the
    // fixed point is informative (not uniform).
    let np_class: Vec<usize> =
        (0..cfg.num_np).map(|_| rng.gen_range(cfg.classes as u32) as usize).collect();
    for (i, &cls) in np_class.iter().enumerate() {
        let _ = i;
        if rng.next_f64() < cfg.seed_fraction {
            b.add_vertex(CoemVertex::seeded(cfg.classes, cls, true));
        } else {
            b.add_vertex(CoemVertex::unlabeled(cfg.classes, true));
        }
    }
    // Each context has a preferred class (contexts select for classes).
    let ct_class: Vec<usize> =
        (0..cfg.num_ct).map(|_| rng.gen_range(cfg.classes as u32) as usize).collect();
    for _ in 0..cfg.num_ct {
        b.add_vertex(CoemVertex::unlabeled(cfg.classes, false));
    }
    // Edges: context chosen by Zipf popularity; NP strongly biased toward
    // NPs of the context's class (real contexts select for classes —
    // "citizen of _" co-occurs with countries). Cross-class co-occurrences
    // exist but carry low counts.
    let mut seen = std::collections::HashSet::new();
    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < cfg.num_edges && attempts < cfg.num_edges * 30 {
        attempts += 1;
        let ct = rng.next_zipf(cfg.num_ct, cfg.skew);
        let same_class = rng.next_f64() < 0.9;
        let np = if same_class {
            // rejection-sample an NP of the context's class (bounded tries)
            let mut np = rng.gen_range(cfg.num_np as u32) as usize;
            for _ in 0..16 {
                if np_class[np] == ct_class[ct] {
                    break;
                }
                np = rng.gen_range(cfg.num_np as u32) as usize;
            }
            np
        } else {
            rng.gen_range(cfg.num_np as u32) as usize
        };
        if !seen.insert((np as u32, ct as u32)) {
            continue;
        }
        let count = if np_class[np] == ct_class[ct] {
            1 + rng.next_zipf(20, 1.5) as u32
        } else {
            1
        };
        let w = CoemEdge { weight: count as f32 };
        b.add_undirected(np as u32, (cfg.num_np + ct) as u32, w, w);
        added += 1;
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_config_shape() {
        let mut rng = Pcg32::seed_from_u64(1);
        let cfg = NerConfig::small(0.05);
        let g = generate(&cfg, &mut rng);
        assert_eq!(g.num_vertices(), cfg.num_np + cfg.num_ct);
        // undirected: 2 directed edges per co-occurrence
        assert!(g.num_edges() >= cfg.num_edges, "{} < {}", g.num_edges(), cfg.num_edges);
    }

    #[test]
    fn bipartite_structure() {
        let mut rng = Pcg32::seed_from_u64(2);
        let cfg = NerConfig::small(0.02);
        let mut g = generate(&cfg, &mut rng);
        for e in 0..g.num_edges() as u32 {
            let edge = g.edge(e);
            let src_np = (edge.src as usize) < cfg.num_np;
            let dst_np = (edge.dst as usize) < cfg.num_np;
            assert_ne!(src_np, dst_np, "edge {e} not bipartite");
        }
        // vertex kinds recorded
        assert!(g.vertex_data(0).is_np);
        assert!(!g.vertex_data(cfg.num_np as u32).is_np);
    }

    #[test]
    fn has_seeds_and_skewed_degrees() {
        let mut rng = Pcg32::seed_from_u64(3);
        let cfg = NerConfig::small(0.05);
        let mut g = generate(&cfg, &mut rng);
        let seeds = (0..g.num_vertices() as u32).filter(|&v| g.vertex_data(v).seed).count();
        assert!(seeds > 0, "need seed labels");
        // context degree skew: max degree far above mean
        let ct0 = cfg.num_np as u32;
        let degs: Vec<usize> =
            (ct0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        let max = *degs.iter().max().unwrap() as f64;
        assert!(max > 5.0 * mean, "max {max} vs mean {mean} — expected Zipf skew");
    }
}
