//! Protein–protein interaction MRF stand-in (paper §4.2): the paper's factor
//! graph (from Elidan et al. 2006) has ~14K vertices, ~100K edges, and a
//! greedy coloring with ~20 colors whose class sizes are **heavily skewed**
//! (Fig 5b) — that skew is what limits Gibbs scaling to ~10×/16. The
//! generator reproduces those structural facts: a hub-skewed random graph
//! whose greedy coloring needs many colors with a skewed histogram.

use crate::apps::gibbs::{GibbsEdge, GibbsVertex};
use crate::apps::mrf::EdgePotential;
use crate::graph::{DataGraph, GraphBuilder};
use crate::util::Pcg32;

/// Generated protein-network-like Gibbs task.
pub struct ProteinNetwork {
    pub graph: DataGraph<GibbsVertex, GibbsEdge>,
    /// Shared pairwise potential tables (K×K).
    pub tables: Vec<Vec<f32>>,
    pub arity: usize,
}

/// Generate with `n` vertices and ~`m` undirected edges. Defaults matching
/// the paper's scale (14K vertices, 100K edges) are used by the benches at
/// reduced size; `arity` is the variable cardinality.
pub fn generate(n: usize, m: usize, arity: usize, rng: &mut Pcg32) -> ProteinNetwork {
    let mut b: GraphBuilder<GibbsVertex, GibbsEdge> = GraphBuilder::with_capacity(n, 2 * m);
    for _ in 0..n {
        let pot: Vec<f32> = (0..arity).map(|_| 0.3 + rng.next_f32()).collect();
        b.add_vertex(GibbsVertex::new(pot));
    }
    // symmetric attractive/repulsive tables
    let mut tables = Vec::new();
    for t in 0..6 {
        let strength = 0.3 + 0.1 * t as f32;
        let attract = t % 2 == 0;
        let mut tab = vec![0.0f32; arity * arity];
        for i in 0..arity {
            for j in 0..arity {
                let same = i == j;
                tab[i * arity + j] =
                    if same == attract { 1.0 } else { (1.0 - strength).max(0.1) };
            }
        }
        tables.push(tab);
    }
    // A deliberately clustered + hub-skewed topology: a few dense cliques
    // (protein complexes) + zipf-biased background edges. Dense cliques force
    // the greedy coloring to use many colors; zipf hubs skew class sizes.
    let mut seen = std::collections::HashSet::new();
    let clique_count = (n / 400).max(1);
    let clique_size = 18.min(n);
    let mut added = 0usize;
    for c in 0..clique_count {
        let base: Vec<u32> =
            (0..clique_size).map(|_| rng.gen_range(n as u32)).collect();
        let _ = c;
        for (a, &u) in base.iter().enumerate() {
            for &v in &base[a + 1..] {
                if u != v && seen.insert((u.min(v), u.max(v))) && added < m {
                    let t = rng.gen_range(tables.len() as u32);
                    let e = GibbsEdge { potential: EdgePotential::Table(t) };
                    b.add_undirected(u, v, e, e);
                    added += 1;
                }
            }
        }
    }
    let mut attempts = 0usize;
    let mut degree = vec![0usize; n];
    let cap = (8 * m / n).clamp(16, 72); // hubs in the tens, as in real PPI data
    while added < m && attempts < m * 20 {
        attempts += 1;
        let u = rng.next_zipf(n, 0.9) as u32;
        let v = rng.gen_range(n as u32);
        if u == v || degree[u as usize] >= cap || degree[v as usize] >= cap {
            continue;
        }
        if !seen.insert((u.min(v), u.max(v))) {
            continue;
        }
        degree[u as usize] += 1;
        degree[v as usize] += 1;
        let t = rng.gen_range(tables.len() as u32);
        let e = GibbsEdge { potential: EdgePotential::Table(t) };
        b.add_undirected(u, v, e, e);
        added += 1;
    }
    ProteinNetwork { graph: b.build(), tables, arity }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::coloring::{color_classes, validate_coloring, ColoringUpdate};
    use crate::consistency::ConsistencyModel;
    use crate::engine::{Program, ThreadedEngine};
    use crate::scheduler::{FifoScheduler, Scheduler, Task};
    use crate::sdt::Sdt;

    #[test]
    fn generates_requested_size() {
        let mut rng = Pcg32::seed_from_u64(1);
        let net = generate(1000, 4000, 4, &mut rng);
        assert_eq!(net.graph.num_vertices(), 1000);
        assert!(net.graph.num_edges() as f64 >= 2.0 * 4000.0 * 0.9);
    }

    #[test]
    fn coloring_is_many_colored_and_skewed() {
        // the Fig 5b structural property: many colors, skewed class sizes
        let mut rng = Pcg32::seed_from_u64(2);
        let net = generate(1400, 10000, 4, &mut rng);
        let mut g = net.graph;
        let n = g.num_vertices();
        let sched = FifoScheduler::new(n);
        for v in 0..n as u32 {
            sched.add_task(Task::new(v));
        }
        let sdt = Sdt::new();
        let upd = ColoringUpdate;
        Program::new()
            .update_fn(&upd)
            .workers(2)
            .model(ConsistencyModel::Edge)
            .run_on(&ThreadedEngine, &mut g, &sched, &sdt);
        let ncolors = validate_coloring(&mut g).unwrap();
        assert!(ncolors >= 10, "expected many colors, got {ncolors}");
        let classes = color_classes(&mut g);
        let sizes: Vec<usize> = classes.iter().map(|c| c.len()).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().filter(|&&s| s > 0).min().unwrap();
        assert!(max > 10 * min.max(1), "skew expected: {sizes:?}");
    }
}
