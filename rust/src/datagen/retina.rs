//! Synthetic 3-D "retinal scan" volumes (paper §4.1: 256×64×64 laser density
//! estimates). The generator produces smooth axial strata — retina-like
//! layered structure — modulated by low-frequency undulation, plus speckle
//! noise, quantized to `k` intensity levels. The MRF topology (6-connected
//! grid, axis-labelled Laplace potentials) is identical to the paper's.

use crate::apps::mrf::{grid3d, GridDims, Mrf};
use crate::util::Pcg32;

/// A generated denoising task.
pub struct RetinaVolume {
    pub dims: GridDims,
    /// Clean quantized levels (ground truth), length `dims.len()`.
    pub clean: Vec<u32>,
    /// Noisy observed levels.
    pub noisy: Vec<u32>,
    /// Number of intensity levels (MRF arity).
    pub k: usize,
}

/// Generate the layered volume. `noise` is the per-voxel corruption
/// probability (a corrupted voxel jumps to a random level — speckle).
pub fn generate(dims: GridDims, k: usize, noise: f64, rng: &mut Pcg32) -> RetinaVolume {
    assert!(k >= 2);
    let mut clean = Vec::with_capacity(dims.len());
    // Random layer boundaries along z with smooth (x, y) undulation.
    let n_layers = (k).min(6);
    let phase_x = rng.range_f64(0.0, std::f64::consts::TAU);
    let phase_y = rng.range_f64(0.0, std::f64::consts::TAU);
    let amp = dims.nz as f64 * 0.08;
    let layer_level: Vec<u32> =
        (0..n_layers).map(|i| ((i * (k - 1)) / (n_layers - 1).max(1)) as u32).collect();
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            for x in 0..dims.nx {
                let _ = dims.index(x, y, z);
                let undulation = amp
                    * ((x as f64 / dims.nx as f64 * std::f64::consts::TAU + phase_x).sin()
                        + (y as f64 / dims.ny as f64 * std::f64::consts::TAU + phase_y).cos())
                    / 2.0;
                let zz = (z as f64 + undulation).clamp(0.0, dims.nz as f64 - 1.0);
                let layer = ((zz / dims.nz as f64) * n_layers as f64) as usize;
                clean.push(layer_level[layer.min(n_layers - 1)]);
            }
        }
    }
    // reorder: the loop above pushed in x-fastest order already matching index()
    let noisy: Vec<u32> = clean
        .iter()
        .map(|&c| {
            if rng.next_f64() < noise {
                rng.gen_range(k as u32)
            } else {
                c
            }
        })
        .collect();
    RetinaVolume { dims, clean, noisy, k }
}

/// Robust observation potentials around the noisy level: a Gaussian data
/// term mixed with a uniform outlier floor (speckle noise replaces voxels
/// with arbitrary levels, so the likelihood must not vanish off-peak):
/// φ_v(x) = (1-π) exp(-(x − obs)² / (2σ²)) + π/K.
pub fn observation_potential(obs: u32, k: usize, sigma: f32) -> Vec<f32> {
    let outlier = 0.25f32;
    (0..k)
        .map(|x| {
            let d = x as f32 - obs as f32;
            (1.0 - outlier) * (-d * d / (2.0 * sigma * sigma)).exp() + outlier / k as f32
        })
        .collect()
}

/// Build the denoising MRF from a volume: node potentials from the noisy
/// observations, 6-connected Laplace edges.
pub fn build_mrf(vol: &RetinaVolume, sigma: f32) -> Mrf {
    let mut mrf = grid3d(vol.dims, vol.k, |v| {
        observation_potential(vol.noisy[v as usize], vol.k, sigma)
    });
    for v in 0..mrf.graph.num_vertices() as u32 {
        mrf.graph.vertex_data(v).observed = vol.noisy[v as usize];
    }
    mrf
}

/// Axis-aligned window average of the noisy volume — the paper's "proxy for
/// ground-truth smoothed images" used to fix the learning targets.
pub fn smoothed_proxy(vol: &RetinaVolume, radius: usize) -> Vec<f32> {
    let dims = vol.dims;
    let mut out = vec![0.0f32; dims.len()];
    for z in 0..dims.nz {
        for y in 0..dims.ny {
            for x in 0..dims.nx {
                let mut sum = 0.0f32;
                let mut cnt = 0.0f32;
                let r = radius as isize;
                for (dx, dy, dz) in
                    (-r..=r).flat_map(|a| (-r..=r).flat_map(move |b| (-r..=r).map(move |c| (a, b, c))))
                {
                    let (xx, yy, zz) =
                        (x as isize + dx, y as isize + dy, z as isize + dz);
                    if xx >= 0
                        && yy >= 0
                        && zz >= 0
                        && (xx as usize) < dims.nx
                        && (yy as usize) < dims.ny
                        && (zz as usize) < dims.nz
                    {
                        sum += vol.noisy[dims.index(xx as usize, yy as usize, zz as usize) as usize]
                            as f32;
                        cnt += 1.0;
                    }
                }
                out[dims.index(x, y, z) as usize] = sum / cnt;
            }
        }
    }
    out
}

/// Fraction of voxels whose noisy level differs from the clean level.
pub fn error_rate(reference: &[u32], test: &[u32]) -> f64 {
    let wrong = reference.iter().zip(test).filter(|(a, b)| a != b).count();
    wrong as f64 / reference.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_is_layered_and_noisy() {
        let mut rng = Pcg32::seed_from_u64(1);
        let dims = GridDims::new(16, 16, 16);
        let vol = generate(dims, 5, 0.2, &mut rng);
        assert_eq!(vol.clean.len(), dims.len());
        // layering: top and bottom slabs differ
        let top = vol.clean[dims.index(8, 8, 0) as usize];
        let bottom = vol.clean[dims.index(8, 8, 15) as usize];
        assert_ne!(top, bottom, "layers must vary along z");
        // noise actually corrupts around the requested rate
        let rate = error_rate(&vol.clean, &vol.noisy);
        assert!(rate > 0.1 && rate < 0.3, "rate={rate}");
        // all levels in range
        assert!(vol.noisy.iter().all(|&l| l < 5));
    }

    #[test]
    fn clean_volume_is_smooth_in_xy() {
        let mut rng = Pcg32::seed_from_u64(3);
        let dims = GridDims::new(12, 12, 12);
        let vol = generate(dims, 5, 0.0, &mut rng);
        // neighboring x voxels rarely differ (smooth undulation)
        let mut diffs = 0;
        let mut total = 0;
        for z in 0..12 {
            for y in 0..12 {
                for x in 0..11 {
                    total += 1;
                    if vol.clean[dims.index(x, y, z) as usize]
                        != vol.clean[dims.index(x + 1, y, z) as usize]
                    {
                        diffs += 1;
                    }
                }
            }
        }
        assert!((diffs as f64) < 0.15 * total as f64, "{diffs}/{total} x-jumps");
    }

    #[test]
    fn observation_potential_peaks_at_observation() {
        let pot = observation_potential(2, 5, 1.0);
        let argmax = pot
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax, 2);
        assert!(pot.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn build_mrf_wires_observations() {
        let mut rng = Pcg32::seed_from_u64(5);
        let dims = GridDims::new(4, 4, 4);
        let vol = generate(dims, 4, 0.1, &mut rng);
        let mut mrf = build_mrf(&vol, 1.0);
        assert_eq!(mrf.graph.num_vertices(), 64);
        for v in 0..64u32 {
            assert_eq!(mrf.graph.vertex_data(v).observed, vol.noisy[v as usize]);
        }
    }

    #[test]
    fn smoothing_reduces_noise_variance() {
        let mut rng = Pcg32::seed_from_u64(7);
        let dims = GridDims::new(10, 10, 10);
        let vol = generate(dims, 5, 0.3, &mut rng);
        let smooth = smoothed_proxy(&vol, 1);
        // smoothed volume is closer to clean (in MSE) than the noisy one
        let mse = |xs: &[f32]| -> f64 {
            xs.iter()
                .zip(&vol.clean)
                .map(|(a, &c)| (*a as f64 - c as f64).powi(2))
                .sum::<f64>()
                / xs.len() as f64
        };
        let noisy_f: Vec<f32> = vol.noisy.iter().map(|&x| x as f32).collect();
        assert!(mse(&smooth) < mse(&noisy_f));
    }
}
