//! Synthetic financial-report regression data for the Lasso experiment
//! (paper §4.4): word counts of 10-K reports predicting stock volatility
//! (Kogan et al. 2009). The paper derives two variants by deleting common
//! words: *sparser* (209K features, 1.2M non-zeros) and *denser* (217K
//! features, 3.5M non-zeros) — the density contrast is what drives the
//! full-consistency contention result in Fig 7.
//!
//! The generator emits a scaled bag-of-words-like design matrix: Zipf word
//! frequencies (common words appear in many documents — exactly what makes
//! the graph denser), log-count values, and a sparse ground-truth weight
//! vector producing the targets.

use crate::apps::lasso::LassoProblem;
use crate::util::Pcg32;

/// Configuration: `docs` observations, `features` words.
#[derive(Debug, Clone)]
pub struct FinanceConfig {
    pub docs: usize,
    pub features: usize,
    /// Average non-zeros per document.
    pub nnz_per_doc: usize,
    /// Zipf skew of word frequencies (higher = a few very common words).
    pub skew: f64,
}

impl FinanceConfig {
    /// Sparser variant (common words deleted): low per-doc density.
    pub fn sparser(scale: f64) -> FinanceConfig {
        FinanceConfig {
            docs: (1500.0 * scale) as usize,
            features: (10_000.0 * scale) as usize,
            nnz_per_doc: 40,
            skew: 0.7,
        }
    }

    /// Denser variant (common words kept): ~3x the non-zeros, heavier skew
    /// (hub features shared by many documents).
    pub fn denser(scale: f64) -> FinanceConfig {
        FinanceConfig {
            docs: (1500.0 * scale) as usize,
            features: (10_000.0 * scale) as usize,
            nnz_per_doc: 120,
            skew: 1.1,
        }
    }
}

/// Generate the problem plus the ground-truth weights used for the targets.
pub fn generate(cfg: &FinanceConfig, rng: &mut Pcg32) -> (LassoProblem, Vec<f64>) {
    let d = cfg.features;
    // sparse ground truth: 2% of features matter
    let mut w_true = vec![0.0f64; d];
    for _ in 0..(d / 50).max(2) {
        w_true[rng.gen_range(d as u32) as usize] = rng.range_f64(-2.0, 2.0);
    }
    let mut rows = Vec::with_capacity(cfg.docs);
    let mut y = Vec::with_capacity(cfg.docs);
    for _ in 0..cfg.docs {
        let mut idx = std::collections::HashSet::new();
        while idx.len() < cfg.nnz_per_doc.min(d) {
            idx.insert(rng.next_zipf(d, cfg.skew) as u32);
        }
        let row: Vec<(u32, f32)> = idx
            .into_iter()
            .map(|i| {
                // log(1 + count) with Zipf-ish counts
                let count = 1 + rng.next_zipf(30, 1.4);
                (i, (1.0 + count as f32).ln())
            })
            .collect();
        let target: f64 = row
            .iter()
            .map(|&(i, x)| x as f64 * w_true[i as usize])
            .sum::<f64>()
            + 0.05 * rng.next_gaussian();
        rows.push(row);
        y.push(target as f32);
    }
    (LassoProblem::from_sparse(d, &rows, &y), w_true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn denser_variant_has_more_nonzeros() {
        let mut rng = Pcg32::seed_from_u64(1);
        let (sparse, _) = generate(&FinanceConfig::sparser(0.05), &mut rng);
        let mut rng = Pcg32::seed_from_u64(1);
        let (dense, _) = generate(&FinanceConfig::denser(0.05), &mut rng);
        assert!(dense.graph.num_edges() > 2 * sparse.graph.num_edges());
    }

    #[test]
    fn structure_is_bipartite_and_sized() {
        let mut rng = Pcg32::seed_from_u64(2);
        let cfg = FinanceConfig::sparser(0.05);
        let (p, w_true) = generate(&cfg, &mut rng);
        assert_eq!(p.num_weights, cfg.features);
        assert_eq!(p.num_obs, cfg.docs);
        assert_eq!(w_true.len(), cfg.features);
        assert!(w_true.iter().filter(|w| w.abs() > 0.0).count() >= 2);
    }

    #[test]
    fn hub_features_exist_in_denser_variant() {
        let mut rng = Pcg32::seed_from_u64(3);
        let (p, _) = generate(&FinanceConfig::denser(0.05), &mut rng);
        let g = p.graph;
        let degs: Vec<usize> = (0..p.num_weights as u32).map(|v| g.degree(v)).collect();
        let mean = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        let max = *degs.iter().max().unwrap() as f64;
        assert!(max > 3.0 * mean.max(0.1), "hub features drive Fig 7 contention: max={max} mean={mean}");
        let _ = g.num_vertices();
    }
}
