//! Telemetry **exporters**, both hand-written (the crate deliberately has
//! no JSON dependency):
//!
//! * [`write_chrome_trace`] — Chrome `trace_event` JSON, loadable in
//!   Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`: one
//!   track (`tid`) per worker ring, spans as `"X"` complete events,
//!   instants as `"i"` events, and cross-shard delta→apply edges as
//!   `"s"`/`"f"` async flow arrows paired by `(vertex, version)`.
//!   `wire_send`/`wire_apply` instants are widened to 1µs `"X"` slices so
//!   the flow arrows have slices to anchor to. Within a track every
//!   slice/instant is written in non-decreasing `ts` order.
//! * [`write_metrics_jsonl`] — one JSON object per line per
//!   [`MetricSample`], ready for `jq`/pandas.

use super::ring::{Event, EventKind, ALL_KINDS};
use super::sampler::MetricSample;
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Escape a string for a JSON string literal (labels are the only
/// caller-controlled strings in the trace).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Microseconds (3 decimals — full ns precision) for a ns timestamp.
fn us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1e3)
}

fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

fn kind_of(ev: &Event) -> EventKind {
    ALL_KINDS[ev.kind as usize]
}

/// Write `tracks` (label + time-sorted events per ring) as Chrome
/// `trace_event` JSON. `flow_cap` bounds the delta→apply arrow count.
pub(crate) fn write_chrome_trace(
    path: &Path,
    tracks: &[(String, Vec<Event>)],
    flow_cap: usize,
) -> std::io::Result<()> {
    ensure_parent(path)?;
    let mut out = BufWriter::new(File::create(path)?);
    out.write_all(b"{\"traceEvents\":[\n")?;
    let mut first = true;
    let mut emit = |out: &mut BufWriter<File>, line: &str| -> std::io::Result<()> {
        if first {
            first = false;
        } else {
            out.write_all(b",\n")?;
        }
        out.write_all(line.as_bytes())
    };
    emit(
        &mut out,
        "{\"ph\":\"M\",\"pid\":0,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"graphlab\"}}",
    )?;
    for (tid, (label, _)) in tracks.iter().enumerate() {
        emit(
            &mut out,
            &format!(
                "{{\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                escape(label)
            ),
        )?;
    }
    for (tid, (_, events)) in tracks.iter().enumerate() {
        for ev in events {
            let kind = kind_of(ev);
            let (name, cat) = (kind.name(), kind.category());
            let args = format!("{{\"a\":{},\"b\":{}}}", ev.a, ev.b);
            let line = if kind.is_span() {
                format!(
                    "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"ts\":{},\"dur\":{},\
                     \"name\":\"{name}\",\"cat\":\"{cat}\",\"args\":{args}}}",
                    us(ev.t_ns),
                    us(ev.dur_ns.max(1)),
                )
            } else if matches!(kind, EventKind::WireSend | EventKind::WireApply) {
                // Widened to a 1µs slice so flow arrows have an anchor.
                format!(
                    "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"ts\":{},\"dur\":1.000,\
                     \"name\":\"{name}\",\"cat\":\"{cat}\",\"args\":{args}}}",
                    us(ev.t_ns),
                )
            } else {
                format!(
                    "{{\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{tid},\"ts\":{},\
                     \"name\":\"{name}\",\"cat\":\"{cat}\",\"args\":{args}}}",
                    us(ev.t_ns),
                )
            };
            emit(&mut out, &line)?;
        }
    }
    // Cross-shard delta→apply flow arrows: pair the first send of a
    // (vertex, version) with its first not-earlier apply on another
    // track.
    let mut sends: HashMap<(u64, u64), (usize, u64)> = HashMap::new();
    for (tid, (_, events)) in tracks.iter().enumerate() {
        for ev in events {
            if kind_of(ev) == EventKind::WireSend {
                sends.entry((ev.a, ev.b)).or_insert((tid, ev.t_ns));
            }
        }
    }
    let mut arrows = 0usize;
    'outer: for (tid, (_, events)) in tracks.iter().enumerate() {
        for ev in events {
            if kind_of(ev) != EventKind::WireApply {
                continue;
            }
            let Some(&(src_tid, src_ns)) = sends.get(&(ev.a, ev.b)) else { continue };
            if src_tid == tid || ev.t_ns < src_ns {
                continue;
            }
            sends.remove(&(ev.a, ev.b));
            emit(
                &mut out,
                &format!(
                    "{{\"ph\":\"s\",\"id\":{arrows},\"pid\":0,\"tid\":{src_tid},\
                     \"ts\":{},\"name\":\"delta\",\"cat\":\"wire\"}}",
                    us(src_ns),
                ),
            )?;
            emit(
                &mut out,
                &format!(
                    "{{\"ph\":\"f\",\"bp\":\"e\",\"id\":{arrows},\"pid\":0,\"tid\":{tid},\
                     \"ts\":{},\"name\":\"delta\",\"cat\":\"wire\"}}",
                    us(ev.t_ns),
                ),
            )?;
            arrows += 1;
            if arrows >= flow_cap {
                break 'outer;
            }
        }
    }
    out.write_all(b"\n]}\n")?;
    out.flush()
}

/// Write the sampled time series as JSONL: one object per sample.
pub(crate) fn write_metrics_jsonl(
    path: &Path,
    samples: &[MetricSample],
) -> std::io::Result<()> {
    ensure_parent(path)?;
    let mut out = BufWriter::new(File::create(path)?);
    for s in samples {
        let hist: Vec<String> = s.lag_hist.iter().map(u64::to_string).collect();
        let progress = match s.progress {
            Some(p) if p.is_finite() => format!("{p}"),
            _ => "null".to_string(),
        };
        writeln!(
            out,
            "{{\"t_ms\":{:.3},\"tasks\":{},\"tasks_per_sec\":{:.3},\
             \"queue_depth\":{},\"retry_depth\":{},\"ghost_bytes\":{},\
             \"lag_hist\":[{}],\"progress\":{}}}",
            s.t_ms,
            s.tasks,
            s.tasks_per_sec,
            s.queue_depth,
            s.retry_depth,
            s.ghost_bytes,
            hist.join(","),
            progress,
        )?;
    }
    out.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::ring::LAG_BUCKETS;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("graphlab-telemetry-{}-{name}", std::process::id()))
    }

    fn ev(kind: EventKind, t_ns: u64, dur_ns: u64, a: u64, b: u64) -> Event {
        Event { kind: kind as u8, t_ns, dur_ns, a, b }
    }

    #[test]
    fn chrome_trace_structure_and_flow_arrows() {
        let tracks = vec![
            (
                "worker-0".to_string(),
                vec![
                    ev(EventKind::TaskExec, 1_000, 2_000, 5, 0),
                    ev(EventKind::WireSend, 4_000, 0, 7, 3),
                ],
            ),
            (
                "worker-1".to_string(),
                vec![
                    ev(EventKind::ScopeDefer, 2_000, 0, 9, 1),
                    ev(EventKind::WireApply, 9_000, 0, 7, 3),
                ],
            ),
        ];
        let path = tmp("trace.json");
        write_chrome_trace(&path, &tracks, 16).expect("trace export");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\"traceEvents\":[\n"));
        assert!(text.trim_end().ends_with("]}"));
        assert!(text.contains("\"thread_name\""), "track metadata present");
        assert!(text.contains("\"name\":\"worker-1\""));
        assert!(text.contains("\"ph\":\"X\"") && text.contains("\"name\":\"task\""));
        assert!(text.contains("\"ph\":\"i\"") && text.contains("\"name\":\"scope_defer\""));
        assert!(text.contains("\"ph\":\"s\""), "flow start for the delta edge");
        assert!(text.contains("\"ph\":\"f\""), "flow finish for the delta edge");
        assert_eq!(text.matches("\"id\":0").count(), 2, "one arrow, both endpoints");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flow_cap_bounds_the_arrow_count() {
        let sends: Vec<Event> =
            (0..10).map(|i| ev(EventKind::WireSend, 10 * i, 0, i, 1)).collect();
        let applies: Vec<Event> =
            (0..10).map(|i| ev(EventKind::WireApply, 1_000 + 10 * i, 0, i, 1)).collect();
        let tracks = vec![("a".to_string(), sends), ("b".to_string(), applies)];
        let path = tmp("trace-cap.json");
        write_chrome_trace(&path, &tracks, 3).expect("trace export");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("\"ph\":\"s\"").count(), 3, "arrows capped");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn metrics_jsonl_one_object_per_sample() {
        let samples = vec![
            MetricSample {
                t_ms: 0.5,
                tasks: 0,
                tasks_per_sec: 0.0,
                queue_depth: 10,
                retry_depth: 0,
                ghost_bytes: 0,
                lag_hist: [0; LAG_BUCKETS],
                progress: None,
            },
            MetricSample {
                t_ms: 10.5,
                tasks: 100,
                tasks_per_sec: 10_000.0,
                queue_depth: 4,
                retry_depth: 2,
                ghost_bytes: 640,
                lag_hist: [1, 2, 0, 0, 0, 0, 0, 0],
                progress: Some(0.25),
            },
        ];
        let path = tmp("metrics.jsonl");
        write_metrics_jsonl(&path, &samples).expect("metrics export");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"progress\":null"));
        assert!(lines[1].contains("\"progress\":0.25"));
        assert!(lines[1].contains("\"lag_hist\":[1,2,0,0,0,0,0,0]"));
        assert!(lines[1].contains("\"ghost_bytes\":640"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn labels_are_escaped() {
        assert_eq!(escape("worker \"0\"\\n"), "worker \\\"0\\\"\\\\n");
        assert_eq!(escape("tab\tend"), "tab\\u0009end");
    }
}
