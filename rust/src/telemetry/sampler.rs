//! The telemetry **sampler**: on a fixed cadence, collapse the live ring
//! counters into one [`MetricSample`] — tasks executed (cumulative and as
//! a rate against the previous sample), scheduler queue depth, retry-deque
//! depth, ghost bytes shipped, the observed-staleness histogram, and the
//! app-supplied convergence scalar. The threaded and sharded engines run
//! [`crate::telemetry::Telemetry::sample_loop`] on a dedicated thread
//! inside their worker scope; the sequential engine samples inline on its
//! update loop. The series exports as JSONL
//! ([`super::export::write_metrics_jsonl`]).

use super::ring::LAG_BUCKETS;

/// One fixed-interval observation of the run.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricSample {
    /// Milliseconds since the run clock origin.
    pub t_ms: f64,
    /// Tasks executed so far (cumulative; monotone across the series).
    pub tasks: u64,
    /// Task rate derived against the previous sample (0 for the first).
    pub tasks_per_sec: f64,
    /// Scheduler pending-task depth ([`crate::scheduler::Scheduler::approx_len`]).
    pub queue_depth: u64,
    /// Tasks parked in retry deques / overflow injectors at sample time.
    pub retry_depth: u64,
    /// Ghost bytes shipped so far (cumulative).
    pub ghost_bytes: u64,
    /// Observed replica-staleness histogram: bucket `i` counts reads that
    /// saw a lag in `[2^i - 1, 2^(i+1) - 2]` master versions (cumulative).
    pub lag_hist: [u64; LAG_BUCKETS],
    /// The app's convergence scalar
    /// ([`crate::engine::Program::progress_metric`]), probed at sample
    /// time; `None` when no hook is registered.
    pub progress: Option<f64>,
}

/// Where a sample's non-ring inputs come from. The closures are borrowed
/// from the engine's run scope (they typically capture the scheduler, the
/// retry-depth counter, and the SDT for the progress hook) and must be
/// callable from the sampler thread.
pub struct SampleSources<'a> {
    /// Pending tasks in the scheduler.
    pub queue_depth: &'a (dyn Fn() -> u64 + Sync),
    /// Tasks parked in retry deques / overflow injectors.
    pub retry_depth: &'a (dyn Fn() -> u64 + Sync),
    /// The convergence scalar, when the program registered one.
    pub progress: Option<&'a (dyn Fn() -> f64 + Sync)>,
}
