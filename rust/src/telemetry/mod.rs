//! **Engine telemetry**: always-compiled, runtime-gated tracing for every
//! engine back-end.
//!
//! Three pieces (see `docs/ARCHITECTURE.md` § Observability):
//!
//! * [`ring`] — per-worker bounded lock-free event rings of timestamped
//!   spans and instants ([`EventKind`] is the taxonomy), with a drop
//!   counter on overflow and per-kind atomic counts the sampler reads
//!   live;
//! * [`sampler`] — fixed-interval collapse of the rings into a
//!   [`MetricSample`] time series (tasks/sec, queue/retry depth, ghost
//!   bytes, staleness distribution, and the app's convergence scalar via
//!   [`Program::progress_metric`](crate::engine::Program::progress_metric));
//! * [`export`] — Chrome `trace_event` JSON (one track per worker, async
//!   arrows for cross-shard delta→apply edges; loadable in Perfetto or
//!   `chrome://tracing`) plus a JSONL metrics stream.
//!
//! The whole subsystem is off unless the run carries a
//! [`TelemetryConfig`] (via
//! [`Program::telemetry`](crate::engine::Program::telemetry)): engines
//! then build one [`Telemetry`] per run, bind each worker thread to its
//! ring, and the emit points scattered through the engines, scheduler,
//! scope admission, and transports record through a thread-local binding
//! — a disabled run allocates nothing and every emit call collapses to
//! one thread-local read and a branch.

pub mod clock;
pub mod export;
pub mod ring;
pub mod sampler;

pub use clock::{MonoClock, SpanStart};
pub use ring::{Event, EventKind, WorkerRing, ALL_KINDS, KIND_COUNT, LAG_BUCKETS};
pub use sampler::{MetricSample, SampleSources};

use std::cell::Cell;
use std::marker::PhantomData;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Runtime telemetry knobs, handed to
/// [`Program::telemetry`](crate::engine::Program::telemetry). Presence of
/// a config is the enable switch — a run without one pays nothing.
#[derive(Clone, Debug)]
pub struct TelemetryConfig {
    /// Events retained per worker ring; overflow drops (counted).
    pub ring_capacity: usize,
    /// Sampler cadence (a first and a final sample always happen, so even
    /// runs shorter than one interval produce a usable series).
    pub sample_interval: Duration,
    /// When set, the run writes a Chrome `trace_event` JSON file here.
    pub trace_path: Option<PathBuf>,
    /// When set, the run writes the metric samples as JSONL here.
    pub metrics_path: Option<PathBuf>,
    /// Cap on exported delta→apply flow arrows (bounds trace file size).
    pub flow_arrow_cap: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            ring_capacity: 1 << 15,
            sample_interval: Duration::from_millis(10),
            trace_path: None,
            metrics_path: None,
            flow_arrow_cap: 2048,
        }
    }
}

impl TelemetryConfig {
    /// Set the per-worker ring capacity (events).
    pub fn with_ring_capacity(mut self, events: usize) -> Self {
        self.ring_capacity = events;
        self
    }

    /// Set the sampler cadence.
    pub fn with_sample_interval(mut self, interval: Duration) -> Self {
        self.sample_interval = interval;
        self
    }

    /// Write a Chrome `trace_event` JSON file at run end.
    pub fn with_trace_path(mut self, path: PathBuf) -> Self {
        self.trace_path = Some(path);
        self
    }

    /// Write the metric samples as JSONL at run end.
    pub fn with_metrics_path(mut self, path: PathBuf) -> Self {
        self.metrics_path = Some(path);
        self
    }

    /// Cap exported delta→apply flow arrows.
    pub fn with_flow_arrow_cap(mut self, arrows: usize) -> Self {
        self.flow_arrow_cap = arrows;
        self
    }
}

/// What a worker thread's emit calls resolve against: its ring and the
/// run clock origin.
#[derive(Clone, Copy)]
struct Bound {
    ring: *const WorkerRing,
    origin: Instant,
}

thread_local! {
    static CURRENT: Cell<Option<Bound>> = const { Cell::new(None) };
}

/// Sentinel [`span_start`] returns when telemetry is unbound on this
/// thread; [`span_end`] treats it as "no span open".
pub const SPAN_OFF: u64 = u64::MAX;

/// Open a span: the current run-clock time, or [`SPAN_OFF`] when this
/// thread has no telemetry binding (the disabled fast path: one
/// thread-local read and a branch).
#[inline]
pub fn span_start() -> u64 {
    CURRENT.with(|c| match c.get() {
        Some(b) => b.origin.elapsed().as_nanos() as u64,
        None => SPAN_OFF,
    })
}

/// Close a span opened by [`span_start`] and record it.
#[inline]
pub fn span_end(kind: EventKind, start_ns: u64, a: u64, b: u64) {
    if start_ns == SPAN_OFF {
        return;
    }
    CURRENT.with(|c| {
        if let Some(bound) = c.get() {
            let now = bound.origin.elapsed().as_nanos() as u64;
            // SAFETY: the binding guard keeps the ring alive and bound to
            // this thread (see `WorkerBinding`).
            let ring = unsafe { &*bound.ring };
            ring.push(Event {
                kind: kind as u8,
                t_ns: start_ns,
                dur_ns: now.saturating_sub(start_ns),
                a,
                b,
            });
        }
    });
}

/// Record a span whose timing was measured externally on the same run
/// clock (the sequential engine's trace-cost path: one measurement feeds
/// both the [`crate::engine::trace::TraceEvent`] and this ring).
#[inline]
pub fn span_at(kind: EventKind, start_ns: u64, dur_ns: u64, a: u64, b: u64) {
    CURRENT.with(|c| {
        if let Some(bound) = c.get() {
            // SAFETY: as in `span_end`.
            let ring = unsafe { &*bound.ring };
            ring.push(Event { kind: kind as u8, t_ns: start_ns, dur_ns, a, b });
        }
    });
}

/// Record an instant event at the current run-clock time.
#[inline]
pub fn instant(kind: EventKind, a: u64, b: u64) {
    CURRENT.with(|c| {
        if let Some(bound) = c.get() {
            let now = bound.origin.elapsed().as_nanos() as u64;
            // SAFETY: as in `span_end`.
            let ring = unsafe { &*bound.ring };
            ring.push(Event { kind: kind as u8, t_ns: now, dur_ns: 0, a, b });
        }
    });
}

/// Add to the bound ring's ghost-bytes-shipped gauge (sampler input).
#[inline]
pub fn add_ghost_bytes(n: u64) {
    CURRENT.with(|c| {
        if let Some(bound) = c.get() {
            // SAFETY: as in `span_end`.
            unsafe { &*bound.ring }.add_ghost_bytes(n);
        }
    });
}

/// Record one observed replica staleness in the bound ring's histogram.
#[inline]
pub fn observe_lag(lag: u64) {
    CURRENT.with(|c| {
        if let Some(bound) = c.get() {
            // SAFETY: as in `span_end`.
            unsafe { &*bound.ring }.observe_lag(lag);
        }
    });
}

/// RAII guard for a worker thread's ring binding: restores the previous
/// binding on drop. Deliberately `!Send` (the binding is thread-local).
pub struct WorkerBinding {
    prev: Option<Bound>,
    _not_send: PhantomData<*const ()>,
}

impl Drop for WorkerBinding {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

/// One run's telemetry state: the config, the run clock, one ring per
/// track (workers plus one "engine" control track), and the sampled time
/// series. Engines create it when the run config carries a
/// [`TelemetryConfig`], bind worker threads to rings for the run's
/// duration, and [`Telemetry::finish`] it into the
/// [`RunReport`](crate::engine::RunReport) after the workers joined.
pub struct Telemetry {
    cfg: TelemetryConfig,
    clock: MonoClock,
    rings: Vec<WorkerRing>,
    labels: Vec<String>,
    samples: Mutex<Vec<MetricSample>>,
}

impl Telemetry {
    /// One ring per entry of `labels` (track names in the trace export).
    pub fn new(cfg: TelemetryConfig, labels: Vec<String>) -> Telemetry {
        assert!(!labels.is_empty(), "telemetry needs at least one track");
        let rings = labels.iter().map(|_| WorkerRing::new(cfg.ring_capacity)).collect();
        Telemetry { cfg, clock: MonoClock::start(), rings, labels, samples: Mutex::new(Vec::new()) }
    }

    /// The run clock (copy; same timeline as every recorded event).
    pub fn clock(&self) -> MonoClock {
        self.clock
    }

    /// Number of tracks (rings).
    pub fn tracks(&self) -> usize {
        self.rings.len()
    }

    /// The configured sampler cadence (inline samplers honor it too).
    pub fn sample_interval(&self) -> Duration {
        self.cfg.sample_interval
    }

    /// Direct ring access (tests and live diagnostics).
    pub fn ring(&self, track: usize) -> &WorkerRing {
        &self.rings[track]
    }

    /// Bind the calling thread to track `track`'s ring until the returned
    /// guard drops. At most one thread may be bound to a given ring at a
    /// time — that is the rings' single-producer contract.
    pub fn bind_worker(&self, track: usize) -> WorkerBinding {
        let bound = Bound { ring: &self.rings[track], origin: self.clock.origin() };
        let prev = CURRENT.with(|c| c.replace(Some(bound)));
        WorkerBinding { prev, _not_send: PhantomData }
    }

    /// Live sum of `kind` counts across every ring.
    pub fn total_count(&self, kind: EventKind) -> u64 {
        self.rings.iter().map(|r| r.count(kind)).sum()
    }

    /// Take one metric sample right now (also used by the sequential
    /// engine, which samples inline instead of from a thread).
    pub fn sample_now(&self, sources: &SampleSources<'_>) {
        let t_ms = self.clock.now_ns() as f64 / 1e6;
        let tasks = self.total_count(EventKind::TaskExec);
        let ghost_bytes: u64 = self.rings.iter().map(WorkerRing::ghost_bytes).sum();
        let mut lag_hist = [0u64; LAG_BUCKETS];
        for ring in &self.rings {
            for (acc, n) in lag_hist.iter_mut().zip(ring.lag_hist()) {
                *acc += n;
            }
        }
        let queue_depth = (sources.queue_depth)();
        let retry_depth = (sources.retry_depth)();
        let progress = sources.progress.map(|f| f());
        let mut samples = self.samples.lock().unwrap();
        let tasks_per_sec = match samples.last() {
            Some(prev) if t_ms > prev.t_ms => {
                (tasks - prev.tasks) as f64 / ((t_ms - prev.t_ms) / 1e3)
            }
            _ => 0.0,
        };
        samples.push(MetricSample {
            t_ms,
            tasks,
            tasks_per_sec,
            queue_depth,
            retry_depth,
            ghost_bytes,
            lag_hist,
            progress,
        });
    }

    /// The sampler loop: an immediate sample, one per
    /// [`TelemetryConfig::sample_interval`] until `done`, and a final
    /// sample on the way out. Engines run this on a dedicated thread
    /// inside their worker scope.
    pub fn sample_loop(&self, done: &AtomicBool, sources: &SampleSources<'_>) {
        self.sample_now(sources);
        let mut last = Instant::now();
        while !done.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_micros(500));
            if last.elapsed() >= self.cfg.sample_interval {
                self.sample_now(sources);
                last = Instant::now();
            }
        }
        self.sample_now(sources);
    }

    /// Collapse the run's telemetry into a [`TelemetryReport`], writing
    /// the configured trace/metrics exports. Call after every bound
    /// thread has joined (the rings' read-after-join contract). Export IO
    /// failures are reported on stderr and leave the corresponding path
    /// unset in the report — telemetry must never fail a run.
    pub fn finish(self) -> TelemetryReport {
        let mut counts = [0u64; KIND_COUNT];
        let mut events_dropped = 0u64;
        let mut events_recorded = 0u64;
        let mut tracks: Vec<(String, Vec<Event>)> = Vec::with_capacity(self.rings.len());
        for (label, ring) in self.labels.iter().zip(&self.rings) {
            for kind in ALL_KINDS {
                counts[kind as usize] += ring.count(kind);
            }
            events_dropped += ring.dropped();
            let mut events = ring.snapshot_events();
            events_recorded += events.len() as u64;
            events.sort_by_key(|e| e.t_ns);
            tracks.push((label.clone(), events));
        }
        let samples = self.samples.into_inner().unwrap();
        let mut trace_path = None;
        if let Some(path) = &self.cfg.trace_path {
            match export::write_chrome_trace(path, &tracks, self.cfg.flow_arrow_cap) {
                Ok(()) => trace_path = Some(path.clone()),
                Err(e) => eprintln!("graphlab telemetry: writing trace {path:?} failed: {e}"),
            }
        }
        let mut metrics_path = None;
        if let Some(path) = &self.cfg.metrics_path {
            match export::write_metrics_jsonl(path, &samples) {
                Ok(()) => metrics_path = Some(path.clone()),
                Err(e) => eprintln!("graphlab telemetry: writing metrics {path:?} failed: {e}"),
            }
        }
        TelemetryReport {
            events_recorded,
            events_dropped,
            counts,
            samples,
            trace_path,
            metrics_path,
            tracks,
        }
    }
}

/// The telemetry section of a [`RunReport`](crate::engine::RunReport):
/// per-kind event counts, the sampled time series, and where the exports
/// landed. `None` in the report means telemetry was off for the run.
#[derive(Debug, Clone)]
pub struct TelemetryReport {
    /// Events retained in the rings.
    pub events_recorded: u64,
    /// Events dropped on ring overflow (counted, never silent).
    pub events_dropped: u64,
    /// Per-kind emit counts, indexed by [`EventKind`] (dropped events
    /// still count — conservation checks rely on it).
    counts: [u64; KIND_COUNT],
    /// The sampled time series, in time order.
    pub samples: Vec<MetricSample>,
    /// Chrome trace file actually written (unset on IO failure or when
    /// not configured).
    pub trace_path: Option<PathBuf>,
    /// JSONL metrics file actually written.
    pub metrics_path: Option<PathBuf>,
    /// The retained events, per track (worker rings plus the engine
    /// control track), each sorted by start time — the same view the
    /// trace exporter wrote.
    pub tracks: Vec<(String, Vec<Event>)>,
}

impl TelemetryReport {
    /// Events emitted for `kind` (including dropped ring slots).
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Total events emitted (recorded + dropped).
    pub fn total_events(&self) -> u64 {
        self.events_recorded + self.events_dropped
    }

    /// All retained events of `kind`, across tracks, in track order.
    pub fn events_of(&self, kind: EventKind) -> Vec<Event> {
        self.tracks
            .iter()
            .flat_map(|(_, events)| events.iter())
            .filter(|e| e.kind == kind as u8)
            .copied()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbound_thread_emits_nothing() {
        assert_eq!(span_start(), SPAN_OFF, "no binding, no clock read result");
        // None of these may panic or record anywhere.
        span_end(EventKind::TaskExec, SPAN_OFF, 0, 0);
        span_at(EventKind::TaskExec, 1, 2, 0, 0);
        instant(EventKind::ScopeDefer, 0, 0);
        add_ghost_bytes(64);
        observe_lag(3);
    }

    #[test]
    fn bound_emits_land_in_the_right_ring() {
        let tel = Telemetry::new(
            TelemetryConfig::default(),
            vec!["worker-0".into(), "engine".into()],
        );
        {
            let _bind = tel.bind_worker(0);
            let t0 = span_start();
            assert_ne!(t0, SPAN_OFF);
            span_end(EventKind::TaskExec, t0, 7, 1);
            instant(EventKind::ScopeDefer, 9, 2);
            add_ghost_bytes(100);
            observe_lag(2);
        }
        assert_eq!(span_start(), SPAN_OFF, "guard drop unbinds the thread");
        assert_eq!(tel.ring(0).count(EventKind::TaskExec), 1);
        assert_eq!(tel.ring(0).count(EventKind::ScopeDefer), 1);
        assert_eq!(tel.ring(1).count(EventKind::TaskExec), 0, "other tracks untouched");
        assert_eq!(tel.ring(0).ghost_bytes(), 100);
        let report = tel.finish();
        assert_eq!(report.count(EventKind::TaskExec), 1);
        assert_eq!(report.events_recorded, 2);
        assert_eq!(report.events_dropped, 0);
        assert_eq!(report.tracks.len(), 2);
        assert_eq!(report.events_of(EventKind::TaskExec).len(), 1);
        assert!(report.trace_path.is_none(), "no export configured");
    }

    #[test]
    fn nested_bindings_restore_on_drop() {
        let tel = Telemetry::new(TelemetryConfig::default(), vec!["a".into(), "b".into()]);
        let _outer = tel.bind_worker(0);
        {
            let _inner = tel.bind_worker(1);
            instant(EventKind::Handoff, 1, 1);
        }
        instant(EventKind::Handoff, 2, 2);
        assert_eq!(tel.ring(1).count(EventKind::Handoff), 1);
        assert_eq!(tel.ring(0).count(EventKind::Handoff), 1, "outer binding restored");
    }

    #[test]
    fn sampler_series_is_cumulative_and_stamped() {
        let tel = Telemetry::new(TelemetryConfig::default(), vec!["w".into()]);
        let _bind = tel.bind_worker(0);
        let q = || 4u64;
        let r = || 1u64;
        let p = || 0.5f64;
        let sources = SampleSources { queue_depth: &q, retry_depth: &r, progress: Some(&p) };
        tel.sample_now(&sources);
        instant(EventKind::TaskExec, 0, 0);
        instant(EventKind::TaskExec, 1, 0);
        std::thread::sleep(Duration::from_millis(2));
        tel.sample_now(&sources);
        drop(_bind);
        let report = tel.finish();
        assert_eq!(report.samples.len(), 2);
        let (s0, s1) = (&report.samples[0], &report.samples[1]);
        assert_eq!(s0.tasks, 0);
        assert_eq!(s1.tasks, 2, "task counter is cumulative");
        assert!(s1.t_ms > s0.t_ms, "samples advance on the run clock");
        assert!(s1.tasks_per_sec > 0.0, "rate derived from the previous sample");
        assert_eq!(s1.queue_depth, 4);
        assert_eq!(s1.retry_depth, 1);
        assert_eq!(s1.progress, Some(0.5), "convergence scalar probed per sample");
    }
}
