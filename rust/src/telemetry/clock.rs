//! The telemetry **monotonic clock**: one [`Instant`] origin per run, all
//! timestamps expressed as nanoseconds since that origin. Every timing the
//! engine takes — ring span events, sampler tick stamps, and the
//! sequential engine's [`crate::engine::trace::TraceEvent`] cost
//! measurement — derives from the same [`MonoClock`], so a simulator
//! replay and a telemetry trace of the same run can never disagree about
//! what a task cost.

use std::time::Instant;

/// A monotonic run clock: nanoseconds since a fixed [`Instant`] origin.
/// Copyable, so the one origin can be handed to every worker and helper
/// that needs to stamp an event on the same timeline.
#[derive(Clone, Copy, Debug)]
pub struct MonoClock {
    origin: Instant,
}

impl MonoClock {
    /// Start a new clock at "now".
    pub fn start() -> MonoClock {
        MonoClock { origin: Instant::now() }
    }

    /// Rebuild a clock from an existing origin (shares a timeline).
    pub(crate) fn from_origin(origin: Instant) -> MonoClock {
        MonoClock { origin }
    }

    /// The shared origin instant.
    pub(crate) fn origin(&self) -> Instant {
        self.origin
    }

    /// Nanoseconds elapsed since the origin.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A span in flight on a [`MonoClock`] timeline: the start stamp of a
/// timed region. This is the one span helper both the telemetry rings and
/// the sequential engine's trace cost measurement ride (see
/// [`crate::engine::trace`]).
#[derive(Clone, Copy, Debug)]
pub struct SpanStart {
    start_ns: u64,
}

impl SpanStart {
    /// Open a span at the clock's current time.
    #[inline]
    pub fn begin(clock: &MonoClock) -> SpanStart {
        SpanStart { start_ns: clock.now_ns() }
    }

    /// The span's opening timestamp (ns since the clock origin).
    #[inline]
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    /// Close the span: `(start_ns, duration_ns)` against the same clock.
    #[inline]
    pub fn finish(&self, clock: &MonoClock) -> (u64, u64) {
        let now = clock.now_ns();
        (self.start_ns, now.saturating_sub(self.start_ns))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone() {
        let c = MonoClock::start();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a, "monotonic clock must never step back");
    }

    #[test]
    fn span_measures_on_the_shared_timeline() {
        let c = MonoClock::start();
        let s = SpanStart::begin(&c);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let (start, dur) = s.finish(&c);
        assert_eq!(start, s.start_ns());
        assert!(dur >= 1_000_000, "a 2ms sleep must cost at least 1ms");
        let copy = MonoClock::from_origin(c.origin());
        assert!(copy.now_ns() >= start + dur, "same origin, same timeline");
    }
}
