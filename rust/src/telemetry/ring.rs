//! Per-worker **bounded event rings**: fixed-capacity, single-producer
//! append buffers of timestamped [`Event`]s, written lock-free by the one
//! worker thread bound to the ring and read only after that worker has
//! been joined (the exporter) or through its atomic side counters (the
//! sampler). On overflow the ring *drops* the event — never blocks, never
//! overwrites — and counts the drop, so a trace can say exactly how much
//! it is missing.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Staleness-distribution buckets: `log2(lag + 1)` clamped to the last
/// bucket (lags of 0, 1, 2–3, 4–7, … master versions).
pub const LAG_BUCKETS: usize = 8;

/// Every instrumented event category. The discriminant doubles as the
/// index into per-ring and report-level count arrays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Span: one update-function execution under its acquired scope
    /// (`a` = vertex, `b` = update-function id).
    TaskExec = 0,
    /// Span: a scope acquisition that did **not** succeed first try — the
    /// in-place conflict re-attempt ladder, timed from the first failed
    /// try-acquire to the dispatch outcome (`a` = vertex).
    ScopeContend = 1,
    /// Instant: a task pushed to a retry deque after exhausting its
    /// adaptive re-attempts (`a` = vertex, `b` = deferral age).
    ScopeDefer = 2,
    /// Instant: a deferral-fairness escalation — the task's next dispatch
    /// used a blocking acquisition (`a` = vertex, `b` = deferral age).
    ScopeEscalate = 3,
    /// Instant: a pipelined split acquisition went pending — remote half
    /// granted, local half conflicted, remote locks parked (`a` = vertex).
    SplitStall = 4,
    /// Span: one delta-batcher window flushed through the transport
    /// (`a` = deltas shipped, `b` = bytes shipped).
    DeltaFlush = 5,
    /// Instant: one ghost delta handed to the transport's send path
    /// (`a` = vertex, `b` = version; paired with [`EventKind::WireApply`]
    /// by the exporter into a cross-shard delta→apply flow arrow).
    WireSend = 6,
    /// Instant: one ghost delta applied to a replica at drain
    /// (`a` = vertex, `b` = version).
    WireApply = 7,
    /// Instant: a bounded-staleness admission pull (`a` = vertex,
    /// `b` = observed lag in master versions before the pull).
    StalePull = 8,
    /// Instant: a failed admission pull re-issued under backoff
    /// (`a` = vertex, `b` = attempt number).
    PullRetry = 9,
    /// Instant: a socket delta connection reconnected after a broken
    /// pipe (`a` = vertex mid-send, `b` = attempt number).
    SocketReconnect = 10,
    /// Span: a send stalled on a full bounded send window — the socket
    /// backend's backpressure (`a` = frame bytes).
    Backpressure = 11,
    /// Instant: a worker observed a newly announced snapshot epoch and
    /// performed its marker step (`a` = epoch).
    SnapshotAdopt = 12,
    /// Span: one shard's owned rows serialized for a snapshot epoch
    /// (`a` = epoch, `b` = rows captured).
    SnapshotCapture = 13,
    /// Instant: a task popped by the wrong shard's worker and handed off
    /// to the owner shard (`a` = vertex, `b` = destination shard).
    Handoff = 14,
    /// Instant: an injector push spilled past the lock-free ring into the
    /// mutex overflow list (scheduler layer; `a` = overflow depth).
    InjectorOverflow = 15,
    /// Instant: the fault injector perturbed traffic (`a` = fault class:
    /// 0 drop, 1 duplicate, 2 delay, 3 severed pull).
    Fault = 16,
}

/// Number of event categories (array sizes for per-kind counters).
pub const KIND_COUNT: usize = 17;

/// All kinds, in discriminant order (taxonomy iteration for exporters,
/// summaries, and conservation tests).
pub const ALL_KINDS: [EventKind; KIND_COUNT] = [
    EventKind::TaskExec,
    EventKind::ScopeContend,
    EventKind::ScopeDefer,
    EventKind::ScopeEscalate,
    EventKind::SplitStall,
    EventKind::DeltaFlush,
    EventKind::WireSend,
    EventKind::WireApply,
    EventKind::StalePull,
    EventKind::PullRetry,
    EventKind::SocketReconnect,
    EventKind::Backpressure,
    EventKind::SnapshotAdopt,
    EventKind::SnapshotCapture,
    EventKind::Handoff,
    EventKind::InjectorOverflow,
    EventKind::Fault,
];

impl EventKind {
    /// Short name used in trace exports and summaries.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::TaskExec => "task",
            EventKind::ScopeContend => "scope_contend",
            EventKind::ScopeDefer => "scope_defer",
            EventKind::ScopeEscalate => "scope_escalate",
            EventKind::SplitStall => "split_stall",
            EventKind::DeltaFlush => "delta_flush",
            EventKind::WireSend => "wire_send",
            EventKind::WireApply => "wire_apply",
            EventKind::StalePull => "stale_pull",
            EventKind::PullRetry => "pull_retry",
            EventKind::SocketReconnect => "reconnect",
            EventKind::Backpressure => "backpressure",
            EventKind::SnapshotAdopt => "snapshot_adopt",
            EventKind::SnapshotCapture => "snapshot_capture",
            EventKind::Handoff => "handoff",
            EventKind::InjectorOverflow => "injector_overflow",
            EventKind::Fault => "fault",
        }
    }

    /// Whether events of this kind are timed spans (the rest are
    /// instants, recorded with `dur_ns == 0`).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::TaskExec
                | EventKind::ScopeContend
                | EventKind::DeltaFlush
                | EventKind::Backpressure
                | EventKind::SnapshotCapture
        )
    }

    /// Trace category group ("engine", "wire", "snapshot", "sched").
    pub fn category(self) -> &'static str {
        match self {
            EventKind::TaskExec
            | EventKind::ScopeContend
            | EventKind::ScopeDefer
            | EventKind::ScopeEscalate
            | EventKind::SplitStall
            | EventKind::Handoff => "engine",
            EventKind::DeltaFlush
            | EventKind::WireSend
            | EventKind::WireApply
            | EventKind::StalePull
            | EventKind::PullRetry
            | EventKind::SocketReconnect
            | EventKind::Backpressure
            | EventKind::Fault => "wire",
            EventKind::SnapshotAdopt | EventKind::SnapshotCapture => "snapshot",
            EventKind::InjectorOverflow => "sched",
        }
    }
}

/// One recorded event: a span when `dur_ns > 0` semantics apply (spans
/// record their opening timestamp in `t_ns`), an instant otherwise. `a`
/// and `b` are kind-specific payload words (see [`EventKind`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct Event {
    /// [`EventKind`] discriminant.
    pub kind: u8,
    /// Nanoseconds since the run clock origin (span start for spans).
    pub t_ns: u64,
    /// Span duration in ns; 0 for instants.
    pub dur_ns: u64,
    /// First payload word (kind-specific).
    pub a: u64,
    /// Second payload word (kind-specific).
    pub b: u64,
}

/// A single-producer bounded event buffer plus the atomic side counters
/// the sampler reads live.
///
/// Safety contract (why the `unsafe impl Sync` below is sound): the
/// `events` slots are written through `&self` only by the one thread the
/// ring is bound to ([`crate::telemetry::Telemetry::bind_worker`] hands
/// out the binding and the engines bind each ring to exactly one worker);
/// `len` is published with release ordering and readers load it with
/// acquire before touching slots, and the exporter additionally reads
/// only after the producing thread has been joined.
pub struct WorkerRing {
    events: UnsafeCell<Box<[Event]>>,
    len: AtomicUsize,
    dropped: AtomicU64,
    counts: [AtomicU64; KIND_COUNT],
    ghost_bytes: AtomicU64,
    lag_hist: [AtomicU64; LAG_BUCKETS],
}

unsafe impl Sync for WorkerRing {}

impl WorkerRing {
    /// A ring holding up to `capacity` events (at least 1).
    pub fn new(capacity: usize) -> WorkerRing {
        WorkerRing {
            events: UnsafeCell::new(vec![Event::default(); capacity.max(1)].into_boxed_slice()),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            ghost_bytes: AtomicU64::new(0),
            lag_hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Append `ev` (single producer: only the bound worker thread). The
    /// per-kind count always advances — a conservation check can rely on
    /// it even when the slot itself is dropped on overflow.
    #[inline]
    pub fn push(&self, ev: Event) {
        self.counts[ev.kind as usize].fetch_add(1, Ordering::Relaxed);
        let len = self.len.load(Ordering::Relaxed);
        // SAFETY: single-producer contract (see type docs); `len` is this
        // thread's own high-water mark, so the slot is unaliased.
        let slots = unsafe { &mut *self.events.get() };
        if len >= slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        slots[len] = ev;
        self.len.store(len + 1, Ordering::Release);
    }

    /// Add to the ring's ghost-bytes-shipped gauge (sampler input).
    #[inline]
    pub fn add_ghost_bytes(&self, n: u64) {
        self.ghost_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one observed replica staleness in the lag histogram.
    #[inline]
    pub fn observe_lag(&self, lag: u64) {
        let bucket = (63 - lag.saturating_add(1).leading_zeros()).min(LAG_BUCKETS as u32 - 1);
        self.lag_hist[bucket as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Events recorded for `kind` so far (live; includes dropped slots).
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize].load(Ordering::Relaxed)
    }

    /// Events whose ring slot was dropped on overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Ghost bytes gauge (live).
    pub fn ghost_bytes(&self) -> u64 {
        self.ghost_bytes.load(Ordering::Relaxed)
    }

    /// Snapshot of the lag histogram (live).
    pub fn lag_hist(&self) -> [u64; LAG_BUCKETS] {
        std::array::from_fn(|i| self.lag_hist[i].load(Ordering::Relaxed))
    }

    /// Copy out the recorded events. Safe to call while the producer may
    /// still be appending (acquire on `len` covers every published slot);
    /// the exporter calls it after the producer joined, so it sees all.
    pub fn snapshot_events(&self) -> Vec<Event> {
        let len = self.len.load(Ordering::Acquire);
        // SAFETY: slots below `len` were published with release ordering
        // and are never rewritten (append-only, drop-on-overflow).
        let slots = unsafe { &*self.events.get() };
        slots[..len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_records_and_counts() {
        let r = WorkerRing::new(4);
        r.push(Event { kind: EventKind::TaskExec as u8, t_ns: 5, dur_ns: 2, a: 1, b: 0 });
        r.push(Event { kind: EventKind::ScopeDefer as u8, t_ns: 9, dur_ns: 0, a: 3, b: 1 });
        assert_eq!(r.count(EventKind::TaskExec), 1);
        assert_eq!(r.count(EventKind::ScopeDefer), 1);
        assert_eq!(r.count(EventKind::Handoff), 0);
        assert_eq!(r.dropped(), 0);
        let evs = r.snapshot_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].t_ns, 5);
        assert_eq!(evs[1].a, 3);
    }

    #[test]
    fn overflow_drops_are_counted_not_lost_silently() {
        let r = WorkerRing::new(2);
        for i in 0..5 {
            r.push(Event { kind: EventKind::TaskExec as u8, t_ns: i, ..Event::default() });
        }
        assert_eq!(r.snapshot_events().len(), 2, "capacity bounds the ring");
        assert_eq!(r.dropped(), 3, "every overflowed event is counted");
        assert_eq!(r.count(EventKind::TaskExec), 5, "counts include dropped events");
    }

    #[test]
    fn lag_histogram_buckets_by_log2() {
        let r = WorkerRing::new(1);
        for lag in [0, 1, 2, 3, 4, 1_000_000] {
            r.observe_lag(lag);
        }
        let h = r.lag_hist();
        assert_eq!(h[0], 1, "lag 0");
        assert_eq!(h[1], 2, "lags 1..=2");
        assert_eq!(h[2], 2, "lags 3..=6");
        assert_eq!(h[LAG_BUCKETS - 1], 1, "huge lags clamp to the last bucket");
        assert_eq!(h.iter().sum::<u64>(), 6);
    }

    #[test]
    fn taxonomy_is_dense_and_named() {
        for (i, k) in ALL_KINDS.iter().enumerate() {
            assert_eq!(*k as usize, i, "discriminants must be dense for array indexing");
            assert!(!k.name().is_empty());
            assert!(!k.category().is_empty());
        }
    }
}
