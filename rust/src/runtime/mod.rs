//! PJRT runtime bridge: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (Layer 2/1) and executes them from the rust
//! coordinator — python never runs on the request path.
//!
//! Pipeline per artifact (see /opt/xla-example and DESIGN.md):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos).

mod accel_bp;

pub use accel_bp::{bp_artifact_available, AccelGridBp};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape of one artifact argument: `f32:256x5` in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgShape {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl ArgShape {
    fn parse(tok: &str) -> Result<ArgShape> {
        let (dtype, dims) =
            tok.split_once(':').ok_or_else(|| anyhow!("bad shape token {tok:?}"))?;
        let dims = if dims.is_empty() {
            Vec::new()
        } else {
            dims.split('x')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(ArgShape { dtype: dtype.to_string(), dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One entry of `artifacts/manifest.tsv`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<ArgShape>,
    pub outputs: Vec<ArgShape>,
}

/// Parse the TSV manifest (name, file, in:..., out:...).
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            bail!("manifest line {}: expected 4 columns, got {}", lineno + 1, cols.len());
        }
        let parse_specs = |col: &str, prefix: &str| -> Result<Vec<ArgShape>> {
            let body = col
                .strip_prefix(prefix)
                .ok_or_else(|| anyhow!("manifest line {}: missing {prefix}", lineno + 1))?;
            body.split(';').filter(|t| !t.is_empty()).map(ArgShape::parse).collect()
        };
        out.push(ArtifactMeta {
            name: cols[0].to_string(),
            path: dir.join(cols[1]),
            inputs: parse_specs(cols[2], "in:")?,
            outputs: parse_specs(cols[3], "out:")?,
        });
    }
    Ok(out)
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on f32 buffers. `inputs[i]` must have `meta.inputs[i]`
    /// elements; returns one `Vec<f32>` per declared output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.meta.inputs) {
            if buf.len() != spec.elements() {
                bail!(
                    "{}: input size {} != shape {:?}",
                    self.meta.name,
                    buf.len(),
                    spec.dims
                );
            }
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Loads and caches compiled artifacts against one PJRT client.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    metas: HashMap<String, ArtifactMeta>,
    compiled: HashMap<String, Executable>,
}

impl ArtifactRegistry {
    /// Open the registry over `dir` (usually `artifacts/`).
    pub fn open(dir: &Path) -> Result<ArtifactRegistry> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let metas = read_manifest(dir)?
            .into_iter()
            .map(|m| (m.name.clone(), m))
            .collect();
        Ok(ArtifactRegistry { client, metas, compiled: HashMap::new() })
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.metas.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let meta = self
                .metas
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}; have {:?}", self.names()))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", meta.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.compiled[name])
    }
}

/// Default artifacts directory: `$GRAPHLAB_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("GRAPHLAB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arg_shape_parsing() {
        let s = ArgShape::parse("f32:256x5").unwrap();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.dims, vec![256, 5]);
        assert_eq!(s.elements(), 1280);
        let scalar = ArgShape::parse("f32:").unwrap();
        assert_eq!(scalar.elements(), 1);
        assert!(ArgShape::parse("nonsense").is_err());
    }

    #[test]
    fn manifest_parsing_roundtrip() {
        let dir = std::env::temp_dir().join("graphlab_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "bp\tbp.hlo.txt\tin:f32:8x2;f32:2x2\tout:f32:8x2;f32:8\n",
        )
        .unwrap();
        let metas = read_manifest(&dir).unwrap();
        assert_eq!(metas.len(), 1);
        assert_eq!(metas[0].name, "bp");
        assert_eq!(metas[0].inputs.len(), 2);
        assert_eq!(metas[0].outputs[1].dims, vec![8]);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = read_manifest(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    // Tests that require built artifacts live in rust/tests/runtime_pjrt.rs
    // (integration tests) so `cargo test --lib` stays green before
    // `make artifacts`.
}
