//! Accelerated grid-BP driver: the Layer-3 coordinator drains whole Jacobi
//! sweeps of the grid MRF through the AOT-compiled batched message kernel
//! (`bp_batch_b{B}_k{K}`, Layer 1/2).
//!
//! This is the TPU-era restatement of the paper's hot loop (DESIGN.md
//! §Hardware-Adaptation): the coordinator still owns scheduling/termination
//! (sweep-to-convergence with residual tracking — the synchronous scheduler
//! semantics of §3.4), while the per-edge message math runs as dense
//! `[B, K] × [K, K]` batches. Edges are grouped by axis so each batch shares
//! one Laplace ψ; partial batches are padded with uniform rows.

use super::ArtifactRegistry;
use crate::apps::mrf::{normalize, EdgePotential, Mrf};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Batched grid-BP executor (owns its PJRT client + compiled kernel).
pub struct AccelGridBp {
    registry: ArtifactRegistry,
    artifact: String,
    batch: usize,
    k: usize,
}

impl AccelGridBp {
    /// Open over `dir`, selecting the `bp_batch_b{batch}_k{k}` artifact.
    pub fn open(dir: &Path, batch: usize, k: usize) -> Result<AccelGridBp> {
        let mut registry = ArtifactRegistry::open(dir)?;
        let artifact = format!("bp_batch_b{batch}_k{k}");
        registry.load(&artifact)?; // compile eagerly; fails fast if missing
        Ok(AccelGridBp { registry, artifact, batch, k })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// One synchronous (Jacobi) sweep over all directed edges of `mrf`.
    /// Returns the max message residual of the sweep.
    pub fn sweep(&mut self, mrf: &mut Mrf, lambda: [f64; 3]) -> Result<f32> {
        let k = self.k;
        anyhow::ensure!(mrf.arity == k, "arity {} != kernel K {}", mrf.arity, k);
        let m = mrf.graph.num_edges();

        // Gather: cavity rows + old messages, grouped by axis (shared ψ).
        // Beliefs are computed from the *pre-sweep* messages (Jacobi).
        let n = mrf.graph.num_vertices();
        let mut beliefs = vec![0.0f32; n * k];
        for v in 0..n as u32 {
            let mut b = mrf.graph.vertex_data(v).potential.clone();
            for &e in mrf.graph.in_edges(v).to_vec().iter() {
                let msg = &mrf.graph.edge_data(e).message;
                for (bi, mi) in b.iter_mut().zip(msg) {
                    *bi *= *mi;
                }
            }
            normalize(&mut b);
            beliefs[v as usize * k..(v as usize + 1) * k].copy_from_slice(&b);
        }

        let mut by_axis: [Vec<u32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for e in 0..m as u32 {
            match mrf.graph.edge_data(e).potential {
                EdgePotential::Laplace { axis } => by_axis[axis as usize].push(e),
                EdgePotential::Table(_) => {
                    anyhow::bail!("accelerated path supports Laplace grids only")
                }
            }
        }

        let mut max_residual = 0.0f32;
        for (axis, edges) in by_axis.iter().enumerate() {
            if edges.is_empty() {
                continue;
            }
            // ψ for this axis from λ (symmetric Laplace).
            let mut psi = vec![0.0f32; k * k];
            for i in 0..k {
                for j in 0..k {
                    psi[i * k + j] =
                        (-(lambda[axis]) * (i as f64 - j as f64).abs()).exp() as f32;
                }
            }
            for chunk in edges.chunks(self.batch) {
                let rows = chunk.len();
                let uniform = 1.0f32 / k as f32;
                let mut cavity = vec![uniform; self.batch * k];
                let mut old = vec![uniform; self.batch * k];
                for (r, &e) in chunk.iter().enumerate() {
                    let edge = mrf.graph.edge(e);
                    let src = edge.src as usize;
                    let mut cav: Vec<f32> =
                        beliefs[src * k..(src + 1) * k].to_vec();
                    if let Some(rev) = mrf.graph.reverse_edge(e) {
                        let m_in = mrf.graph.edge_data(rev).message.clone();
                        for (c, mi) in cav.iter_mut().zip(&m_in) {
                            *c = if *mi > 1e-30 { *c / *mi } else { 0.0 };
                        }
                    }
                    normalize(&mut cav);
                    cavity[r * k..(r + 1) * k].copy_from_slice(&cav);
                    old[r * k..(r + 1) * k]
                        .copy_from_slice(&mrf.graph.edge_data(e).message);
                }
                let exe = self.registry.load(&self.artifact)?;
                let outs = exe.run_f32(&[&cavity, &psi, &old])?;
                let (msgs, residuals) = (&outs[0], &outs[1]);
                for (r, &e) in chunk.iter().enumerate() {
                    mrf.graph
                        .edge_data(e)
                        .message
                        .copy_from_slice(&msgs[r * k..(r + 1) * k]);
                }
                for &res in residuals.iter().take(rows) {
                    max_residual = max_residual.max(res);
                }
            }
        }

        // Refresh beliefs from the new messages.
        for v in 0..n as u32 {
            let mut b = mrf.graph.vertex_data(v).potential.clone();
            for &e in mrf.graph.in_edges(v).to_vec().iter() {
                let msg = &mrf.graph.edge_data(e).message;
                for (bi, mi) in b.iter_mut().zip(msg) {
                    *bi *= *mi;
                }
            }
            normalize(&mut b);
            mrf.graph.vertex_data(v).belief = b;
        }
        Ok(max_residual)
    }

    /// Sweep until the max residual drops below `tol` (or `max_sweeps`).
    /// Returns (sweeps run, final residual).
    pub fn run(
        &mut self,
        mrf: &mut Mrf,
        lambda: [f64; 3],
        max_sweeps: usize,
        tol: f32,
    ) -> Result<(usize, f32)> {
        let mut last = f32::INFINITY;
        for s in 1..=max_sweeps {
            last = self.sweep(mrf, lambda)?;
            if last < tol {
                return Ok((s, last));
            }
        }
        Ok((max_sweeps, last))
    }
}

/// Convenience: does the artifact set include the (batch, k) BP kernel?
pub fn bp_artifact_available(dir: &Path, batch: usize, k: usize) -> bool {
    super::read_manifest(dir)
        .map(|m| m.iter().any(|a| a.name == format!("bp_batch_b{batch}_k{k}")))
        .unwrap_or(false)
}

impl AccelGridBp {
    /// Expose the registry for callers that also run other artifacts.
    pub fn registry_mut(&mut self) -> &mut ArtifactRegistry {
        &mut self.registry
    }

    pub fn platform(&self) -> String {
        self.registry.platform()
    }

    pub fn artifact_error(dir: &Path, batch: usize, k: usize) -> anyhow::Error {
        anyhow!(
            "artifact bp_batch_b{batch}_k{k} not found under {} — run `make artifacts`",
            dir.display()
        )
    }
}
