//! The **Shared Data Table** (SDT, paper §3.1) and the **sync mechanism**
//! (paper §3.2.2, Alg. 1).
//!
//! The SDT is an associative map `T[Key] -> Value` holding globally shared
//! state (hyper-parameters, convergence statistics). Update functions get
//! *read-only* access; writes happen through the sync mechanism's `Apply`
//! step or through exclusive setup code.
//!
//! A sync operation is `(key, r0, Fold, optional Merge, Apply)`:
//!   r_{i+1} <- Fold(D_v, r_i)        sequentially over vertices (Alg. 1)
//!   r       <- Merge(r_i, r_j)       parallel tree reduction, if provided
//!   T[key]  <- Apply(r_{|V|})        finalization
//!
//! Execution (on demand or periodic/background) is driven by the engine,
//! which owns the consistency locks; this module owns registration and the
//! type-erased plumbing.

use std::any::Any;
use std::collections::HashMap;
use std::sync::RwLock;
use std::time::Duration;

/// Type-erased accumulator.
pub type Acc = Box<dyn Any + Send>;

/// The shared data table. Cheap to read concurrently; writes are rare
/// (sync Apply, setup).
#[derive(Default)]
pub struct Sdt {
    entries: RwLock<HashMap<String, Box<dyn Any + Send + Sync>>>,
}

impl Sdt {
    pub fn new() -> Sdt {
        Sdt::default()
    }

    /// Insert / overwrite a typed value.
    pub fn set<T: Any + Send + Sync>(&self, key: &str, value: T) {
        self.entries.write().unwrap().insert(key.to_string(), Box::new(value));
    }

    /// Clone out a typed value. Returns `None` on missing key or wrong type.
    pub fn get<T: Any + Clone>(&self, key: &str) -> Option<T> {
        self.entries.read().unwrap().get(key).and_then(|v| v.downcast_ref::<T>().cloned())
    }

    /// Typed read with a default.
    pub fn get_or<T: Any + Clone>(&self, key: &str, default: T) -> T {
        self.get(key).unwrap_or(default)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.read().unwrap().contains_key(key)
    }

    pub fn keys(&self) -> Vec<String> {
        self.entries.read().unwrap().keys().cloned().collect()
    }

    /// Read-modify-write under the table lock (used by Apply closures).
    pub fn update<T: Any + Send + Sync + Clone>(&self, key: &str, f: impl FnOnce(Option<T>) -> T) {
        let mut map = self.entries.write().unwrap();
        let cur = map.get(key).and_then(|v| v.downcast_ref::<T>().cloned());
        map.insert(key.to_string(), Box::new(f(cur)));
    }
}

/// A registered sync operation over vertex data of type `V` (type-erased).
pub struct SyncOp<V> {
    pub key: String,
    /// Background execution period; `None` = on-demand only.
    pub interval: Option<Duration>,
    init: Box<dyn Fn() -> Acc + Send + Sync>,
    fold: Box<dyn Fn(Acc, &V) -> Acc + Send + Sync>,
    merge: Option<Box<dyn Fn(Acc, Acc) -> Acc + Send + Sync>>,
    apply: Box<dyn Fn(Acc, &Sdt) + Send + Sync>,
}

impl<V> SyncOp<V> {
    pub fn init_acc(&self) -> Acc {
        (self.init)()
    }
    pub fn fold_acc(&self, acc: Acc, v: &V) -> Acc {
        (self.fold)(acc, v)
    }
    pub fn has_merge(&self) -> bool {
        self.merge.is_some()
    }
    pub fn merge_acc(&self, a: Acc, b: Acc) -> Acc {
        match &self.merge {
            Some(m) => m(a, b),
            None => panic!("sync op {:?} has no merge function", self.key),
        }
    }
    pub fn apply_acc(&self, acc: Acc, sdt: &Sdt) {
        (self.apply)(acc, sdt)
    }
}

/// Builder for a typed sync op; erases types at `build`.
pub struct SyncOpBuilder<V, T> {
    key: String,
    r0: T,
    interval: Option<Duration>,
    _marker: std::marker::PhantomData<fn(&V)>,
}

impl<V: 'static, T: Any + Send + Sync + Clone + 'static> SyncOpBuilder<V, T> {
    pub fn new(key: &str, r0: T) -> Self {
        SyncOpBuilder {
            key: key.to_string(),
            r0,
            interval: None,
            _marker: std::marker::PhantomData,
        }
    }

    /// Run periodically in the background while the engine executes.
    pub fn every(mut self, interval: Duration) -> Self {
        self.interval = Some(interval);
        self
    }

    /// Provide Fold and Apply (no Merge: sequential fold only).
    pub fn build(
        self,
        fold: impl Fn(T, &V) -> T + Send + Sync + 'static,
        apply: impl Fn(T, &Sdt) + Send + Sync + 'static,
    ) -> SyncOp<V> {
        let r0 = self.r0.clone();
        SyncOp {
            key: self.key,
            interval: self.interval,
            init: Box::new(move || Box::new(r0.clone()) as Acc),
            fold: Box::new(move |acc, v| {
                let t = *acc.downcast::<T>().expect("sync fold: accumulator type");
                Box::new(fold(t, v)) as Acc
            }),
            merge: None,
            apply: Box::new(move |acc, sdt| {
                let t = *acc.downcast::<T>().expect("sync apply: accumulator type");
                apply(t, sdt)
            }),
        }
    }

    /// Provide Fold, Merge and Apply (parallel tree reduction enabled).
    pub fn build_with_merge(
        self,
        fold: impl Fn(T, &V) -> T + Send + Sync + 'static,
        merge: impl Fn(T, T) -> T + Send + Sync + 'static,
        apply: impl Fn(T, &Sdt) + Send + Sync + 'static,
    ) -> SyncOp<V> {
        let mut op = self.build(fold, apply);
        op.merge = Some(Box::new(move |a, b| {
            let ta = *a.downcast::<T>().expect("sync merge: accumulator type (lhs)");
            let tb = *b.downcast::<T>().expect("sync merge: accumulator type (rhs)");
            Box::new(merge(ta, tb)) as Acc
        }));
        op
    }
}

/// Run a sync op sequentially over a slice of vertex data (Alg. 1). The
/// engine uses this for on-demand syncs; the threaded engine shards the fold
/// and combines shards with `merge` when available.
pub fn run_sync_sequential<V>(op: &SyncOp<V>, data: impl Iterator<Item = impl std::ops::Deref<Target = V>>, sdt: &Sdt) {
    let mut acc = op.init_acc();
    for v in data {
        acc = op.fold_acc(acc, &v);
    }
    op.apply_acc(acc, sdt);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_typed() {
        let sdt = Sdt::new();
        sdt.set("lambda", 0.5f64);
        sdt.set("name", "bp".to_string());
        assert_eq!(sdt.get::<f64>("lambda"), Some(0.5));
        assert_eq!(sdt.get::<String>("name").as_deref(), Some("bp"));
        assert_eq!(sdt.get::<u32>("lambda"), None, "wrong type must be None");
        assert_eq!(sdt.get::<f64>("missing"), None);
        assert_eq!(sdt.get_or::<f64>("missing", 9.0), 9.0);
    }

    #[test]
    fn update_read_modify_write() {
        let sdt = Sdt::new();
        sdt.update::<u64>("count", |c| c.unwrap_or(0) + 1);
        sdt.update::<u64>("count", |c| c.unwrap_or(0) + 1);
        assert_eq!(sdt.get::<u64>("count"), Some(2));
    }

    #[test]
    fn sync_fold_apply() {
        // Sum vertex values and divide by count in Apply (the paper's
        // "average residual" pattern).
        let op: SyncOp<f64> = SyncOpBuilder::new("avg", (0.0f64, 0u64)).build(
            |(s, n), v| (s + *v, n + 1),
            |(s, n), sdt| sdt.set("avg", s / n.max(1) as f64),
        );
        let sdt = Sdt::new();
        let data = [1.0f64, 2.0, 3.0, 6.0];
        run_sync_sequential(&op, data.iter(), &sdt);
        assert_eq!(sdt.get::<f64>("avg"), Some(3.0));
    }

    #[test]
    fn sync_merge_tree_reduction_matches_sequential() {
        let op: SyncOp<f64> = SyncOpBuilder::new("sum", 0.0f64).build_with_merge(
            |s, v| s + *v,
            |a, b| a + b,
            |s, sdt| sdt.set("sum", s),
        );
        let sdt = Sdt::new();
        // Shard the fold, then merge — must equal the sequential result.
        let data: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let mut left = op.init_acc();
        for v in &data[..50] {
            left = op.fold_acc(left, v);
        }
        let mut right = op.init_acc();
        for v in &data[50..] {
            right = op.fold_acc(right, v);
        }
        let merged = op.merge_acc(left, right);
        op.apply_acc(merged, &sdt);
        assert_eq!(sdt.get::<f64>("sum"), Some(5050.0));
    }

    #[test]
    fn concurrent_readers_dont_block() {
        use std::sync::Arc;
        let sdt = Arc::new(Sdt::new());
        sdt.set("x", 1.0f64);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&sdt);
            handles.push(std::thread::spawn(move || {
                let mut acc = 0.0;
                for _ in 0..1000 {
                    acc += s.get::<f64>("x").unwrap();
                }
                acc
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 1000.0);
        }
    }

    #[test]
    fn interval_marks_background_ops() {
        let op: SyncOp<f64> = SyncOpBuilder::new("bg", 0.0f64)
            .every(Duration::from_millis(10))
            .build(|s, v| s + *v, |s, sdt| sdt.set("bg", s));
        assert_eq!(op.interval, Some(Duration::from_millis(10)));
        assert!(!op.has_merge());
    }
}
