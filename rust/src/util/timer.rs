//! Wall-clock timing helpers and the in-repo micro-benchmark harness
//! (the offline vendor set has no `criterion`; `benches/*.rs` use
//! `harness = false` and this module).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn elapsed_ns(&self) -> u64 {
        self.elapsed().as_nanos() as u64
    }
}

/// Time a closure once, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// A single benchmark measurement: per-iteration seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    /// Render one row in the bench report format the harness prints.
    pub fn row(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>6}",
            self.name,
            fmt_secs(s.mean),
            fmt_secs(s.stddev),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            self.iters
        )
    }
}

pub fn bench_header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12} {:>12} {:>6}",
        "benchmark", "mean", "stddev", "p50", "p95", "iters"
    )
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Micro-benchmark runner: warms up, then measures `iters` iterations
/// (each timed individually so percentiles are meaningful).
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        samples.push(t.elapsed_secs());
    }
    BenchResult { name: name.to_string(), summary: Summary::from_samples(&samples), iters }
}

/// Adaptive variant: picks an iteration count so total time ≈ `budget_secs`.
pub fn bench_for<T>(name: &str, budget_secs: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // Calibrate with one run.
    let (_, once) = time_it(&mut f);
    let iters = ((budget_secs / once.max(1e-9)) as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0usize;
        let r = bench("noop", 2, 10, || count += 1);
        assert_eq!(r.iters, 10);
        assert_eq!(count, 12); // warmup + measured
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }
}
