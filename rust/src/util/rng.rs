//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the PCG-XSH-RR
//! 64/32 generator (O'Neill 2014) plus the SplitMix64 seeder. PCG is fast,
//! statistically solid for simulation workloads, and — crucially for a
//! reproduction repo — fully deterministic across platforms, so every figure
//! regenerates bit-identically from a seed.

/// SplitMix64: used to expand a single `u64` seed into stream/state material.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Create a generator from a seed; distinct seeds give distinct streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let initstate = splitmix64(&mut sm);
        let initseq = splitmix64(&mut sm);
        let mut rng = Pcg32 { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-thread / per-shard RNGs).
    pub fn fork(&mut self, salt: u64) -> Pcg32 {
        let s = self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::seed_from_u64(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second variate dropped for
    /// simplicity; simulation workloads here are not normal-draw bound).
    pub fn next_gaussian(&mut self) -> f64 {
        // Rejection-free polar-less Box–Muller.
        let u1 = (1.0 - self.next_f64()).max(1e-300); // (0,1]
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(1e-300);
        -u.ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "sample_discrete: all-zero weights");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Draw from Zipf(n, s) by inverse-CDF over precomputed weights is too
    /// slow for large n; we use rejection-inversion (Hörmann–Derflinger).
    /// Good enough for generating power-law degree sequences.
    pub fn next_zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        // Simple inversion on the continuous approximation, then clamp.
        // P(X >= x) ~ x^(1-s); invert u = x^(1-s) => x = u^(1/(1-s)).
        let one_minus_s = 1.0 - s;
        let x = if s == 1.0 {
            ((n as f64).ln() * self.next_f64()).exp()
        } else {
            let max_cdf = (n as f64).powf(one_minus_s);
            let u = 1.0 + self.next_f64() * (max_cdf - 1.0);
            u.powf(1.0 / one_minus_s)
        };
        (x.floor() as usize).clamp(1, n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg32::seed_from_u64(42);
        let mut b = Pcg32::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Pcg32::seed_from_u64(1);
        let mut b = Pcg32::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::seed_from_u64(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg32::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.gen_range(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seed_from_u64(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed_from_u64(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn discrete_sampling_follows_weights() {
        let mut r = Pcg32::seed_from_u64(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.sample_discrete(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Pcg32::seed_from_u64(13);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.next_zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[70]);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg32::seed_from_u64(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
