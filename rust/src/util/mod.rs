//! Foundation substrates built in-repo (the offline environment vendors only
//! the `xla` crate's dependency closure, so PRNG / CLI / stats / bench /
//! property-testing are implemented here rather than pulled from crates.io).

pub mod bitset;
pub mod cli;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitset::BitSet;
pub use cli::{Args, Cli, CliError};
pub use rng::Pcg32;
pub use stats::{Online, Summary};
pub use timer::Timer;
