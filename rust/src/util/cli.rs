//! Minimal command-line argument parser (the offline vendor set has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI: register options, then `parse` an argv slice.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<OptSpec>,
}

/// Parse result: resolved option values plus positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue { key: String, value: String, expect: &'static str },
    Help(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::BadValue { key, value, expect } => {
                write!(f, "option --{key}: cannot parse {value:?} as {expect}")
            }
            CliError::Help(text) => write!(f, "{text}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(program: &str, about: &str) -> Cli {
        Cli { program: program.to_string(), about: about.to_string(), specs: Vec::new() }
    }

    /// Register a valued option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register a boolean flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} — {}", self.program, self.about);
        let _ = writeln!(out, "\nOPTIONS:");
        for s in &self.specs {
            if s.is_flag {
                let _ = writeln!(out, "  --{:<24} {}", s.name, s.help);
            } else {
                let _ = writeln!(
                    out,
                    "  --{:<24} {} [default: {}]",
                    format!("{} <v>", s.name),
                    s.help,
                    s.default.as_deref().unwrap_or("")
                );
            }
        }
        out
    }

    /// Parse argv (without the program name). `--help` yields `CliError::Help`.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.clone(), d.clone());
            }
            if spec.is_flag {
                args.flags.insert(spec.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.is_flag {
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or(CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or_else(|| {
            panic!("option --{key} was not registered with a default")
        })
    }

    pub fn get_flag(&self, key: &str) -> bool {
        *self.flags.get(key).unwrap_or(&false)
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        self.get(key).parse().map_err(|_| CliError::BadValue {
            key: key.to_string(),
            value: self.get(key).to_string(),
            expect: "usize",
        })
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        self.get(key).parse().map_err(|_| CliError::BadValue {
            key: key.to_string(),
            value: self.get(key).to_string(),
            expect: "u64",
        })
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        self.get(key).parse().map_err(|_| CliError::BadValue {
            key: key.to_string(),
            value: self.get(key).to_string(),
            expect: "f64",
        })
    }

    /// Comma-separated list of usize, e.g. `--procs 1,2,4,8,16`.
    pub fn get_usize_list(&self, key: &str) -> Result<Vec<usize>, CliError> {
        self.get(key)
            .split(',')
            .map(|tok| {
                tok.trim().parse().map_err(|_| CliError::BadValue {
                    key: key.to_string(),
                    value: self.get(key).to_string(),
                    expect: "comma-separated usize list",
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    fn demo() -> Cli {
        Cli::new("demo", "test program")
            .opt("n", "10", "count")
            .opt("rate", "0.5", "a rate")
            .opt("procs", "1,2,4", "processor list")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let a = demo().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 10);
        assert_eq!(a.get_f64("rate").unwrap(), 0.5);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn explicit_values_and_flags() {
        let a = demo().parse(&argv(&["--n", "42", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 42);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = demo().parse(&argv(&["--rate=0.25"])).unwrap();
        assert_eq!(a.get_f64("rate").unwrap(), 0.25);
    }

    #[test]
    fn usize_list() {
        let a = demo().parse(&argv(&["--procs", "1,2,4,8,16"])).unwrap();
        assert_eq!(a.get_usize_list("procs").unwrap(), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(demo().parse(&argv(&["--bogus"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(demo().parse(&argv(&["--n"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn help_surfaces_usage() {
        match demo().parse(&argv(&["--help"])) {
            Err(CliError::Help(text)) => assert!(text.contains("--n")),
            other => panic!("expected help, got {other:?}"),
        }
    }

    #[test]
    fn bad_value_reported() {
        let a = demo().parse(&argv(&["--n", "xyz"])).unwrap();
        assert!(matches!(a.get_usize("n"), Err(CliError::BadValue { .. })));
    }
}
