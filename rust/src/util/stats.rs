//! Online and batch statistics used by the engine metrics, the discrete-event
//! simulator, and the benchmark harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let delta = other.mean - self.mean;
        self.mean += delta * other.n as f64 / n;
        self.m2 += other.m2 + delta * delta * (self.n as f64) * (other.n as f64) / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Batch summary over a sample: mean, stddev, and exact percentiles.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from_samples on empty slice");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// L1 distance between two equal-length vectors.
pub fn l1_distance(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// L2 norm.
pub fn l2_norm(a: &[f32]) -> f32 {
    a.iter().map(|x| x * x).sum::<f32>().sqrt()
}

/// Peak signal-to-noise ratio between images in [0, max_val].
pub fn psnr(reference: &[f32], test: &[f32], max_val: f32) -> f64 {
    debug_assert_eq!(reference.len(), test.len());
    let mse: f64 = reference
        .iter()
        .zip(test)
        .map(|(a, b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / reference.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * ((max_val as f64).powi(2) / mse).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut o = Online::new();
        for &x in &xs {
            o.add(x);
        }
        let s = Summary::from_samples(&xs);
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.stddev() - s.stddev).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 10.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn online_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Online::new();
        let mut left = Online::new();
        let mut right = Online::new();
        for (i, &x) in xs.iter().enumerate() {
            all.add(x);
            if i < 37 {
                left.add(x)
            } else {
                right.add(x)
            }
        }
        left.merge(&right);
        assert!((left.mean() - all.mean()).abs() < 1e-10);
        assert!((left.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 0.5), 50.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert!((percentile_sorted(&sorted, 0.95) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn psnr_identical_is_infinite() {
        let img = [0.1f32, 0.5, 0.9];
        assert!(psnr(&img, &img, 1.0).is_infinite());
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let img: Vec<f32> = (0..64).map(|i| (i as f32) / 64.0).collect();
        let small: Vec<f32> = img.iter().map(|x| x + 0.01).collect();
        let big: Vec<f32> = img.iter().map(|x| x + 0.2).collect();
        assert!(psnr(&img, &small, 1.0) > psnr(&img, &big, 1.0));
    }
}
