//! Fixed-capacity bitset used by schedulers (vertex membership), the graph
//! coloring app, and the set-scheduler planner.

/// Dense bitset over `0..capacity`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    pub fn new(capacity: usize) -> BitSet {
        BitSet { words: vec![0; capacity.div_ceil(64)], capacity }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.capacity);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i`; returns true if it was previously unset.
    #[inline]
    pub fn insert(&mut self, i: usize) -> bool {
        let was = self.get(i);
        self.set(i);
        !was
    }

    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Iterate set bit indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let tz = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + tz)
                }
            })
        })
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// True if the two sets share any element.
    pub fn intersects(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::prop_assert;

    #[test]
    fn set_get_clear() {
        let mut b = BitSet::new(200);
        assert!(!b.get(77));
        b.set(77);
        assert!(b.get(77));
        b.clear(77);
        assert!(!b.get(77));
    }

    #[test]
    fn insert_reports_novelty() {
        let mut b = BitSet::new(10);
        assert!(b.insert(3));
        assert!(!b.insert(3));
    }

    #[test]
    fn count_and_iter_agree() {
        let mut b = BitSet::new(130);
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            b.set(i);
        }
        assert_eq!(b.count(), 8);
        let collected: Vec<usize> = b.iter().collect();
        assert_eq!(collected, vec![0, 1, 63, 64, 65, 127, 128, 129]);
    }

    #[test]
    fn union_and_intersects() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        a.set(5);
        b.set(70);
        assert!(!a.intersects(&b));
        a.union_with(&b);
        assert!(a.get(70) && a.get(5));
        assert!(a.intersects(&b));
    }

    #[test]
    fn prop_matches_reference_set() {
        forall(100, |g| {
            let cap = g.usize_in(1..300);
            let ops = g.vec_usize(0..80, 0..cap);
            let mut bs = BitSet::new(cap);
            let mut reference = std::collections::BTreeSet::new();
            for (k, &i) in ops.iter().enumerate() {
                if k % 3 == 2 {
                    bs.clear(i);
                    reference.remove(&i);
                } else {
                    bs.set(i);
                    reference.insert(i);
                }
            }
            prop_assert!(bs.count() == reference.len(), "count mismatch");
            let got: Vec<usize> = bs.iter().collect();
            let want: Vec<usize> = reference.into_iter().collect();
            prop_assert!(got == want, "iter mismatch: {got:?} vs {want:?}");
            Ok(())
        });
    }
}
