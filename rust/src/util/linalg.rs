//! Small dense linear-algebra helpers used by test oracles and the
//! compressed-sensing outer loop (Gaussian elimination reference solver,
//! mat-vec products). Deliberately simple — the *parallel* solvers in this
//! repo are the GraphLab GaBP programs; this module is the ground truth.

/// Dense row-major matrix view helpers.
pub fn matvec(a: &[f64], n: usize, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), n * x.len());
    let m = x.len();
    (0..n).map(|i| (0..m).map(|j| a[i * m + j] * x[j]).sum()).collect()
}

/// Solve `A x = b` for dense square `A` (row-major) by Gaussian elimination
/// with partial pivoting. Panics on singular systems.
pub fn solve_dense(a: &[f64], b: &[f64]) -> Vec<f64> {
    let n = b.len();
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        assert!(m[piv * n + col].abs() > 1e-12, "singular matrix at column {col}");
        if piv != col {
            for c in 0..n {
                m.swap(col * n + c, piv * n + c);
            }
            rhs.swap(col, piv);
        }
        // eliminate
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                m[r * n + c] -= f * m[col * n + c];
            }
            rhs[r] -= f * rhs[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = rhs[i];
        for j in i + 1..n {
            s -= m[i * n + j] * x[j];
        }
        x[i] = s / m[i * n + i];
    }
    x
}

/// `xᵀ y`.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// `||x||₂`.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `||x||₁`.
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// `||x||_∞`.
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Soft-thresholding operator `sign(c) · max(|c| - t, 0)`.
#[inline]
pub fn soft_threshold(c: f64, t: f64) -> f64 {
    if c > t {
        c - t
    } else if c < -t {
        c + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::prop_assert;

    #[test]
    fn solves_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let x = solve_dense(&a, &[3.0, -2.0]);
        assert_eq!(x, vec![3.0, -2.0]);
    }

    #[test]
    fn solves_known_system() {
        // [2 1; 1 3] x = [3; 5] => x = [4/5, 7/5]
        let a = vec![2.0, 1.0, 1.0, 3.0];
        let x = solve_dense(&a, &[3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = vec![0.0, 1.0, 1.0, 0.0];
        let x = solve_dense(&a, &[2.0, 3.0]);
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn prop_solve_then_matvec_roundtrips() {
        forall(40, |g| {
            let n = g.usize_in(1..8);
            // diagonally dominant => nonsingular
            let mut a = vec![0.0f64; n * n];
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] = g.f64_in(-1.0, 1.0);
                }
                a[i * n + i] = n as f64 + 1.0 + g.f64_in(0.0, 1.0);
            }
            let x_true: Vec<f64> = (0..n).map(|_| g.f64_in(-5.0, 5.0)).collect();
            let b = matvec(&a, n, &x_true);
            let x = solve_dense(&a, &b);
            for (got, want) in x.iter().zip(&x_true) {
                prop_assert!((got - want).abs() < 1e-8, "{got} vs {want}");
            }
            Ok(())
        });
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(5.0, 2.0), 3.0);
        assert_eq!(soft_threshold(-5.0, 2.0), -3.0);
        assert_eq!(soft_threshold(1.0, 2.0), 0.0);
        assert_eq!(soft_threshold(-1.5, 2.0), 0.0);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(norm1(&x), 7.0);
        assert_eq!(norm_inf(&x), 4.0);
        assert_eq!(dot(&x, &[1.0, 1.0]), -1.0);
    }
}
