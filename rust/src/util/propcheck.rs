//! `propcheck` — a miniature property-based testing framework.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so this module
//! provides the subset the test suite needs: seeded generators, a `forall`
//! runner that reports the failing case and its seed, and greedy input
//! shrinking for the common container shapes (vectors, integer ranges).
//!
//! Usage:
//! ```no_run
//! use graphlab::util::propcheck::{forall, Gen};
//! use graphlab::prop_assert;
//! forall(100, |g: &mut Gen| {
//!     let xs = g.vec_usize(0..64, 0..100);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     prop_assert!(sorted.len() == xs.len(), "sort must preserve length");
//!     Ok(())
//! });
//! ```

use super::rng::Pcg32;
use std::ops::Range;

/// Property outcome: `Err(msg)` is a counterexample.
pub type PropResult = Result<(), String>;

/// Assertion macro for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($msg:tt)+) => {
        if !($cond) {
            return Err(format!($($msg)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Input generator handed to each property invocation.
pub struct Gen {
    rng: Pcg32,
    /// Size hint that grows across cases so early cases are small.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Pcg32::seed_from_u64(seed), size }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.end > r.start);
        self.rng.range_usize(r.start, r.end)
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.next_f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Vector of usize values: length drawn from `len_range` (capped by the
    /// current size hint), elements from `val_range`.
    pub fn vec_usize(&mut self, len_range: Range<usize>, val_range: Range<usize>) -> Vec<usize> {
        let max_len = len_range.end.min(len_range.start + self.size + 1);
        let len = self.usize_in(len_range.start..max_len.max(len_range.start + 1));
        (0..len).map(|_| self.usize_in(val_range.clone())).collect()
    }

    pub fn vec_f32(&mut self, len_range: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let len = self.usize_in(len_range);
        (0..len).map(|_| lo + (hi - lo) * self.rng.next_f32()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property. Panics with the seed and the
/// counterexample message on failure so the case can be replayed with
/// `forall_seeded`.
pub fn forall<F>(cases: usize, prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    forall_seeded(0xC0FFEE, cases, prop)
}

/// Like [`forall`] but with an explicit base seed (for replaying failures).
pub fn forall_seeded<F>(base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // Grow size with the case index: early cases stress small inputs.
        let size = 1 + case * 64 / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // Greedy "shrink": retry with progressively smaller size hints at
            // the same seed to look for a smaller failing configuration.
            let mut best = (size, msg);
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = (s, m2);
                }
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, size {}): {}\n\
                 replay: forall_seeded({seed:#x}, 1, ..) with size {}",
                best.0, best.1, best.0
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(50, |g| {
            let xs = g.vec_usize(0..32, 0..100);
            let mut sorted = xs.clone();
            sorted.sort_unstable();
            prop_assert!(sorted.len() == xs.len());
            prop_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "sorted order");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(50, |g| {
            let x = g.usize_in(0..1000);
            prop_assert!(x < 900, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut vals = Vec::new();
            forall_seeded(7, 10, |g| {
                vals.push(g.u32());
                Ok(())
            });
            vals
        };
        // Two runs see identical streams (pure function of seed).
        // NOTE: closure captures prevent direct comparison; inline instead.
        let mut a = Vec::new();
        forall_seeded(7, 10, |g| {
            a.push(g.u32());
            Ok(())
        });
        let mut b = Vec::new();
        forall_seeded(7, 10, |g| {
            b.push(g.u32());
            Ok(())
        });
        let _ = collect; // silence unused
        assert_eq!(a, b);
    }
}
