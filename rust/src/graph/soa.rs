//! Flat **structure-of-arrays vertex storage** for the numeric hot loops.
//!
//! The default [`DataGraph`] attaches one heap-allocated data block per
//! vertex, which is the right shape for arbitrary user types but the wrong
//! shape for the BP/Gibbs inner loops: a `Vec<f32>` belief per vertex
//! scatters the float payloads across the heap, so a sweep over vertices
//! chases one pointer (and takes one cache miss) per vertex before it
//! touches a single number. A [`FlatVertexStore`] instead keeps every
//! vertex's fixed-arity float payload in two contiguous slabs —
//! `Vec<f32>` for the distributions and `Vec<u32>` for the discrete
//! fields — indexed by `vid * lanes`, so a sweep is a linear walk and a
//! clone-under-lock delta capture is a `copy_from_slice` of one row
//! instead of a deep `Vec` clone.
//!
//! The [`FlatVertex`] view trait is the bridge: a vertex type declares how
//! many f32/u32 lanes it occupies at a given arity and how to scatter
//! itself into (and gather itself from) a row. The BP and Gibbs vertex
//! types implement it in `apps/`; the micro benches use the store to
//! measure the SoA-vs-`Vec`-per-vertex gap (`results/BENCH_shard.json`).

use super::{DataGraph, VertexId};
use std::marker::PhantomData;

/// A vertex type with a fixed per-arity flat layout: `f32_lanes(k)` floats
/// plus `u32_lanes(k)` words fully describe one vertex. Implementations
/// must keep `write_flat` and `read_flat` exact inverses.
pub trait FlatVertex: Sized {
    /// Number of `f32` lanes one vertex occupies at arity `arity`.
    fn f32_lanes(arity: usize) -> usize;

    /// Number of `u32` lanes one vertex occupies at arity `arity`.
    fn u32_lanes(arity: usize) -> usize;

    /// Scatter this vertex into its row slices. Both slices have exactly
    /// the lane lengths declared above.
    fn write_flat(&self, floats: &mut [f32], words: &mut [u32]);

    /// Gather a vertex back from its row slices.
    fn read_flat(arity: usize, floats: &[f32], words: &[u32]) -> Self;
}

/// Contiguous structure-of-arrays storage for `n` vertices of a
/// [`FlatVertex`] type: one `f32` slab and one `u32` slab, row `v` at
/// `v * lanes .. (v + 1) * lanes`. See the module docs for why this beats
/// `Vec`-per-vertex on sweep-shaped workloads.
pub struct FlatVertexStore<V: FlatVertex> {
    arity: usize,
    f32_lanes: usize,
    u32_lanes: usize,
    floats: Vec<f32>,
    words: Vec<u32>,
    len: usize,
    _marker: PhantomData<fn() -> V>,
}

impl<V: FlatVertex> FlatVertexStore<V> {
    /// Zero-initialized store for `len` vertices at arity `arity`.
    pub fn new(arity: usize, len: usize) -> FlatVertexStore<V> {
        let f32_lanes = V::f32_lanes(arity);
        let u32_lanes = V::u32_lanes(arity);
        FlatVertexStore {
            arity,
            f32_lanes,
            u32_lanes,
            floats: vec![0.0; len * f32_lanes],
            words: vec![0; len * u32_lanes],
            len,
            _marker: PhantomData,
        }
    }

    /// Gather every vertex data block of `graph` into a fresh store.
    pub fn from_graph<E>(graph: &mut DataGraph<V, E>, arity: usize) -> FlatVertexStore<V> {
        let mut store = FlatVertexStore::new(arity, graph.num_vertices());
        graph.for_each_vertex_mut(|v, data| store.set(v, data));
        store
    }

    /// Scatter every row back into `graph`'s vertex data blocks.
    pub fn scatter_to_graph<E>(&self, graph: &mut DataGraph<V, E>) {
        assert_eq!(self.len, graph.num_vertices(), "store/graph size mismatch");
        graph.for_each_vertex_mut(|v, data| *data = self.get(v));
    }

    /// Number of vertices stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the store empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The arity the store was built for.
    pub fn arity(&self) -> usize {
        self.arity
    }

    /// `f32` lanes per vertex row.
    pub fn f32_lanes(&self) -> usize {
        self.f32_lanes
    }

    /// `u32` lanes per vertex row.
    pub fn u32_lanes(&self) -> usize {
        self.u32_lanes
    }

    /// Vertex `v`'s float row (shared).
    #[inline]
    pub fn floats_of(&self, v: VertexId) -> &[f32] {
        let i = v as usize * self.f32_lanes;
        &self.floats[i..i + self.f32_lanes]
    }

    /// Vertex `v`'s float row (exclusive).
    #[inline]
    pub fn floats_of_mut(&mut self, v: VertexId) -> &mut [f32] {
        let i = v as usize * self.f32_lanes;
        &mut self.floats[i..i + self.f32_lanes]
    }

    /// Vertex `v`'s word row (shared).
    #[inline]
    pub fn words_of(&self, v: VertexId) -> &[u32] {
        let i = v as usize * self.u32_lanes;
        &self.words[i..i + self.u32_lanes]
    }

    /// Vertex `v`'s word row (exclusive).
    #[inline]
    pub fn words_of_mut(&mut self, v: VertexId) -> &mut [u32] {
        let i = v as usize * self.u32_lanes;
        &mut self.words[i..i + self.u32_lanes]
    }

    /// Both rows of vertex `v`, exclusively — the shape an update kernel
    /// wants (beliefs in the float row, discrete state in the word row).
    #[inline]
    pub fn row_mut(&mut self, v: VertexId) -> (&mut [f32], &mut [u32]) {
        let fi = v as usize * self.f32_lanes;
        let wi = v as usize * self.u32_lanes;
        (
            &mut self.floats[fi..fi + self.f32_lanes],
            &mut self.words[wi..wi + self.u32_lanes],
        )
    }

    /// Gather vertex `v` back into its materialized form.
    pub fn get(&self, v: VertexId) -> V {
        V::read_flat(self.arity, self.floats_of(v), self.words_of(v))
    }

    /// Scatter `data` into vertex `v`'s rows.
    pub fn set(&mut self, v: VertexId, data: &V) {
        let fi = v as usize * self.f32_lanes;
        let wi = v as usize * self.u32_lanes;
        data.write_flat(
            &mut self.floats[fi..fi + self.f32_lanes],
            &mut self.words[wi..wi + self.u32_lanes],
        );
    }

    /// Copy vertex `src`'s rows out of `from` into this store's vertex
    /// `dst` — the slab-slice form of clone-under-lock delta capture: two
    /// `copy_from_slice` calls, no allocation, no pointer chase.
    pub fn copy_row_from(&mut self, dst: VertexId, from: &FlatVertexStore<V>, src: VertexId) {
        debug_assert_eq!(self.f32_lanes, from.f32_lanes);
        debug_assert_eq!(self.u32_lanes, from.u32_lanes);
        self.floats_of_mut(dst).copy_from_slice(from.floats_of(src));
        self.words_of_mut(dst).copy_from_slice(from.words_of(src));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// A miniature BP-shaped vertex: one distribution of length `arity`
    /// plus two discrete fields.
    #[derive(Debug, Clone, PartialEq)]
    struct MiniVertex {
        dist: Vec<f32>,
        tag: u32,
        hits: u32,
    }

    impl FlatVertex for MiniVertex {
        fn f32_lanes(arity: usize) -> usize {
            arity
        }
        fn u32_lanes(_arity: usize) -> usize {
            2
        }
        fn write_flat(&self, floats: &mut [f32], words: &mut [u32]) {
            floats.copy_from_slice(&self.dist);
            words[0] = self.tag;
            words[1] = self.hits;
        }
        fn read_flat(_arity: usize, floats: &[f32], words: &[u32]) -> MiniVertex {
            MiniVertex { dist: floats.to_vec(), tag: words[0], hits: words[1] }
        }
    }

    fn mini(v: u32) -> MiniVertex {
        MiniVertex {
            dist: vec![v as f32, v as f32 + 0.5, v as f32 + 0.25],
            tag: v * 10,
            hits: v,
        }
    }

    #[test]
    fn set_get_round_trips_and_rows_are_contiguous() {
        let mut store: FlatVertexStore<MiniVertex> = FlatVertexStore::new(3, 4);
        assert_eq!(store.len(), 4);
        assert_eq!(store.f32_lanes(), 3);
        assert_eq!(store.u32_lanes(), 2);
        for v in 0..4u32 {
            store.set(v, &mini(v));
        }
        for v in 0..4u32 {
            assert_eq!(store.get(v), mini(v), "vertex {v}");
            assert_eq!(store.floats_of(v), mini(v).dist.as_slice());
            assert_eq!(store.words_of(v), &[v * 10, v]);
        }
        // rows really are slab slices at vid * lanes
        let (f, w) = store.row_mut(2);
        f[0] = 99.0;
        w[1] = 77;
        assert_eq!(store.floats_of(2)[0], 99.0);
        assert_eq!(store.words_of(2)[1], 77);
    }

    #[test]
    fn copy_row_from_is_a_slab_copy() {
        let mut a: FlatVertexStore<MiniVertex> = FlatVertexStore::new(3, 3);
        let mut b: FlatVertexStore<MiniVertex> = FlatVertexStore::new(3, 3);
        for v in 0..3u32 {
            a.set(v, &mini(v + 1));
        }
        b.copy_row_from(0, &a, 2);
        assert_eq!(b.get(0), mini(3));
        assert_eq!(b.get(1), MiniVertex { dist: vec![0.0; 3], tag: 0, hits: 0 });
    }

    #[test]
    fn graph_gather_scatter_round_trips() {
        let mut g: DataGraph<MiniVertex, ()> = {
            let mut b = GraphBuilder::new();
            for v in 0..5u32 {
                b.add_vertex(mini(v));
            }
            for v in 0..4u32 {
                b.add_undirected(v, v + 1, (), ());
            }
            b.build()
        };
        let mut store = FlatVertexStore::from_graph(&mut g, 3);
        assert_eq!(store.len(), 5);
        // mutate in flat form, scatter back
        for v in 0..5u32 {
            store.floats_of_mut(v)[0] += 100.0;
            store.words_of_mut(v)[1] += 1;
        }
        store.scatter_to_graph(&mut g);
        for v in 0..5u32 {
            let want = {
                let mut m = mini(v);
                m.dist[0] += 100.0;
                m.hits += 1;
                m
            };
            assert_eq!(*g.vertex_data(v), want, "vertex {v}");
        }
    }
}
