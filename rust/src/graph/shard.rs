//! The **sharded data graph**: a built [`DataGraph`] cut into `k` shards
//! with **ghost replication** of cut-boundary neighbors — the partition
//! layer Distributed GraphLab (Low et al. 2012) and GraphLab-in-the-Cloud
//! build everything on, emulated in one address space as the rehearsal for
//! real multi-process distribution.
//!
//! Each [`Shard`] owns one contiguous [`PartitionMap`] block of vertex ids
//! and carries:
//!
//! * a **local CSR** over its owned vertices whose adjacency entries
//!   resolve ([`Shard::resolve`]) to either another owned vertex or a
//!   **ghost** — a replicated read-only copy of a boundary neighbor owned
//!   by a remote shard;
//! * a **versioned ghost table** ([`GhostEntry`]): each replica pairs its
//!   data copy with a monotonically increasing `AtomicU64` sync stamp and a
//!   word-sized reader–writer lock guarding the copy.
//!
//! The explicit **sync API** ([`ShardedGraph::sync_vertex_from`],
//! [`ShardedGraph::sync_all`]) propagates an owned vertex's writes to every
//! remote replica, bumping each stamp — in a real distributed deployment
//! this is the network flush; here it is a locked copy whose counters
//! ([`crate::engine::ContentionStats::ghost_syncs`]) measure exactly the
//! traffic a cluster would pay, and whose **edge-cut ratio**
//! ([`ShardedGraph::cut_ratio`]) measures how much of it the partition
//! (and a locality-preserving vertex order, see
//! [`super::GraphBuilder::bfs_order`]) avoids.

use super::{Csr, DataCell, DataGraph, PartitionMap, VertexId};
use crate::consistency::{LockTable, ScopeLock};
use crate::transport::{GhostTransport, PullRequest};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

/// A replicated copy of a remote shard's boundary vertex: the data snapshot
/// plus a monotonically increasing version stamp bumped on every sync.
pub struct GhostEntry<V> {
    global: VertexId,
    owner: usize,
    /// Sync stamp: 0 = construction-time snapshot; monotone per entry.
    /// Bumped by one on every legacy [`GhostEntry::store`] and set to the
    /// shipped master version by [`GhostEntry::store_versioned`] (the
    /// transport path).
    version: AtomicU64,
    /// Pending-delta slot: the newest master version *shipped toward* this
    /// replica (possibly still queued in a transport). Always `>=
    /// version()`; the gap is the in-flight delta window.
    pending: AtomicU64,
    /// Newest version copied into the process-local [`DataGraph`] row of
    /// this vertex (resident mode only — see [`GhostEntry::sync_row`]).
    /// In-process sharded runs share one `DataGraph`, so the row is always
    /// current and this stays 0.
    row: AtomicU64,
    /// Guards `data`: readers share, a sync holds it exclusively.
    lock: ScopeLock,
    data: DataCell<V>,
}

impl<V> GhostEntry<V> {
    /// Global id of the replicated vertex.
    pub fn global(&self) -> VertexId {
        self.global
    }

    /// Shard that owns the master copy.
    pub fn owner(&self) -> usize {
        self.owner
    }

    /// Current sync stamp (monotone; 0 = never synced since construction).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Newest version shipped toward this replica (see the pending-delta
    /// slot). Equals [`GhostEntry::version`] when nothing is in flight.
    pub fn pending_version(&self) -> u64 {
        self.pending.load(Ordering::Acquire)
    }

    /// Advance the pending-delta slot (called by transports at send time).
    pub(crate) fn note_pending(&self, version: u64) {
        self.pending.fetch_max(version, Ordering::AcqRel);
    }
}

impl<V: Clone> GhostEntry<V> {
    /// Copy the replica into the caller's process-local master row if the
    /// row has fallen behind the replica — the resident-mode bridge
    /// between the versioned ghost table (where pulled and drained deltas
    /// land) and the `DataGraph` rows update functions actually read. In
    /// one address space the row IS the remote owner's live master and
    /// this is never called; in a resident process the row is a dead copy
    /// unless refreshed here.
    ///
    /// `apply` receives the replica under its read lock and must
    /// `clone_from` it into the row. The caller must hold the vertex's
    /// **write** lock (a Full-model scope), so no concurrent reader can
    /// observe the row mid-write.
    pub(crate) fn sync_row(&self, apply: impl FnOnce(&V)) {
        if self.version.load(Ordering::Acquire) <= self.row.load(Ordering::Acquire) {
            return;
        }
        self.lock.read_spin();
        // Re-read under the lock: the version the row will now reflect.
        let version = self.version.load(Ordering::Acquire);
        // SAFETY: read lock held for the duration of the copy-out.
        apply(unsafe { self.data.get_ref() });
        self.lock.unlock_read();
        self.row.fetch_max(version, Ordering::AcqRel);
    }
}

impl<V: Clone> GhostEntry<V> {
    /// Clone the replica under a shared lock.
    pub fn read(&self) -> V {
        self.lock.read_spin();
        // SAFETY: read lock held for the duration of the clone.
        let value = unsafe { self.data.get_ref() }.clone();
        self.lock.unlock_read();
        value
    }

    /// Overwrite the replica from the owner's data and bump the version.
    /// `clone_from` rather than `= clone()`: for heap-backed vertex types
    /// (`Vec<f32>` beliefs) it copies into the replica's existing
    /// allocation, so a steady-state sync writes bytes instead of
    /// allocating.
    fn store(&self, value: &V) {
        self.lock.write_spin();
        // SAFETY: exclusive lock held for the duration of the write.
        unsafe {
            self.data.get_mut_unchecked().clone_from(value);
        }
        self.lock.unlock_write();
        let bumped = self.version.fetch_add(1, Ordering::Release) + 1;
        self.pending.fetch_max(bumped, Ordering::AcqRel);
    }

    /// Overwrite the replica *only if* `version` is newer than what it
    /// holds (the transport path: reordered or duplicate deliveries lose).
    /// The version check happens under the entry's write lock so a stale
    /// payload can never land after a fresher one. Returns whether the
    /// write was applied.
    pub(crate) fn store_versioned(&self, value: &V, version: u64) -> bool {
        self.lock.write_spin();
        let newer = version > self.version.load(Ordering::Acquire);
        if newer {
            // SAFETY: exclusive lock held for the duration of the write.
            // `clone_from` reuses the replica's existing heap allocation.
            unsafe {
                self.data.get_mut_unchecked().clone_from(value);
            }
            self.version.store(version, Ordering::Release);
        }
        self.lock.unlock_write();
        if newer {
            self.pending.fetch_max(version, Ordering::AcqRel);
        }
        newer
    }
}

/// Resolution of a shard-local adjacency code (see
/// [`Shard::local_neighbors`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalRef {
    /// Neighbor owned by this shard (global vertex id).
    Owned(VertexId),
    /// Index into this shard's ghost table.
    Ghost(u32),
}

/// One shard: a contiguous block of owned vertices, their local CSR, and
/// the ghost replicas of their remote neighbors.
pub struct Shard<V> {
    id: usize,
    owned: Range<VertexId>,
    /// Local CSR over owned vertices (row `i` = owned vertex
    /// `owned.start + i`). Items `< num_owned` are owned-local indices;
    /// items `>= num_owned` encode `num_owned + ghost_index`.
    local_adj: Csr,
    /// Ghost replicas, sorted by global id.
    ghosts: Vec<GhostEntry<V>>,
    /// Per owned vertex: does its scope cross the shard boundary?
    boundary: Vec<bool>,
}

impl<V> Shard<V> {
    pub fn id(&self) -> usize {
        self.id
    }

    pub fn owned_range(&self) -> Range<VertexId> {
        self.owned.clone()
    }

    pub fn num_owned(&self) -> usize {
        (self.owned.end - self.owned.start) as usize
    }

    pub fn num_ghosts(&self) -> usize {
        self.ghosts.len()
    }

    pub fn owns(&self, v: VertexId) -> bool {
        self.owned.contains(&v)
    }

    /// Does owned vertex `v` have a neighbor on another shard?
    pub fn is_boundary(&self, v: VertexId) -> bool {
        debug_assert!(self.owns(v), "vertex {v} not owned by shard {}", self.id);
        self.boundary[(v - self.owned.start) as usize]
    }

    pub fn ghosts(&self) -> &[GhostEntry<V>] {
        &self.ghosts
    }

    pub fn ghost(&self, idx: usize) -> &GhostEntry<V> {
        &self.ghosts[idx]
    }

    /// The replica of global vertex `g`, if this shard holds one.
    pub fn ghost_of(&self, global: VertexId) -> Option<&GhostEntry<V>> {
        self.ghosts
            .binary_search_by_key(&global, |g| g.global)
            .ok()
            .map(|i| &self.ghosts[i])
    }

    /// Encoded local adjacency row of owned vertex `v`; decode entries with
    /// [`Self::resolve`].
    pub fn local_neighbors(&self, v: VertexId) -> &[u32] {
        debug_assert!(self.owns(v), "vertex {v} not owned by shard {}", self.id);
        self.local_adj.row((v - self.owned.start) as usize)
    }

    /// Decode a [`Self::local_neighbors`] entry.
    pub fn resolve(&self, code: u32) -> LocalRef {
        let n = self.num_owned() as u32;
        if code < n {
            LocalRef::Owned(self.owned.start + code)
        } else {
            LocalRef::Ghost(code - n)
        }
    }
}

/// The sharded view of a data graph. Owns the partition metadata and all
/// ghost replicas; the master vertex/edge data stays in the [`DataGraph`].
pub struct ShardedGraph<V> {
    part: PartitionMap,
    shards: Vec<Shard<V>>,
    /// CSR over vertices: `replica_sites[replica_offsets[v]..replica_offsets[v+1]]`
    /// are v's ghost replicas, packed as (shard, ghost index).
    replica_offsets: Vec<u32>,
    replica_sites: Vec<(u32, u32)>,
    /// Per-vertex master version: bumped by the owner on every replicated
    /// write ([`ShardedGraph::bump_master`]); a replica's staleness is
    /// `master_version(v) - entry.version()`. Stays 0 for interior
    /// vertices.
    master_versions: Vec<AtomicU64>,
    edge_cut: usize,
    num_edges: usize,
}

impl<V: Clone> ShardedGraph<V> {
    /// Cut `graph` into `num_shards` contiguous-block shards (clamped to at
    /// least 1), snapshotting ghost data from the current vertex values.
    /// Takes `&mut` only for exclusive, setup-time data access — the
    /// returned value owns everything it needs and does not borrow the
    /// graph.
    pub fn new<E>(graph: &mut DataGraph<V, E>, num_shards: usize) -> ShardedGraph<V> {
        let n = graph.num_vertices();
        let part = PartitionMap::new(n, num_shards);
        let k = part.num_parts();
        let mut shards = Vec::with_capacity(k);
        let mut replica_lists: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
        for s in 0..k {
            let owned = part.range(s);
            let start = owned.start;
            let num_owned = (owned.end - owned.start) as usize;

            // Ghost set: every neighbor owned by another shard.
            let mut ghost_ids: Vec<VertexId> = Vec::new();
            for v in owned.clone() {
                for &u in graph.neighbors(v) {
                    if part.owner_of(u) != s {
                        ghost_ids.push(u);
                    }
                }
            }
            ghost_ids.sort_unstable();
            ghost_ids.dedup();

            // Local CSR: owned-local indices for intra-shard neighbors,
            // `num_owned + ghost_index` for cut-boundary neighbors.
            let mut offsets = vec![0u32; num_owned + 1];
            let mut items = Vec::new();
            let mut boundary = vec![false; num_owned];
            for v in owned.clone() {
                let li = (v - start) as usize;
                for &u in graph.neighbors(v) {
                    if part.owner_of(u) == s {
                        items.push(u - start);
                    } else {
                        boundary[li] = true;
                        let g =
                            ghost_ids.binary_search(&u).expect("ghost indexed") as u32;
                        items.push(num_owned as u32 + g);
                    }
                }
                offsets[li + 1] = items.len() as u32;
            }

            // Ghost entries snapshot the owner's current data; register
            // each as a replica site of its global vertex.
            let mut ghosts = Vec::with_capacity(ghost_ids.len());
            for (i, &u) in ghost_ids.iter().enumerate() {
                replica_lists[u as usize].push((s as u32, i as u32));
                ghosts.push(GhostEntry {
                    global: u,
                    owner: part.owner_of(u),
                    version: AtomicU64::new(0),
                    pending: AtomicU64::new(0),
                    row: AtomicU64::new(0),
                    lock: ScopeLock::new(),
                    data: DataCell::new(graph.vertex_data_ref(u).clone()),
                });
            }
            shards.push(Shard {
                id: s,
                owned,
                local_adj: Csr { offsets, items },
                ghosts,
                boundary,
            });
        }

        let mut replica_offsets = vec![0u32; n + 1];
        let mut replica_sites = Vec::new();
        for (v, list) in replica_lists.iter().enumerate() {
            replica_sites.extend_from_slice(list);
            replica_offsets[v + 1] = replica_sites.len() as u32;
        }

        let mut edge_cut = 0usize;
        for e in 0..graph.num_edges() as u32 {
            let edge = graph.edge(e);
            if part.owner_of(edge.src) != part.owner_of(edge.dst) {
                edge_cut += 1;
            }
        }

        ShardedGraph {
            part,
            shards,
            replica_offsets,
            replica_sites,
            master_versions: (0..n).map(|_| AtomicU64::new(0)).collect(),
            edge_cut,
            num_edges: graph.num_edges(),
        }
    }

    /// Propagate `data` — the owner's current value of `v`, read under the
    /// caller's lock (e.g. the still-held update scope) — to every remote
    /// ghost replica. Returns the number of replicas written.
    pub fn sync_vertex_from(&self, v: VertexId, data: &V) -> u64 {
        let sites = self.replicas_of(v);
        for &(s, g) in sites {
            self.shards[s as usize].ghosts[g as usize].store(data);
        }
        sites.len() as u64
    }

    /// Versioned propagation (the transport path): write `data` stamped
    /// with master `version` to every replica, skipping any that already
    /// hold something newer. Returns the number of replicas actually
    /// written.
    pub fn sync_vertex_versioned(&self, v: VertexId, data: &V, version: u64) -> u64 {
        let mut applied = 0;
        for &(s, g) in self.replicas_of(v) {
            let entry = &self.shards[s as usize].ghosts[g as usize];
            entry.note_pending(version);
            if entry.store_versioned(data, version) {
                applied += 1;
            }
        }
        applied
    }

    /// Pull-on-demand: refresh one replica from its owner's current master
    /// data under a freshly taken per-vertex read lock, stamping it with
    /// the master version. The refresh is issued through `transport`'s
    /// request/reply path (`GhostTransport::pull`), so on a serializing
    /// backend the data crosses the wire as a framed request + encoded
    /// reply instead of a direct peer read; the owner-side service closure
    /// supplied here is the single place the master is read, and it runs
    /// under the held read lock. Returns whether the replica was behind
    /// and got updated. (The engine's scope-admission staleness check uses
    /// the in-scope variant `Scope::refresh_stale_ghosts`, which reuses
    /// the locks the scope already holds.)
    pub fn pull_replica<E>(
        &self,
        graph: &DataGraph<V, E>,
        locks: &LockTable,
        transport: &dyn GhostTransport<V>,
        shard: usize,
        ghost: usize,
    ) -> bool {
        let entry = &self.shards[shard].ghosts[ghost];
        let v = entry.global;
        if entry.version() >= self.master_version(v) {
            return false;
        }
        let _g = locks.read(v);
        // Re-read under the lock: a writer may have bumped again before we
        // acquired it, and the data we read now carries that version.
        let master = self.master_version(v);
        let receipt = transport.pull(
            shard,
            PullRequest { vertex: v, min_version: master },
            &|u| {
                // SAFETY: read lock on v held for the duration of the copy.
                let data = unsafe { graph.vertex_data_unchecked(u) };
                (data, self.master_version(u))
            },
        );
        receipt.applied
    }

    /// Propagate vertex `v` under a freshly taken per-vertex read lock.
    pub fn sync_vertex<E>(
        &self,
        graph: &DataGraph<V, E>,
        locks: &LockTable,
        v: VertexId,
    ) -> u64 {
        if self.replicas_of(v).is_empty() {
            return 0;
        }
        let _g = locks.read(v);
        // SAFETY: read lock on v held for the duration of the propagation.
        let data = unsafe { graph.vertex_data_unchecked(v) };
        self.sync_vertex_from(v, data)
    }

    /// Full sync pass: propagate every *replicated* vertex — interior
    /// vertices are skipped before any lock is taken, so a pass costs
    /// O(replicated) lock acquisitions instead of k·|V|. Returns
    /// `(vertices synced, replicas written)`.
    pub fn sync_all<E>(&self, graph: &DataGraph<V, E>, locks: &LockTable) -> (u64, u64) {
        let mut vertices = 0;
        let mut replicas = 0;
        for v in 0..self.part.len() as u32 {
            if self.replicas_of(v).is_empty() {
                continue;
            }
            vertices += 1;
            replicas += self.sync_vertex(graph, locks, v);
        }
        (vertices, replicas)
    }

    /// Every ghost replica equals its owner's current data (exclusive
    /// access; test/diagnostic helper).
    pub fn ghosts_consistent<E>(&self, graph: &mut DataGraph<V, E>) -> bool
    where
        V: PartialEq,
    {
        for sh in &self.shards {
            for g in &sh.ghosts {
                if g.read() != *graph.vertex_data_ref(g.global) {
                    return false;
                }
            }
        }
        true
    }
}

impl<V> ShardedGraph<V> {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn num_vertices(&self) -> usize {
        self.part.len()
    }

    pub fn partition(&self) -> &PartitionMap {
        &self.part
    }

    /// The shard owning vertex `v`.
    pub fn owner_of(&self, v: VertexId) -> usize {
        self.part.owner_of(v)
    }

    pub fn shard(&self, s: usize) -> &Shard<V> {
        &self.shards[s]
    }

    pub fn shards(&self) -> &[Shard<V>] {
        &self.shards
    }

    /// Does `v`'s scope cross a shard boundary?
    pub fn is_boundary(&self, v: VertexId) -> bool {
        self.shards[self.part.owner_of(v)].is_boundary(v)
    }

    /// Total ghost replicas across all shards.
    pub fn num_ghosts(&self) -> usize {
        self.shards.iter().map(|s| s.ghosts.len()).sum()
    }

    /// Current master version of vertex `v` (0 = never bumped).
    pub fn master_version(&self, v: VertexId) -> u64 {
        self.master_versions[v as usize].load(Ordering::Acquire)
    }

    /// Bump and return vertex `v`'s master version. Called by the owner
    /// while holding `v`'s write lock (one bump per replicated write), so
    /// versions are unique and monotone per vertex.
    pub fn bump_master(&self, v: VertexId) -> u64 {
        self.master_versions[v as usize].fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Ghost replica sites of vertex `v`, packed as (shard, ghost index).
    pub fn replicas_of(&self, v: VertexId) -> &[(u32, u32)] {
        let (a, b) = (
            self.replica_offsets[v as usize] as usize,
            self.replica_offsets[v as usize + 1] as usize,
        );
        &self.replica_sites[a..b]
    }

    /// Directed edges whose endpoints live on different shards.
    pub fn edge_cut(&self) -> usize {
        self.edge_cut
    }

    /// Cut edges as a fraction of all edges — the replication/sync traffic
    /// a distributed deployment would pay for this partition.
    pub fn cut_ratio(&self) -> f64 {
        self.edge_cut as f64 / self.num_edges.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::GraphBuilder;
    use super::*;

    /// 4x4 grid, ids row-major: contiguous 2-way split cuts the middle row
    /// boundary only.
    fn grid4() -> DataGraph<u64, ()> {
        let side = 4u32;
        let mut b = GraphBuilder::new();
        for i in 0..side * side {
            b.add_vertex(i as u64);
        }
        for y in 0..side {
            for x in 0..side {
                let v = y * side + x;
                if x + 1 < side {
                    b.add_undirected(v, v + 1, (), ());
                }
                if y + 1 < side {
                    b.add_undirected(v, v + side, (), ());
                }
            }
        }
        b.build()
    }

    #[test]
    fn shard_structure_covers_and_cuts() {
        let mut g = grid4();
        let sg = ShardedGraph::new(&mut g, 2);
        assert_eq!(sg.num_shards(), 2);
        assert_eq!(sg.num_vertices(), 16);
        // owned blocks tile the id space
        assert_eq!(sg.shard(0).owned_range(), 0..8);
        assert_eq!(sg.shard(1).owned_range(), 8..16);
        // the only cut edges are the 4 vertical pairs between rows 1 and 2
        assert_eq!(sg.edge_cut(), 8, "4 undirected pairs = 8 directed edges");
        assert!((sg.cut_ratio() - 8.0 / 48.0).abs() < 1e-12);
        // each shard ghosts the 4 vertices of the facing row
        assert_eq!(sg.shard(0).num_ghosts(), 4);
        assert_eq!(sg.shard(1).num_ghosts(), 4);
        assert_eq!(sg.num_ghosts(), 8);
        // boundary flags: rows 1 (ids 4..8) and 2 (ids 8..12)
        for v in 0..16u32 {
            let expect = (4..12).contains(&v);
            assert_eq!(sg.is_boundary(v), expect, "vertex {v}");
        }
    }

    #[test]
    fn local_adjacency_resolves_to_owned_and_ghosts() {
        let mut g = grid4();
        let sg = ShardedGraph::new(&mut g, 2);
        let s0 = sg.shard(0);
        // vertex 5 (row 1): neighbors 1, 4, 6 owned; 9 ghosted
        let mut owned = Vec::new();
        let mut ghosts = Vec::new();
        for &code in s0.local_neighbors(5) {
            match s0.resolve(code) {
                LocalRef::Owned(u) => owned.push(u),
                LocalRef::Ghost(gi) => ghosts.push(s0.ghost(gi as usize).global()),
            }
        }
        owned.sort_unstable();
        assert_eq!(owned, vec![1, 4, 6]);
        assert_eq!(ghosts, vec![9]);
        assert_eq!(s0.ghost_of(9).unwrap().owner(), 1);
        assert!(s0.ghost_of(3).is_none(), "owned vertices are not ghosted");
        // interior vertex 0: all neighbors owned
        for &code in s0.local_neighbors(0) {
            assert!(matches!(s0.resolve(code), LocalRef::Owned(_)));
        }
    }

    #[test]
    fn sync_propagates_and_versions_are_monotone() {
        let mut g = grid4();
        let sg = ShardedGraph::new(&mut g, 4);
        let locks = LockTable::new(g.num_vertices());
        assert!(sg.ghosts_consistent(&mut g), "construction snapshots match");

        // mutate a replicated vertex; replicas are stale until synced
        *g.vertex_data(5) = 999;
        assert!(!sg.ghosts_consistent(&mut g));
        let wrote = sg.sync_vertex(&g, &locks, 5);
        assert_eq!(wrote as usize, sg.replicas_of(5).len());
        assert!(wrote >= 1, "row-contiguous 4-way split replicates vertex 5");
        assert!(sg.ghosts_consistent(&mut g));

        // versions bump monotonically per sync
        let before: Vec<u64> = sg
            .replicas_of(5)
            .iter()
            .map(|&(s, gi)| sg.shard(s as usize).ghost(gi as usize).version())
            .collect();
        assert!(before.iter().all(|&v| v == 1));
        let (vertices, replicas) = sg.sync_all(&g, &locks);
        assert_eq!(replicas as usize, sg.num_ghosts());
        let replicated = (0..16u32).filter(|&v| !sg.replicas_of(v).is_empty()).count();
        assert_eq!(vertices as usize, replicated, "interior vertices skipped");
        for (i, &(s, gi)) in sg.replicas_of(5).iter().enumerate() {
            let after = sg.shard(s as usize).ghost(gi as usize).version();
            assert!(after > before[i], "version must increase on sync");
        }
    }

    /// Versioned stores apply newest-wins, advance the pending slot, and a
    /// stale pull-on-demand refreshes a lagging replica from master data.
    #[test]
    fn versioned_sync_and_pull_on_demand() {
        use crate::transport::DirectTransport;
        let mut g = grid4();
        let sg = ShardedGraph::new(&mut g, 2);
        let locks = LockTable::new(g.num_vertices());
        let v = 5u32; // row 1, replicated on shard 1
        assert!(!sg.replicas_of(v).is_empty());
        assert_eq!(sg.master_version(v), 0);

        // owner writes + versioned flush
        *g.vertex_data(v) = 111;
        let ver = sg.bump_master(v);
        assert_eq!(ver, 1);
        let applied = sg.sync_vertex_versioned(v, &111, ver);
        assert_eq!(applied as usize, sg.replicas_of(v).len());
        let (s, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(s as usize).ghost(gi as usize);
        assert_eq!(entry.version(), 1);
        assert_eq!(entry.pending_version(), 1);
        // a duplicate/stale delivery is rejected
        assert_eq!(sg.sync_vertex_versioned(v, &0, 1), 0);

        // owner writes twice more without flushing: replica lags by 2
        *g.vertex_data(v) = 333;
        sg.bump_master(v);
        sg.bump_master(v);
        assert_eq!(sg.master_version(v) - entry.version(), 2);
        // pull-on-demand catches the replica up to the master version
        let t = DirectTransport::new(&sg);
        assert!(sg.pull_replica(&g, &locks, &t, s as usize, gi as usize));
        assert_eq!(entry.version(), 3);
        assert_eq!(entry.read(), 333);
        assert!(
            !sg.pull_replica(&g, &locks, &t, s as usize, gi as usize),
            "already fresh"
        );
    }

    #[test]
    fn single_shard_has_no_ghosts() {
        let mut g = grid4();
        let sg = ShardedGraph::new(&mut g, 1);
        assert_eq!(sg.num_shards(), 1);
        assert_eq!(sg.num_ghosts(), 0);
        assert_eq!(sg.edge_cut(), 0);
        assert_eq!(sg.cut_ratio(), 0.0);
        for v in 0..16u32 {
            assert!(!sg.is_boundary(v));
            assert!(sg.replicas_of(v).is_empty());
        }
    }

    #[test]
    fn more_shards_than_vertices() {
        let mut b: GraphBuilder<u8, ()> = GraphBuilder::new();
        for i in 0..3 {
            b.add_vertex(i);
        }
        b.add_undirected(0, 1, (), ());
        b.add_undirected(1, 2, (), ());
        let mut g = b.build();
        let sg = ShardedGraph::new(&mut g, 8);
        // every vertex its own shard; all edges cut
        assert_eq!(sg.edge_cut(), 4);
        assert!(sg.is_boundary(1));
        assert_eq!(sg.shard(0).num_ghosts(), 1);
        assert_eq!(sg.shard(1).num_ghosts(), 2);
        for s in 3..sg.num_shards() {
            assert_eq!(sg.shard(s).num_owned(), 0);
            assert_eq!(sg.shard(s).num_ghosts(), 0);
        }
    }
}
