//! The GraphLab **data graph** (paper §3.1): a directed graph where arbitrary
//! user data blocks are attached to every vertex and every directed edge.
//!
//! The representation is a frozen CSR (compressed sparse row) built once by
//! [`GraphBuilder`]; GraphLab programs mutate the *data*, never the
//! *structure*, which is what lets the engine hand out interior-mutable
//! references guarded by the consistency-model lock table
//! (see [`crate::consistency`]).

mod builder;
mod partition;
mod sample;
mod shard;
mod soa;

pub use builder::GraphBuilder;
pub use partition::PartitionMap;
pub use sample::induced_subgraph;
pub use shard::{GhostEntry, LocalRef, Shard, ShardedGraph};
pub use soa::{FlatVertex, FlatVertexStore};

use std::cell::UnsafeCell;

/// Vertex identifier (index into the vertex arrays).
pub type VertexId = u32;
/// Edge identifier (index into the edge arrays).
pub type EdgeId = u32;

/// Endpoints of a directed edge `src -> dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub src: VertexId,
    pub dst: VertexId,
}

/// Interior-mutable data cell. Safety discipline: mutable access only while
/// the owning vertex's consistency locks are held (enforced by
/// [`crate::consistency::Scope`]) or under `&mut` / single-thread execution.
#[derive(Debug)]
pub(crate) struct DataCell<T>(UnsafeCell<T>);

// SAFETY: cross-thread access is mediated by the consistency lock table; the
// cell itself is just storage.
unsafe impl<T: Send> Sync for DataCell<T> {}

impl<T> DataCell<T> {
    fn new(v: T) -> Self {
        DataCell(UnsafeCell::new(v))
    }
    #[inline]
    unsafe fn get_ref(&self) -> &T {
        &*self.0.get()
    }
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn get_mut_unchecked(&self) -> &mut T {
        &mut *self.0.get()
    }
}

/// Compressed adjacency: `items[offsets[v]..offsets[v+1]]` are v's entries.
#[derive(Debug, Clone, Default)]
pub(crate) struct Csr {
    pub offsets: Vec<u32>,
    pub items: Vec<u32>,
}

impl Csr {
    #[inline]
    pub fn row(&self, v: usize) -> &[u32] {
        &self.items[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }
}

/// The data graph. `V` is the per-vertex data block, `E` per-directed-edge.
pub struct DataGraph<V, E> {
    vertex_data: Vec<DataCell<V>>,
    edge_data: Vec<DataCell<E>>,
    edges: Vec<Edge>,
    /// Out-edge ids per vertex, sorted by destination vertex.
    out_adj: Csr,
    /// In-edge ids per vertex, sorted by source vertex.
    in_adj: Csr,
    /// Sorted unique neighbor vertex ids (union of in/out, excluding self).
    scope_adj: Csr,
    /// Same neighbor sets reordered for scope-lock acquisition: descending
    /// degree (ties by ascending id). Trying the most-contended lock first
    /// makes a conflicted all-or-nothing acquisition fail before it has
    /// taken (and must roll back) the cheap low-degree locks.
    lock_adj: Csr,
    /// Reverse edge id for each edge, if the opposite direction exists.
    reverse: Vec<Option<EdgeId>>,
    max_degree: usize,
}

impl<V, E> DataGraph<V, E> {
    pub fn num_vertices(&self) -> usize {
        self.vertex_data.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Endpoints of edge `e`.
    #[inline]
    pub fn edge(&self, e: EdgeId) -> Edge {
        self.edges[e as usize]
    }

    /// Out-edge ids of `v` (sorted by destination).
    #[inline]
    pub fn out_edges(&self, v: VertexId) -> &[EdgeId] {
        self.out_adj.row(v as usize)
    }

    /// In-edge ids of `v` (sorted by source).
    #[inline]
    pub fn in_edges(&self, v: VertexId) -> &[EdgeId] {
        self.in_adj.row(v as usize)
    }

    /// Sorted unique neighbors of `v` (in- or out-, self excluded).
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        self.scope_adj.row(v as usize)
    }

    /// Neighbors of `v` in scope-lock acquisition order: descending degree,
    /// ties by ascending id. Same *set* as [`Self::neighbors`]; the order
    /// exists purely for conflict locality in the try-lock protocol (see
    /// [`crate::consistency::LockTable::try_lock_scope`]).
    #[inline]
    pub fn lock_neighbors(&self, v: VertexId) -> &[VertexId] {
        self.lock_adj.row(v as usize)
    }

    pub fn degree(&self, v: VertexId) -> usize {
        self.neighbors(v).len()
    }

    pub fn max_degree(&self) -> usize {
        self.max_degree
    }

    /// The directed edge `u -> v`, if present (binary search on sorted row).
    pub fn find_edge(&self, u: VertexId, v: VertexId) -> Option<EdgeId> {
        let row = self.out_adj.row(u as usize);
        row.binary_search_by_key(&v, |&e| self.edges[e as usize].dst)
            .ok()
            .map(|i| row[i])
    }

    /// Reverse edge of `e` (`v->u` for `u->v`), if present.
    #[inline]
    pub fn reverse_edge(&self, e: EdgeId) -> Option<EdgeId> {
        self.reverse[e as usize]
    }

    // ---- data access -----------------------------------------------------
    //
    // The `unsafe` accessors require that the caller holds the appropriate
    // consistency-model locks (or is otherwise externally synchronized, e.g.
    // the sequential engine / single-threaded setup code).

    /// # Safety
    /// Caller must hold at least a read lock on `v` (or be externally
    /// synchronized).
    #[inline]
    pub unsafe fn vertex_data_unchecked(&self, v: VertexId) -> &V {
        self.vertex_data[v as usize].get_ref()
    }

    /// # Safety
    /// Caller must hold the write lock on `v` (or be externally synchronized).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn vertex_data_mut_unchecked(&self, v: VertexId) -> &mut V {
        self.vertex_data[v as usize].get_mut_unchecked()
    }

    /// # Safety
    /// Caller must hold a read lock covering edge `e` (its endpoint vertices).
    #[inline]
    pub unsafe fn edge_data_unchecked(&self, e: EdgeId) -> &E {
        self.edge_data[e as usize].get_ref()
    }

    /// # Safety
    /// Caller must hold write coverage of edge `e` per the consistency model.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn edge_data_mut_unchecked(&self, e: EdgeId) -> &mut E {
        self.edge_data[e as usize].get_mut_unchecked()
    }

    // Safe accessors for exclusive/setup contexts.

    pub fn vertex_data(&mut self, v: VertexId) -> &mut V {
        self.vertex_data[v as usize].0.get_mut()
    }

    pub fn edge_data(&mut self, e: EdgeId) -> &mut E {
        self.edge_data[e as usize].0.get_mut()
    }

    /// Read-only snapshot accessor. Safe because it takes `&mut self` — no
    /// concurrent engine can be running.
    pub fn vertex_data_ref(&mut self, v: VertexId) -> &V {
        self.vertex_data[v as usize].0.get_mut()
    }

    /// Apply `f` to every vertex's data (exclusive access).
    pub fn for_each_vertex_mut(&mut self, mut f: impl FnMut(VertexId, &mut V)) {
        for (i, cell) in self.vertex_data.iter_mut().enumerate() {
            f(i as VertexId, cell.0.get_mut());
        }
    }

    /// Apply `f` to every edge's data (exclusive access).
    pub fn for_each_edge_mut(&mut self, mut f: impl FnMut(EdgeId, Edge, &mut E)) {
        for (i, cell) in self.edge_data.iter_mut().enumerate() {
            f(i as EdgeId, self.edges[i], cell.0.get_mut());
        }
    }

    /// Fold over vertex data (read-only, exclusive access).
    pub fn fold_vertices<T>(&mut self, init: T, mut f: impl FnMut(T, VertexId, &V) -> T) -> T {
        let mut acc = init;
        for i in 0..self.vertex_data.len() {
            acc = f(acc, i as VertexId, self.vertex_data[i].0.get_mut());
        }
        acc
    }
}

impl<V: Clone, E: Clone> DataGraph<V, E> {
    /// Snapshot all vertex data (exclusive access).
    pub fn vertex_data_snapshot(&mut self) -> Vec<V> {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.vertex_data(v).clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DataGraph<i32, f32> {
        // 0 -> 1 -> 3, 0 -> 2 -> 3 (directed), plus undirected 1 <-> 2
        let mut b = GraphBuilder::new();
        for i in 0..4 {
            b.add_vertex(i);
        }
        b.add_edge(0, 1, 0.1);
        b.add_edge(1, 3, 1.3);
        b.add_edge(0, 2, 0.2);
        b.add_edge(2, 3, 2.3);
        b.add_undirected(1, 2, 1.2, 2.1);
        b.build()
    }

    #[test]
    fn sizes() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 6);
    }

    #[test]
    fn adjacency_is_sorted_and_complete() {
        let g = diamond();
        let outs: Vec<VertexId> =
            g.out_edges(0).iter().map(|&e| g.edge(e).dst).collect();
        assert_eq!(outs, vec![1, 2]);
        let ins: Vec<VertexId> = g.in_edges(3).iter().map(|&e| g.edge(e).src).collect();
        assert_eq!(ins, vec![1, 2]);
    }

    #[test]
    fn neighbors_union_in_out() {
        let g = diamond();
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(3), 2);
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn find_edge_and_reverse() {
        let g = diamond();
        let e12 = g.find_edge(1, 2).unwrap();
        let e21 = g.find_edge(2, 1).unwrap();
        assert_eq!(g.reverse_edge(e12), Some(e21));
        assert_eq!(g.reverse_edge(e21), Some(e12));
        let e01 = g.find_edge(0, 1).unwrap();
        assert_eq!(g.reverse_edge(e01), None);
        assert_eq!(g.find_edge(3, 0), None);
    }

    #[test]
    fn data_mutation() {
        let mut g = diamond();
        *g.vertex_data(2) = 99;
        assert_eq!(*g.vertex_data_ref(2), 99);
        let e = g.find_edge(0, 1).unwrap();
        *g.edge_data(e) = 7.5;
        let mut seen = 0.0;
        g.for_each_edge_mut(|id, _, d| {
            if id == e {
                seen = *d;
            }
        });
        assert_eq!(seen, 7.5);
    }

    #[test]
    fn fold_vertices_sums() {
        let mut g = diamond();
        let total = g.fold_vertices(0, |acc, _, d| acc + *d);
        assert_eq!(total, 0 + 1 + 2 + 3);
    }

    #[test]
    fn unsafe_accessors_match_safe_ones() {
        let mut g = diamond();
        *g.vertex_data(1) = 41;
        unsafe {
            assert_eq!(*g.vertex_data_unchecked(1), 41);
            *g.vertex_data_mut_unchecked(1) += 1;
        }
        assert_eq!(*g.vertex_data_ref(1), 42);
    }
}
