//! Construction of [`DataGraph`]s. Structure is accumulated incrementally and
//! frozen into CSR form by [`GraphBuilder::build`].

use super::{Csr, DataCell, DataGraph, Edge, EdgeId, VertexId};

/// Incremental graph builder.
pub struct GraphBuilder<V, E> {
    vertex_data: Vec<V>,
    edges: Vec<Edge>,
    edge_data: Vec<E>,
    bfs_order: bool,
}

impl<V, E> Default for GraphBuilder<V, E> {
    fn default() -> Self {
        GraphBuilder {
            vertex_data: Vec::new(),
            edges: Vec::new(),
            edge_data: Vec::new(),
            bfs_order: false,
        }
    }
}

impl<V, E> GraphBuilder<V, E> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(vertices: usize, edges: usize) -> Self {
        GraphBuilder {
            vertex_data: Vec::with_capacity(vertices),
            edges: Vec::with_capacity(edges),
            edge_data: Vec::with_capacity(edges),
            bfs_order: false,
        }
    }

    /// Opt into a **locality-preserving BFS relabel** at [`Self::build`]
    /// time: vertex ids are reassigned in breadth-first visit order
    /// (components in ascending seed order, neighbors in ascending id
    /// order — deterministic), so neighborhoods land on nearby ids. Because
    /// [`super::PartitionMap`] blocks (and therefore shard ownership, see
    /// [`super::ShardedGraph`]) are contiguous id ranges, a BFS order keeps
    /// most of a vertex's neighborhood in its own block and shrinks the
    /// edge cut / ghost count relative to an arbitrary insertion order.
    ///
    /// Ids handed out by [`Self::add_vertex`] refer to the *pre-relabel*
    /// order; use [`Self::build_with_mapping`] to recover `old -> new`.
    pub fn bfs_order(&mut self) -> &mut Self {
        self.bfs_order = true;
        self
    }

    pub fn num_vertices(&self) -> usize {
        self.vertex_data.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Add a vertex carrying `data`; returns its id.
    pub fn add_vertex(&mut self, data: V) -> VertexId {
        self.vertex_data.push(data);
        (self.vertex_data.len() - 1) as VertexId
    }

    /// Add the directed edge `src -> dst` carrying `data`; returns its id.
    /// Panics on self-loops (the GraphLab scope model excludes them) and on
    /// out-of-range endpoints.
    pub fn add_edge(&mut self, src: VertexId, dst: VertexId, data: E) -> EdgeId {
        assert!(src != dst, "self-loops are not supported (scope semantics)");
        assert!(
            (src as usize) < self.vertex_data.len() && (dst as usize) < self.vertex_data.len(),
            "edge endpoint out of range: {src}->{dst} with {} vertices",
            self.vertex_data.len()
        );
        self.edges.push(Edge { src, dst });
        self.edge_data.push(data);
        (self.edges.len() - 1) as EdgeId
    }

    /// Add both directions between `u` and `v`; returns `(u->v, v->u)` ids.
    pub fn add_undirected(&mut self, u: VertexId, v: VertexId, uv: E, vu: E) -> (EdgeId, EdgeId) {
        (self.add_edge(u, v, uv), self.add_edge(v, u, vu))
    }

    /// Freeze into CSR form (applying the BFS relabel if
    /// [`Self::bfs_order`] was requested).
    pub fn build(self) -> DataGraph<V, E> {
        self.build_with_mapping().0
    }

    /// Freeze into CSR form, also returning the `old id -> new id` map the
    /// (optional) BFS relabel applied — the identity permutation when
    /// [`Self::bfs_order`] is off.
    pub fn build_with_mapping(mut self) -> (DataGraph<V, E>, Vec<VertexId>) {
        let mapping = if self.bfs_order {
            self.apply_bfs_relabel()
        } else {
            (0..self.vertex_data.len() as VertexId).collect()
        };
        (self.freeze(), mapping)
    }

    /// Relabel vertex ids in deterministic BFS visit order (components in
    /// ascending seed order, neighbors ascending): permutes vertex data and
    /// rewrites edge endpoints in place. Edge ids and edge data are
    /// untouched. Returns `old -> new`.
    fn apply_bfs_relabel(&mut self) -> Vec<VertexId> {
        let n = self.vertex_data.len();
        let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); n];
        for e in &self.edges {
            adj[e.src as usize].push(e.dst);
            adj[e.dst as usize].push(e.src);
        }
        for row in adj.iter_mut() {
            row.sort_unstable();
            row.dedup();
        }
        let mut old_to_new = vec![VertexId::MAX; n];
        let mut order: Vec<VertexId> = Vec::with_capacity(n);
        let mut queue = std::collections::VecDeque::new();
        for seed in 0..n as VertexId {
            if old_to_new[seed as usize] != VertexId::MAX {
                continue;
            }
            old_to_new[seed as usize] = order.len() as VertexId;
            order.push(seed);
            queue.push_back(seed);
            while let Some(v) = queue.pop_front() {
                for &u in &adj[v as usize] {
                    if old_to_new[u as usize] == VertexId::MAX {
                        old_to_new[u as usize] = order.len() as VertexId;
                        order.push(u);
                        queue.push_back(u);
                    }
                }
            }
        }
        let mut data: Vec<Option<V>> = self.vertex_data.drain(..).map(Some).collect();
        self.vertex_data = order
            .iter()
            .map(|&old| data[old as usize].take().expect("each old id mapped once"))
            .collect();
        for e in self.edges.iter_mut() {
            e.src = old_to_new[e.src as usize];
            e.dst = old_to_new[e.dst as usize];
        }
        old_to_new
    }

    /// The CSR freeze itself (structure already in its final id order).
    fn freeze(self) -> DataGraph<V, E> {
        let n = self.vertex_data.len();
        let m = self.edges.len();

        // Counting sort edge ids into out- and in-rows.
        let mut out_counts = vec![0u32; n + 1];
        let mut in_counts = vec![0u32; n + 1];
        for e in &self.edges {
            out_counts[e.src as usize + 1] += 1;
            in_counts[e.dst as usize + 1] += 1;
        }
        for i in 0..n {
            out_counts[i + 1] += out_counts[i];
            in_counts[i + 1] += in_counts[i];
        }
        let out_offsets = out_counts.clone();
        let in_offsets = in_counts.clone();
        let mut out_items = vec![0u32; m];
        let mut in_items = vec![0u32; m];
        let mut out_cursor = out_offsets.clone();
        let mut in_cursor = in_offsets.clone();
        for (id, e) in self.edges.iter().enumerate() {
            let oc = &mut out_cursor[e.src as usize];
            out_items[*oc as usize] = id as u32;
            *oc += 1;
            let ic = &mut in_cursor[e.dst as usize];
            in_items[*ic as usize] = id as u32;
            *ic += 1;
        }

        // Sort each out-row by destination (for find_edge binary search) and
        // each in-row by source (deterministic iteration order).
        for v in 0..n {
            let (s, t) = (out_offsets[v] as usize, out_offsets[v + 1] as usize);
            out_items[s..t].sort_unstable_by_key(|&e| self.edges[e as usize].dst);
            let (s, t) = (in_offsets[v] as usize, in_offsets[v + 1] as usize);
            in_items[s..t].sort_unstable_by_key(|&e| self.edges[e as usize].src);
        }

        let out_adj = Csr { offsets: out_offsets, items: out_items };
        let in_adj = Csr { offsets: in_offsets, items: in_items };

        // Scope adjacency: sorted unique neighbor ids.
        let mut scope_offsets = vec![0u32; n + 1];
        let mut scope_items = Vec::with_capacity(m);
        let mut max_degree = 0usize;
        for v in 0..n {
            let mut nbrs: Vec<u32> = out_adj
                .row(v)
                .iter()
                .map(|&e| self.edges[e as usize].dst)
                .chain(in_adj.row(v).iter().map(|&e| self.edges[e as usize].src))
                .collect();
            nbrs.sort_unstable();
            nbrs.dedup();
            max_degree = max_degree.max(nbrs.len());
            scope_items.extend_from_slice(&nbrs);
            scope_offsets[v + 1] = scope_items.len() as u32;
        }
        let scope_adj = Csr { offsets: scope_offsets, items: scope_items };

        // Lock adjacency: the same neighbor sets, reordered by descending
        // degree (ties by id) so try-lock acquisitions test the most
        // contended word first and fail fast on conflict.
        let degree = |u: u32| {
            scope_adj.offsets[u as usize + 1] - scope_adj.offsets[u as usize]
        };
        let mut lock_items = scope_adj.items.clone();
        for v in 0..n {
            let (s, t) =
                (scope_adj.offsets[v] as usize, scope_adj.offsets[v + 1] as usize);
            lock_items[s..t].sort_unstable_by_key(|&u| (std::cmp::Reverse(degree(u)), u));
        }
        let lock_adj = Csr { offsets: scope_adj.offsets.clone(), items: lock_items };

        // Reverse-edge table via lookup in the sorted out-rows.
        let find = |u: u32, v: u32| -> Option<u32> {
            let row =
                &out_adj.items[out_adj.offsets[u as usize] as usize..out_adj.offsets[u as usize + 1] as usize];
            row.binary_search_by_key(&v, |&e| self.edges[e as usize].dst).ok().map(|i| row[i])
        };
        let reverse: Vec<Option<EdgeId>> =
            self.edges.iter().map(|e| find(e.dst, e.src)).collect();

        DataGraph {
            vertex_data: self.vertex_data.into_iter().map(DataCell::new).collect(),
            edge_data: self.edge_data.into_iter().map(DataCell::new).collect(),
            edges: self.edges,
            out_adj,
            in_adj,
            scope_adj,
            lock_adj,
            reverse,
            max_degree,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::prop_assert;

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        let mut b: GraphBuilder<(), ()> = GraphBuilder::new();
        let v = b.add_vertex(());
        b.add_edge(v, v, ());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_dangling_edge() {
        let mut b: GraphBuilder<(), ()> = GraphBuilder::new();
        let v = b.add_vertex(());
        b.add_edge(v, 5, ());
    }

    #[test]
    fn empty_graph() {
        let g: crate::graph::DataGraph<(), ()> = GraphBuilder::new().build();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn isolated_vertices() {
        let mut b: GraphBuilder<u8, ()> = GraphBuilder::new();
        for i in 0..5 {
            b.add_vertex(i);
        }
        let g = b.build();
        for v in 0..5 {
            assert!(g.neighbors(v).is_empty());
            assert!(g.out_edges(v).is_empty());
        }
        assert_eq!(g.max_degree(), 0);
    }

    #[test]
    fn bfs_relabel_permutes_data_and_preserves_structure() {
        // Path 0-2-4-1-3 inserted with scrambled ids; BFS from 0 visits the
        // path in order, so the relabel recovers a banded structure.
        let mut b: GraphBuilder<u32, ()> = GraphBuilder::new();
        for i in 0..5 {
            b.add_vertex(i * 10);
        }
        for (u, v) in [(0u32, 2u32), (2, 4), (4, 1), (1, 3)] {
            b.add_undirected(u, v, (), ());
        }
        b.bfs_order();
        let (mut g, map) = b.build_with_mapping();
        // old path order 0,2,4,1,3 becomes new ids 0,1,2,3,4
        assert_eq!(map, vec![0, 3, 1, 4, 2]);
        // data followed its vertex
        for old in 0..5u32 {
            assert_eq!(*g.vertex_data_ref(map[old as usize]), old * 10);
        }
        // structure is now a banded path: every edge spans adjacent ids
        for e in 0..g.num_edges() as u32 {
            let edge = g.edge(e);
            assert_eq!(
                edge.src.abs_diff(edge.dst),
                1,
                "BFS relabel must band the path: {edge:?}"
            );
        }
        assert_eq!(g.num_edges(), 8);
    }

    #[test]
    fn build_without_bfs_returns_identity_mapping() {
        let mut b: GraphBuilder<(), ()> = GraphBuilder::new();
        for _ in 0..4 {
            b.add_vertex(());
        }
        b.add_undirected(3, 0, (), ());
        let (g, map) = b.build_with_mapping();
        assert_eq!(map, vec![0, 1, 2, 3]);
        assert!(g.find_edge(3, 0).is_some());
    }

    #[test]
    fn bfs_relabel_covers_disconnected_components() {
        let mut b: GraphBuilder<u8, ()> = GraphBuilder::new();
        for i in 0..6 {
            b.add_vertex(i);
        }
        b.add_undirected(4, 5, (), ());
        b.add_undirected(1, 2, (), ());
        b.bfs_order();
        let (mut g, map) = b.build_with_mapping();
        // every old id mapped to a unique new id
        let mut seen = map.clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        for old in 0..6u8 {
            assert_eq!(*g.vertex_data_ref(map[old as usize]), old);
        }
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn prop_csr_roundtrips_random_graphs() {
        forall(60, |g| {
            let n = g.usize_in(1..40);
            let m = g.usize_in(0..120);
            let mut b: GraphBuilder<usize, (u32, u32)> = GraphBuilder::new();
            for i in 0..n {
                b.add_vertex(i);
            }
            let mut inserted = Vec::new();
            for _ in 0..m {
                let u = g.usize_in(0..n) as u32;
                let v = g.usize_in(0..n) as u32;
                if u != v {
                    b.add_edge(u, v, (u, v));
                    inserted.push((u, v));
                }
            }
            let graph = b.build();
            prop_assert!(graph.num_edges() == inserted.len());

            // Every inserted edge is findable and carries its endpoints as data.
            for &(u, v) in &inserted {
                let e = graph.find_edge(u, v);
                prop_assert!(e.is_some(), "edge {u}->{v} lost");
                let eid = e.unwrap();
                prop_assert!(graph.edge(eid) == super::Edge { src: u, dst: v });
            }

            // Scope adjacency is sorted, unique, self-free, and symmetric.
            for v in 0..n as u32 {
                let nbrs = graph.neighbors(v);
                prop_assert!(nbrs.windows(2).all(|w| w[0] < w[1]), "not sorted/unique");
                prop_assert!(!nbrs.contains(&v), "self in scope");
                for &u in nbrs {
                    prop_assert!(
                        graph.neighbors(u).contains(&v),
                        "scope asymmetry {u} vs {v}"
                    );
                }
            }

            // Lock adjacency is the same set, ordered degree-descending.
            for v in 0..n as u32 {
                let nbrs = graph.neighbors(v);
                let locks = graph.lock_neighbors(v);
                let mut sorted = locks.to_vec();
                sorted.sort_unstable();
                prop_assert!(sorted == nbrs, "lock set != scope set at {v}");
                prop_assert!(
                    locks.windows(2).all(|w| {
                        let (da, db) = (graph.degree(w[0]), graph.degree(w[1]));
                        da > db || (da == db && w[0] < w[1])
                    }),
                    "lock order not degree-descending at {v}"
                );
            }

            // in/out edge counts conserve the edge total.
            let out_total: usize =
                (0..n as u32).map(|v| graph.out_edges(v).len()).sum();
            let in_total: usize = (0..n as u32).map(|v| graph.in_edges(v).len()).sum();
            prop_assert!(out_total == inserted.len() && in_total == inserted.len());
            Ok(())
        });
    }
}
