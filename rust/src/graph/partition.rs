//! Contiguous block partitioning of vertex ids — the ownership map behind
//! **owner-worker affinity** (paper §3.4's partitioned/vertex-affine
//! schedulers; Distributed GraphLab, Low et al. 2012, §graph partitioning).
//!
//! Vertex ids are split into `num_parts` contiguous blocks: part `p` owns
//! `[p * block, (p + 1) * block)`. Contiguity is the point — CSR adjacency
//! and vertex-data arrays are id-ordered, so routing a vertex's tasks to
//! its owning worker keeps that block of vertex data (and most of its
//! neighborhood, for locality-preserving id orders) resident in one core's
//! cache instead of bouncing between all of them, unlike the `v % workers`
//! striping this replaces.

use super::VertexId;

/// A contiguous block partition of `0..len` into `num_parts` parts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionMap {
    num_parts: usize,
    block: usize,
    len: usize,
}

impl PartitionMap {
    /// Partition `num_items` ids into `num_parts` contiguous blocks of
    /// `ceil(num_items / num_parts)` ids each (the last block may be
    /// short). `num_parts` is clamped to at least 1.
    pub fn new(num_items: usize, num_parts: usize) -> PartitionMap {
        let parts = num_parts.max(1);
        let block = num_items.div_ceil(parts).max(1);
        PartitionMap { num_parts: parts, block, len: num_items }
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    /// Items per block (the last block may hold fewer).
    pub fn block_size(&self) -> usize {
        self.block
    }

    /// Total number of items partitioned.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The part owning item `v`. Ids at or beyond `len` clamp into the
    /// last part, so the map is total over `u32`.
    #[inline]
    pub fn owner_of(&self, v: VertexId) -> usize {
        (v as usize / self.block).min(self.num_parts - 1)
    }

    /// The id range owned by part `p` (empty for parts past the last
    /// populated block).
    pub fn range(&self, p: usize) -> std::ops::Range<VertexId> {
        let start = (p * self.block).min(self.len);
        let end = ((p + 1) * self.block).min(self.len);
        start as VertexId..end as VertexId
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_are_contiguous_and_cover() {
        let pm = PartitionMap::new(64, 4);
        assert_eq!(pm.num_parts(), 4);
        assert_eq!(pm.block_size(), 16);
        for p in 0..4 {
            let r = pm.range(p);
            assert_eq!(r.len(), 16);
            for v in r {
                assert_eq!(pm.owner_of(v), p);
            }
        }
        // ranges tile the id space exactly
        let total: usize = (0..4).map(|p| pm.range(p).len()).sum();
        assert_eq!(total, pm.len());
    }

    #[test]
    fn uneven_split_puts_remainder_last() {
        let pm = PartitionMap::new(10, 4);
        assert_eq!(pm.block_size(), 3);
        assert_eq!(pm.range(0), 0..3);
        assert_eq!(pm.range(3), 9..10, "last block holds the remainder");
        assert_eq!(pm.owner_of(9), 3);
    }

    #[test]
    fn more_parts_than_items() {
        let pm = PartitionMap::new(2, 8);
        assert_eq!(pm.owner_of(0), 0);
        assert_eq!(pm.owner_of(1), 1);
        for p in 2..8 {
            assert!(pm.range(p).is_empty());
        }
    }

    #[test]
    fn degenerate_sizes() {
        let pm = PartitionMap::new(0, 3);
        assert!(pm.is_empty());
        assert!(pm.range(0).is_empty());
        let pm = PartitionMap::new(5, 0);
        assert_eq!(pm.num_parts(), 1, "parts clamp to 1");
        assert_eq!(pm.owner_of(4), 0);
        // out-of-range ids clamp into the last part
        let pm = PartitionMap::new(8, 2);
        assert_eq!(pm.owner_of(1000), 1);
    }
}
