//! The GraphLab **scheduler collection** (paper §3.4).
//!
//! The scheduler abstractly represents a dynamic list of **tasks**
//! (vertex–function pairs) to be executed by the engine. The paper's
//! taxonomy, all implemented here:
//!
//! | | Strict order | Relaxed order |
//! |-------------|----------------|---------------------------|
//! | FIFO | [`FifoScheduler`] | [`MultiQueueFifo`] / [`PartitionedScheduler`] |
//! | Prioritized | [`PriorityScheduler`] | [`ApproxPriorityScheduler`] |
//!
//! plus the sweep schedulers — [`SynchronousScheduler`] (Jacobi) and
//! [`RoundRobinScheduler`] (Gauss–Seidel) — the [`SplashScheduler`]
//! (Gonzalez et al. 2009a), and the **set scheduler** (§3.4.1) with its
//! execution-plan DAG compilation ([`set_scheduler`]).
//!
//! The relaxed schedulers share one **lock-free task-distribution layer**
//! ([`deque`]): per-worker [`Injector`] segment queues with owner-affine
//! routing ([`crate::graph::PartitionMap`] contiguous blocks), and the
//! Chase–Lev [`WorkStealingDeque`] the threaded engine uses for its retry
//! path. Only the *strict* variants ([`FifoScheduler`],
//! [`PriorityScheduler`], the splash root heap) still serialize through a
//! mutex — exact global order is what the mutex buys.

pub mod deque;
mod fifo;
mod priority;
pub mod set_scheduler;
mod splash;
mod sweep;

pub use deque::{Injector, PackWords, WorkStealingDeque};
pub use fifo::{FifoScheduler, MultiQueueFifo, PartitionedScheduler};
pub use priority::{ApproxPriorityScheduler, PriorityScheduler};
pub use set_scheduler::{ExecutionPlan, SetScheduler};
pub use splash::SplashScheduler;
pub use sweep::{RoundRobinScheduler, SynchronousScheduler};

use crate::graph::VertexId;
use std::sync::atomic::{AtomicBool, Ordering};

/// Index into the engine's registered update-function table.
pub type FuncId = u32;

/// A schedulable unit of work: apply update function `func` to `vertex`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    pub vertex: VertexId,
    pub func: FuncId,
    /// Only meaningful to prioritized / splash schedulers.
    pub priority: f64,
}

impl Task {
    pub fn new(vertex: VertexId) -> Task {
        Task { vertex, func: 0, priority: 0.0 }
    }
    pub fn with_priority(vertex: VertexId, priority: f64) -> Task {
        Task { vertex, func: 0, priority }
    }
    pub fn with_func(vertex: VertexId, func: FuncId, priority: f64) -> Task {
        Task { vertex, func, priority }
    }
}

/// The scheduler interface consumed by the engines.
///
/// Contract: `add_task` may be called concurrently from update functions;
/// `next_task(worker)` returns `None` when nothing is *currently* available.
/// The engine terminates when every worker sees `None`, no task is in
/// flight, and `is_done()` holds.
///
/// **Retry-aware pop contract (non-blocking engines):** a popped task is
/// not necessarily executed immediately — on scope conflict the threaded
/// engine *defers* it to a retry deque and re-dispatches it later (possibly
/// from a different worker). The task stays "in flight" for the whole
/// interval, and `task_done` is called exactly once, when the update
/// finally runs. Barrier/DAG schedulers therefore must gate only on
/// `task_done`, never on pop order; and because a pending mark is cleared
/// at pop time, a deferred task's (vertex, func) may be legitimately
/// re-added to the queue while the deferred copy waits — schedulers must
/// tolerate that duplicate exactly as they tolerate an execute-then-re-add.
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Insert (or re-prioritize) a task. Schedulers de-duplicate per
    /// (vertex, func) — re-adding a pending task is cheap and, for
    /// prioritized schedulers, raises its priority (residual scheduling).
    fn add_task(&self, t: Task);

    /// Pop the next runnable task for `worker`, or `None` if none available
    /// right now.
    fn next_task(&self, worker: usize) -> Option<Task>;

    /// Completion callback (used by barrier/DAG schedulers).
    fn task_done(&self, _t: Task, _worker: usize) {}

    /// `true` once the scheduler can never produce another task without a
    /// new external `add_task` (for queue schedulers: queue empty).
    fn is_done(&self) -> bool;

    /// Approximate number of pending tasks (monitoring only).
    fn approx_len(&self) -> usize;

    /// The worker whose queue owns `v` under this scheduler's routing, for
    /// **owner-affine** schedulers (tasks are delivered to the owning
    /// worker's shard). `None` means the scheduler has no affinity concept;
    /// engines use this to count owner-affinity hits without guessing at
    /// the scheduler's internal partition.
    fn owner_of(&self, _v: VertexId) -> Option<usize> {
        None
    }
}

/// Default per-vertex update-function slots for schedulers constructed
/// without an explicit `num_funcs` (the FIFO family's `new`). Out-of-range
/// `FuncId`s are rejected by [`PendingFlags`] instead of silently aliasing
/// another vertex's flag.
pub(crate) const DEFAULT_FUNC_SLOTS: usize = 4;

/// Per-(vertex, func) pending flags providing task de-duplication.
/// `try_mark(v, f)` returns true exactly once until `unmark(v, f)`.
pub struct PendingFlags {
    flags: Vec<AtomicBool>,
    num_funcs: usize,
}

impl PendingFlags {
    pub fn new(num_vertices: usize, num_funcs: usize) -> PendingFlags {
        assert!(num_funcs >= 1);
        PendingFlags {
            flags: (0..num_vertices * num_funcs).map(|_| AtomicBool::new(false)).collect(),
            num_funcs,
        }
    }

    #[inline]
    fn idx(&self, t: &Task) -> usize {
        // A func id beyond the configured slot count would alias another
        // vertex's flag (silent lost/duplicated tasks) — fail loudly instead.
        assert!(
            (t.func as usize) < self.num_funcs,
            "FuncId {} out of range: scheduler was built for {} update function(s) \
             (use the with_funcs constructor)",
            t.func,
            self.num_funcs
        );
        t.vertex as usize * self.num_funcs + t.func as usize
    }

    /// Attempt to mark `t` pending; true if it was not already pending.
    #[inline]
    pub fn try_mark(&self, t: &Task) -> bool {
        !self.flags[self.idx(t)].swap(true, Ordering::AcqRel)
    }

    /// Clear the pending mark (called when the task is popped).
    #[inline]
    pub fn unmark(&self, t: &Task) {
        self.flags[self.idx(t)].store(false, Ordering::Release);
    }

    #[inline]
    pub fn is_pending(&self, t: &Task) -> bool {
        self.flags[self.idx(t)].load(Ordering::Acquire)
    }
}

/// Default splash spanning-tree size for [`by_name_for_graph`]
/// ("paper-typical: tens of vertices").
pub const DEFAULT_SPLASH_SIZE: usize = 32;

/// Parse a scheduler name from the CLI; `n` = number of vertices,
/// `workers` = worker count (for sharded schedulers). Covers every
/// scheduler constructible from sizes alone — the splash scheduler also
/// needs graph adjacency, so it lives in [`by_name_for_graph`].
///
/// `"priority"` resolves to the sharded-bucket [`ApproxPriorityScheduler`]
/// (the scalable default); the serial global heap stays reachable as
/// `"priority-strict"`.
pub fn by_name(name: &str, n: usize, workers: usize) -> Option<Box<dyn Scheduler>> {
    Some(match name {
        "fifo" => Box::new(FifoScheduler::new(n)),
        "multiqueue" => Box::new(MultiQueueFifo::new(n, workers)),
        "partitioned" => Box::new(PartitionedScheduler::new(n, workers)),
        "priority" | "approx-priority" => {
            Box::new(ApproxPriorityScheduler::new(n, workers))
        }
        "priority-strict" => Box::new(PriorityScheduler::new(n)),
        "round-robin" => Box::new(RoundRobinScheduler::new(n, 1)),
        "synchronous" => Box::new(SynchronousScheduler::new(n, 1)),
        _ => return None,
    })
}

/// Graph-aware scheduler registry: everything [`by_name`] constructs, plus
/// the schedulers that need the graph's adjacency structure — currently
/// `"splash"` (with [`DEFAULT_SPLASH_SIZE`]). The splash scheduler copies
/// the adjacency at construction, so the returned box does not borrow the
/// graph. (The set scheduler is excluded: it needs an execution plan, not
/// just a graph — see [`set_scheduler`].)
pub fn by_name_for_graph<V, E>(
    name: &str,
    graph: &crate::graph::DataGraph<V, E>,
    workers: usize,
) -> Option<Box<dyn Scheduler>> {
    let n = graph.num_vertices();
    match name {
        "splash" => Some(Box::new(SplashScheduler::new(
            n,
            |v| graph.neighbors(v),
            DEFAULT_SPLASH_SIZE,
            workers,
        ))),
        _ => by_name(name, n, workers),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pending_flags_dedup() {
        let p = PendingFlags::new(4, 2);
        let t = Task::with_func(2, 1, 0.0);
        assert!(p.try_mark(&t));
        assert!(!p.try_mark(&t), "second mark must fail");
        assert!(p.is_pending(&t));
        // distinct func on same vertex is independent
        assert!(p.try_mark(&Task::with_func(2, 0, 0.0)));
        p.unmark(&t);
        assert!(p.try_mark(&t));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pending_flags_reject_out_of_range_func() {
        let p = PendingFlags::new(4, 2);
        p.try_mark(&Task::with_func(0, 2, 0.0));
    }

    #[test]
    fn by_name_covers_cli_schedulers() {
        for name in [
            "fifo",
            "multiqueue",
            "partitioned",
            "approx-priority",
            "priority-strict",
            "round-robin",
            "synchronous",
        ] {
            let s = by_name(name, 10, 2).unwrap_or_else(|| panic!("missing {name}"));
            // registry aliases resolve to their canonical scheduler name
            let want = if name == "priority-strict" { "priority" } else { name };
            assert_eq!(s.name(), want);
        }
        assert!(by_name("bogus", 10, 2).is_none());

        // `priority` defaults to the scalable sharded-bucket variant, not
        // the serial global heap.
        let s = by_name("priority", 10, 2).unwrap();
        assert_eq!(s.name(), "approx-priority");
        let s = by_name("priority-strict", 10, 2).unwrap();
        assert_eq!(s.name(), "priority");

        // The graph-aware registry covers everything above plus splash
        // (which the module table advertises but by_name cannot build).
        let mut b: crate::graph::GraphBuilder<(), ()> = crate::graph::GraphBuilder::new();
        for _ in 0..10 {
            b.add_vertex(());
        }
        for i in 0..9u32 {
            b.add_undirected(i, i + 1, (), ());
        }
        let g = b.build();
        for name in [
            "fifo",
            "multiqueue",
            "partitioned",
            "priority",
            "priority-strict",
            "approx-priority",
            "round-robin",
            "synchronous",
            "splash",
        ] {
            assert!(
                by_name_for_graph(name, &g, 2).is_some(),
                "missing {name} in graph-aware registry"
            );
        }
        assert_eq!(by_name_for_graph("priority", &g, 2).unwrap().name(), "approx-priority");
        assert!(by_name_for_graph("bogus", &g, 2).is_none());

        // splash from the registry must actually schedule
        let s = by_name_for_graph("splash", &g, 2).unwrap();
        s.add_task(Task::with_priority(4, 1.0));
        assert!(s.next_task(0).is_some());
    }
}
