//! The GraphLab **scheduler collection** (paper §3.4).
//!
//! The scheduler abstractly represents a dynamic list of **tasks**
//! (vertex–function pairs) to be executed by the engine. The paper's
//! taxonomy, all implemented here:
//!
//! | | Strict order | Relaxed order |
//! |-------------|----------------|---------------------------|
//! | FIFO | [`FifoScheduler`] | [`MultiQueueFifo`] / [`PartitionedScheduler`] |
//! | Prioritized | [`PriorityScheduler`] | [`ApproxPriorityScheduler`] |
//!
//! plus the sweep schedulers — [`SynchronousScheduler`] (Jacobi) and
//! [`RoundRobinScheduler`] (Gauss–Seidel) — the [`SplashScheduler`]
//! (Gonzalez et al. 2009a), and the **set scheduler** (§3.4.1) with its
//! execution-plan DAG compilation ([`set_scheduler`]).

mod fifo;
mod priority;
pub mod set_scheduler;
mod splash;
mod sweep;

pub use fifo::{FifoScheduler, MultiQueueFifo, PartitionedScheduler};
pub use priority::{ApproxPriorityScheduler, PriorityScheduler};
pub use set_scheduler::{ExecutionPlan, SetScheduler};
pub use splash::SplashScheduler;
pub use sweep::{RoundRobinScheduler, SynchronousScheduler};

use crate::graph::VertexId;
use std::sync::atomic::{AtomicBool, Ordering};

/// Index into the engine's registered update-function table.
pub type FuncId = u32;

/// A schedulable unit of work: apply update function `func` to `vertex`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    pub vertex: VertexId,
    pub func: FuncId,
    /// Only meaningful to prioritized / splash schedulers.
    pub priority: f64,
}

impl Task {
    pub fn new(vertex: VertexId) -> Task {
        Task { vertex, func: 0, priority: 0.0 }
    }
    pub fn with_priority(vertex: VertexId, priority: f64) -> Task {
        Task { vertex, func: 0, priority }
    }
    pub fn with_func(vertex: VertexId, func: FuncId, priority: f64) -> Task {
        Task { vertex, func, priority }
    }
}

/// The scheduler interface consumed by the engines.
///
/// Contract: `add_task` may be called concurrently from update functions;
/// `next_task(worker)` returns `None` when nothing is *currently* available.
/// The engine terminates when every worker sees `None`, no task is in
/// flight, and `is_done()` holds.
pub trait Scheduler: Send + Sync {
    fn name(&self) -> &'static str;

    /// Insert (or re-prioritize) a task. Schedulers de-duplicate per
    /// (vertex, func) — re-adding a pending task is cheap and, for
    /// prioritized schedulers, raises its priority (residual scheduling).
    fn add_task(&self, t: Task);

    /// Pop the next runnable task for `worker`, or `None` if none available
    /// right now.
    fn next_task(&self, worker: usize) -> Option<Task>;

    /// Completion callback (used by barrier/DAG schedulers).
    fn task_done(&self, _t: Task, _worker: usize) {}

    /// `true` once the scheduler can never produce another task without a
    /// new external `add_task` (for queue schedulers: queue empty).
    fn is_done(&self) -> bool;

    /// Approximate number of pending tasks (monitoring only).
    fn approx_len(&self) -> usize;
}

/// Per-(vertex, func) pending flags providing task de-duplication.
/// `try_mark(v, f)` returns true exactly once until `unmark(v, f)`.
pub struct PendingFlags {
    flags: Vec<AtomicBool>,
    num_funcs: usize,
}

impl PendingFlags {
    pub fn new(num_vertices: usize, num_funcs: usize) -> PendingFlags {
        assert!(num_funcs >= 1);
        PendingFlags {
            flags: (0..num_vertices * num_funcs).map(|_| AtomicBool::new(false)).collect(),
            num_funcs,
        }
    }

    #[inline]
    fn idx(&self, t: &Task) -> usize {
        t.vertex as usize * self.num_funcs + t.func as usize
    }

    /// Attempt to mark `t` pending; true if it was not already pending.
    #[inline]
    pub fn try_mark(&self, t: &Task) -> bool {
        !self.flags[self.idx(t)].swap(true, Ordering::AcqRel)
    }

    /// Clear the pending mark (called when the task is popped).
    #[inline]
    pub fn unmark(&self, t: &Task) {
        self.flags[self.idx(t)].store(false, Ordering::Release);
    }

    #[inline]
    pub fn is_pending(&self, t: &Task) -> bool {
        self.flags[self.idx(t)].load(Ordering::Acquire)
    }
}

/// Parse a scheduler name from the CLI; `n` = number of vertices,
/// `workers` = worker count (for sharded schedulers).
pub fn by_name(name: &str, n: usize, workers: usize) -> Option<Box<dyn Scheduler>> {
    Some(match name {
        "fifo" => Box::new(FifoScheduler::new(n)),
        "multiqueue" => Box::new(MultiQueueFifo::new(n, workers)),
        "partitioned" => Box::new(PartitionedScheduler::new(n, workers)),
        "priority" => Box::new(PriorityScheduler::new(n)),
        "approx-priority" => Box::new(ApproxPriorityScheduler::new(n, workers)),
        "round-robin" => Box::new(RoundRobinScheduler::new(n, 1)),
        "synchronous" => Box::new(SynchronousScheduler::new(n, 1)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pending_flags_dedup() {
        let p = PendingFlags::new(4, 2);
        let t = Task::with_func(2, 1, 0.0);
        assert!(p.try_mark(&t));
        assert!(!p.try_mark(&t), "second mark must fail");
        assert!(p.is_pending(&t));
        // distinct func on same vertex is independent
        assert!(p.try_mark(&Task::with_func(2, 0, 0.0)));
        p.unmark(&t);
        assert!(p.try_mark(&t));
    }

    #[test]
    fn by_name_covers_cli_schedulers() {
        for name in [
            "fifo",
            "multiqueue",
            "partitioned",
            "priority",
            "approx-priority",
            "round-robin",
            "synchronous",
        ] {
            let s = by_name(name, 10, 2).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(s.name(), name);
        }
        assert!(by_name("bogus", 10, 2).is_none());
    }
}
