//! FIFO-class task schedulers (paper §3.4): the strict single-queue FIFO and
//! its relaxed variants — the sharded multi-queue (owner-affine insertion +
//! work stealing) and the partitioned scheduler (strict vertex affinity) —
//! which trade ordering strictness for reduced contention.
//!
//! The relaxed variants are built on the lock-free [`Injector`] segment
//! queue (one per worker) with tasks routed to the shard that *owns* the
//! vertex ([`PartitionMap`], contiguous id blocks), so repeated updates of
//! a vertex keep landing on the worker whose cache already holds its scope
//! data. Only the strict FIFO still serializes through a mutex — strict
//! global ordering is exactly what a single queue buys.

use super::{Injector, PendingFlags, Scheduler, Task, DEFAULT_FUNC_SLOTS};
use crate::graph::PartitionMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Ring capacity hint per shard: enough for a full seed of the shard's
/// vertices without touching the overflow list, bounded so huge graphs
/// don't balloon the ring allocation.
fn shard_capacity(num_vertices: usize, shards: usize) -> usize {
    (num_vertices / shards.max(1)).clamp(256, 1 << 15)
}

/// Strict single-queue FIFO. Tasks are de-duplicated per (vertex, func):
/// re-adding a pending task is a no-op. This is also the `Mutex<VecDeque>`
/// baseline the lock-free schedulers are benchmarked against
/// (`results/BENCH_sched.json`).
pub struct FifoScheduler {
    queue: Mutex<VecDeque<Task>>,
    pending: PendingFlags,
    len: AtomicUsize,
}

impl FifoScheduler {
    /// `new` reserves [`DEFAULT_FUNC_SLOTS`] function slots per vertex;
    /// programs with more update functions must use [`Self::with_funcs`]
    /// (an out-of-range `FuncId` panics instead of aliasing).
    pub fn new(num_vertices: usize) -> FifoScheduler {
        Self::with_funcs(num_vertices, DEFAULT_FUNC_SLOTS)
    }

    pub fn with_funcs(num_vertices: usize, num_funcs: usize) -> FifoScheduler {
        FifoScheduler {
            queue: Mutex::new(VecDeque::new()),
            pending: PendingFlags::new(num_vertices, num_funcs),
            len: AtomicUsize::new(0),
        }
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn add_task(&self, t: Task) {
        if self.pending.try_mark(&t) {
            self.queue.lock().unwrap().push_back(t);
            self.len.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn next_task(&self, _worker: usize) -> Option<Task> {
        let t = self.queue.lock().unwrap().pop_front();
        if let Some(ref task) = t {
            self.pending.unmark(task);
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        t
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

/// Relaxed-order FIFO over one lock-free [`Injector`] shard per worker.
/// Insertions are **owner-affine**: a task lands on the shard of the worker
/// that owns its vertex (contiguous [`PartitionMap`] blocks); a worker pops
/// its own shard first and steals from its peers' shards in ring order when
/// it runs dry. This is the scheduler CoEM scales with (Fig 6a/b).
pub struct MultiQueueFifo {
    shards: Vec<Injector<Task>>,
    part: PartitionMap,
    pending: PendingFlags,
    len: AtomicUsize,
}

impl MultiQueueFifo {
    /// See [`FifoScheduler::new`] for the function-slot convention.
    pub fn new(num_vertices: usize, workers: usize) -> MultiQueueFifo {
        Self::with_funcs(num_vertices, workers, DEFAULT_FUNC_SLOTS)
    }

    pub fn with_funcs(
        num_vertices: usize,
        workers: usize,
        num_funcs: usize,
    ) -> MultiQueueFifo {
        let nshards = workers.max(1);
        let cap = shard_capacity(num_vertices, nshards);
        MultiQueueFifo {
            shards: (0..nshards).map(|_| Injector::new(cap)).collect(),
            part: PartitionMap::new(num_vertices, nshards),
            pending: PendingFlags::new(num_vertices, num_funcs),
            len: AtomicUsize::new(0),
        }
    }
}

impl Scheduler for MultiQueueFifo {
    fn name(&self) -> &'static str {
        "multiqueue"
    }

    fn owner_of(&self, v: u32) -> Option<usize> {
        Some(self.part.owner_of(v))
    }

    fn add_task(&self, t: Task) {
        if self.pending.try_mark(&t) {
            self.shards[self.part.owner_of(t.vertex)].push(t);
            self.len.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn next_task(&self, worker: usize) -> Option<Task> {
        let n = self.shards.len();
        // own shard first, then steal in ring order
        let home = worker % n;
        for i in 0..n {
            let shard = (home + i) % n;
            if let Some(t) = self.shards[shard].pop() {
                self.pending.unmark(&t);
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

/// Partitioned FIFO: vertex `v` is owned by the worker whose contiguous
/// block contains it ([`PartitionMap`]); worker `w` only executes its own
/// partition (no stealing). Lowest contention and best locality, at the
/// cost of load imbalance on skewed graphs.
pub struct PartitionedScheduler {
    parts: Vec<Injector<Task>>,
    part: PartitionMap,
    pending: PendingFlags,
    len: AtomicUsize,
}

impl PartitionedScheduler {
    /// See [`FifoScheduler::new`] for the function-slot convention.
    pub fn new(num_vertices: usize, workers: usize) -> PartitionedScheduler {
        Self::with_funcs(num_vertices, workers, DEFAULT_FUNC_SLOTS)
    }

    pub fn with_funcs(
        num_vertices: usize,
        workers: usize,
        num_funcs: usize,
    ) -> PartitionedScheduler {
        let nparts = workers.max(1);
        let cap = shard_capacity(num_vertices, nparts);
        PartitionedScheduler {
            parts: (0..nparts).map(|_| Injector::new(cap)).collect(),
            part: PartitionMap::new(num_vertices, nparts),
            pending: PendingFlags::new(num_vertices, num_funcs),
            len: AtomicUsize::new(0),
        }
    }
}

impl Scheduler for PartitionedScheduler {
    fn name(&self) -> &'static str {
        "partitioned"
    }

    fn owner_of(&self, v: u32) -> Option<usize> {
        Some(self.part.owner_of(v))
    }

    fn add_task(&self, t: Task) {
        if self.pending.try_mark(&t) {
            self.parts[self.part.owner_of(t.vertex)].push(t);
            self.len.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn next_task(&self, worker: usize) -> Option<Task> {
        let p = worker % self.parts.len();
        let t = self.parts[p].pop();
        if let Some(ref task) = t {
            self.pending.unmark(task);
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        t
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn fifo_preserves_order_and_dedups() {
        let s = FifoScheduler::new(10);
        s.add_task(Task::new(3));
        s.add_task(Task::new(1));
        s.add_task(Task::new(3)); // duplicate — dropped
        assert_eq!(s.approx_len(), 2);
        assert_eq!(s.next_task(0).unwrap().vertex, 3);
        // after pop, re-adding is allowed
        s.add_task(Task::new(3));
        assert_eq!(s.next_task(0).unwrap().vertex, 1);
        assert_eq!(s.next_task(0).unwrap().vertex, 3);
        assert!(s.next_task(0).is_none());
        assert!(s.is_done());
    }

    #[test]
    fn with_funcs_keeps_funcs_independent() {
        let s = FifoScheduler::with_funcs(10, 2);
        s.add_task(Task::with_func(3, 0, 0.0));
        s.add_task(Task::with_func(3, 1, 0.0)); // distinct func: no dedup
        s.add_task(Task::with_func(3, 1, 0.5)); // duplicate — dropped
        assert_eq!(s.approx_len(), 2);
        let s = MultiQueueFifo::with_funcs(10, 2, 2);
        s.add_task(Task::with_func(3, 1, 0.0));
        assert_eq!(s.approx_len(), 1);
        let s = PartitionedScheduler::with_funcs(10, 2, 2);
        s.add_task(Task::with_func(3, 1, 0.0));
        assert_eq!(s.approx_len(), 1);
    }

    #[test]
    fn multiqueue_delivers_everything() {
        let s = MultiQueueFifo::new(100, 4);
        for v in 0..100 {
            s.add_task(Task::new(v));
        }
        let mut seen = HashSet::new();
        for w in 0..4 {
            while let Some(t) = s.next_task(w) {
                assert!(seen.insert(t.vertex));
                if seen.len() % 7 == 0 {
                    break; // rotate workers
                }
            }
        }
        // drain remainder
        while let Some(t) = s.next_task(0) {
            assert!(seen.insert(t.vertex));
        }
        assert_eq!(seen.len(), 100);
        assert!(s.is_done());
    }

    #[test]
    fn multiqueue_routes_to_owner_shard() {
        let s = MultiQueueFifo::new(64, 4);
        for v in 0..64 {
            s.add_task(Task::new(v));
        }
        // a worker popping only its own turn sees only vertices it owns
        // (until shards drain and stealing kicks in)
        let t = s.next_task(2).unwrap();
        assert_eq!(s.owner_of(t.vertex), Some(2), "first pop comes from the home shard");
    }

    #[test]
    fn partitioned_respects_ownership() {
        let s = PartitionedScheduler::new(64, 4);
        for v in 0..64 {
            s.add_task(Task::new(v));
        }
        for w in 0..4 {
            while let Some(t) = s.next_task(w) {
                assert_eq!(
                    s.owner_of(t.vertex),
                    Some(w),
                    "vertex {} served to non-owner worker {w}",
                    t.vertex
                );
            }
        }
        assert!(s.is_done());
    }

    #[test]
    fn partitioned_blocks_are_contiguous() {
        let s = PartitionedScheduler::new(64, 4);
        // contiguous blocks of 16, not `v % workers` stripes
        assert_eq!(s.owner_of(0), Some(0));
        assert_eq!(s.owner_of(15), Some(0));
        assert_eq!(s.owner_of(16), Some(1));
        assert_eq!(s.owner_of(63), Some(3));
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let s = Arc::new(MultiQueueFifo::new(4000, 4));
        let counted = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for w in 0..2 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for v in 0..2000u32 {
                    s.add_task(Task::new(w as u32 * 2000 + v));
                }
            }));
        }
        for w in 0..2 {
            let s = Arc::clone(&s);
            let counted = Arc::clone(&counted);
            handles.push(std::thread::spawn(move || {
                let mut idle = 0;
                while idle < 1000 {
                    match s.next_task(w) {
                        Some(_) => {
                            counted.fetch_add(1, Ordering::Relaxed);
                            idle = 0;
                        }
                        None => {
                            idle += 1;
                            std::thread::yield_now();
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counted.load(Ordering::Relaxed), 4000);
    }
}
