//! Lock-free task-distribution primitives shared by the scheduler
//! collection and the threaded engine's retry layer.
//!
//! Two building blocks, both `std`-only (PR 1 dropped crossbeam and this
//! module keeps that decision):
//!
//! * [`WorkStealingDeque`] — a fixed-capacity **Chase–Lev work-stealing
//!   deque** (Chase & Lev 2005, memory orderings per Lê et al. 2013). The
//!   owning worker pushes and pops at the *bottom* (LIFO — the hot end,
//!   cache-warm), thieves steal from the *top* (FIFO — the cold end).
//!   `push` never blocks: a full deque returns the task so the caller can
//!   spill it to an [`Injector`].
//! * [`Injector`] — a **multi-producer multi-consumer segment queue**: a
//!   bounded MPMC ring (Vyukov's algorithm, per-slot sequence numbers) with
//!   a mutex-protected overflow list that is only touched when the ring
//!   fills — the hot path is entirely lock-free. Overflowed items are
//!   preferred by `pop` so a burst can never strand tasks behind a busy
//!   ring.
//!
//! Elements are stored as two 64-bit words in atomic slots (the
//! [`PackWords`] trait), which is what makes the racy-read windows of both
//! algorithms well-defined: a reader that loses the claim CAS may observe a
//! torn pair, but the value is discarded — the protocol guarantees a torn
//! pair is never *returned*. [`crate::scheduler::Task`] (vertex + func +
//! priority) packs exactly into two words.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicIsize, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// An element representable as two 64-bit words, so it can live in atomic
/// queue slots. `unpack(pack(x)) == x` must hold.
pub trait PackWords: Copy {
    fn pack(self) -> [u64; 2];
    fn unpack(words: [u64; 2]) -> Self;
}

impl PackWords for super::Task {
    #[inline]
    fn pack(self) -> [u64; 2] {
        [(self.vertex as u64) | ((self.func as u64) << 32), self.priority.to_bits()]
    }

    #[inline]
    fn unpack(words: [u64; 2]) -> Self {
        super::Task {
            vertex: (words[0] & 0xFFFF_FFFF) as u32,
            func: (words[0] >> 32) as u32,
            priority: f64::from_bits(words[1]),
        }
    }
}

impl PackWords for u32 {
    #[inline]
    fn pack(self) -> [u64; 2] {
        [self as u64, 0]
    }

    #[inline]
    fn unpack(words: [u64; 2]) -> Self {
        words[0] as u32
    }
}

/// Two atomic words of element storage.
#[derive(Default)]
struct Slot {
    w0: AtomicU64,
    w1: AtomicU64,
}

/// Fixed-capacity Chase–Lev work-stealing deque. See module docs.
///
/// Contract: [`Self::push`] and [`Self::pop`] may only be called by the
/// deque's *owning* thread; [`Self::steal`] may be called from any thread.
/// (The methods take `&self` so the deque can be shared across a scoped
/// thread pool; single-owner access to the bottom end is the caller's
/// responsibility, as with every Chase–Lev implementation.)
pub struct WorkStealingDeque<T> {
    /// Steal end (monotonically increasing).
    top: AtomicIsize,
    /// Owner end.
    bottom: AtomicIsize,
    slots: Box<[Slot]>,
    mask: isize,
    _marker: PhantomData<T>,
}

impl<T: PackWords> WorkStealingDeque<T> {
    /// `capacity` is rounded up to a power of two in `[8, 2^20]`.
    pub fn new(capacity: usize) -> WorkStealingDeque<T> {
        let cap = capacity.next_power_of_two().clamp(8, 1 << 20);
        WorkStealingDeque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            slots: (0..cap).map(|_| Slot::default()).collect::<Vec<_>>().into_boxed_slice(),
            mask: cap as isize - 1,
            _marker: PhantomData,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Approximate occupancy (racy by nature; exact when quiescent).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    fn write_slot(&self, idx: isize, value: T) {
        let slot = &self.slots[(idx & self.mask) as usize];
        let words = value.pack();
        slot.w0.store(words[0], Ordering::Relaxed);
        slot.w1.store(words[1], Ordering::Relaxed);
    }

    #[inline]
    fn read_slot(&self, idx: isize) -> T {
        let slot = &self.slots[(idx & self.mask) as usize];
        T::unpack([slot.w0.load(Ordering::Relaxed), slot.w1.load(Ordering::Relaxed)])
    }

    /// Owner-only: push at the bottom. Returns the value back when the
    /// deque is full (caller spills it to an [`Injector`]).
    pub fn push(&self, value: T) -> Result<(), T> {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t > self.mask {
            return Err(value); // full
        }
        self.write_slot(b, value);
        // Publish the element before the new bottom becomes visible to
        // thieves (their `bottom` Acquire load pairs with this Release).
        self.bottom.store(b + 1, Ordering::Release);
        Ok(())
    }

    /// Owner-only: pop at the bottom (LIFO). The last element races with
    /// concurrent thieves and is settled by a CAS on `top`.
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let mut value = Some(self.read_slot(b));
            if t == b {
                // Single element left: win it against thieves or lose it.
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_err()
                {
                    value = None;
                }
                self.bottom.store(b + 1, Ordering::Relaxed);
            }
            value
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Any thread: **steal-half** policy. Claims one element for the caller
    /// (returned) and up to `min(len/2, max_extra)` additional elements,
    /// each fed to `sink` (typically a push onto the thief's own deque, so
    /// one scan amortizes over several tasks instead of thieves returning
    /// for one task at a time — enable via
    /// [`crate::engine::EngineConfig::steal_half`] when steal counters
    /// dominate). Every claim is an individual CAS from the top, so
    /// exactly-once delivery is inherited from [`Self::steal`]; the batch
    /// is not atomic, which is fine — a partially drained victim is
    /// indistinguishable from a victim that had fewer tasks. Returns the
    /// first stolen element and the count handed to `sink`.
    pub fn steal_half(
        &self,
        max_extra: usize,
        mut sink: impl FnMut(T),
    ) -> (Option<T>, usize) {
        let Some(first) = self.steal() else {
            return (None, 0);
        };
        let extra = (self.len() / 2).min(max_extra);
        let mut moved = 0;
        for _ in 0..extra {
            match self.steal() {
                Some(t) => {
                    sink(t);
                    moved += 1;
                }
                None => break,
            }
        }
        (Some(first), moved)
    }

    /// Any thread: steal from the top (FIFO). Retries internally while it
    /// loses claim races; returns `None` only when the deque looks empty.
    pub fn steal(&self) -> Option<T> {
        loop {
            let t = self.top.load(Ordering::Acquire);
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            let value = self.read_slot(t);
            // Claim settles the race against the owner's pop of the last
            // element and against other thieves; a lost claim means the
            // (possibly torn) read above is discarded.
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(value);
            }
        }
    }
}

/// One ring slot: Vyukov sequence number + two words of element storage.
struct InjectorSlot {
    seq: AtomicUsize,
    w0: AtomicU64,
    w1: AtomicU64,
}

/// Multi-producer multi-consumer FIFO segment queue. See module docs.
///
/// Ordering is FIFO on the lock-free ring; items that overflow into the
/// (rarely touched) mutex list are drained *first* by `pop`, so spilled
/// tasks can never starve behind a continuously busy ring.
pub struct Injector<T> {
    slots: Box<[InjectorSlot]>,
    mask: usize,
    enqueue_pos: AtomicUsize,
    dequeue_pos: AtomicUsize,
    overflow: Mutex<VecDeque<T>>,
    overflow_len: AtomicUsize,
    _marker: PhantomData<T>,
}

impl<T: PackWords> Injector<T> {
    /// `capacity_hint` is rounded up to a power of two in `[64, 2^16]`;
    /// pushes beyond ring capacity spill to the overflow list, so the hint
    /// only sizes the lock-free fast path.
    pub fn new(capacity_hint: usize) -> Injector<T> {
        let cap = capacity_hint.next_power_of_two().clamp(64, 1 << 16);
        Injector {
            slots: (0..cap)
                .map(|i| InjectorSlot {
                    seq: AtomicUsize::new(i),
                    w0: AtomicU64::new(0),
                    w1: AtomicU64::new(0),
                })
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: AtomicUsize::new(0),
            dequeue_pos: AtomicUsize::new(0),
            overflow: Mutex::new(VecDeque::new()),
            overflow_len: AtomicUsize::new(0),
            _marker: PhantomData,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    fn spill(&self, value: T) {
        let depth = self.overflow_len.fetch_add(1, Ordering::AcqRel) + 1;
        crate::telemetry::instant(
            crate::telemetry::EventKind::InjectorOverflow,
            depth as u64,
            self.slots.len() as u64,
        );
        self.overflow.lock().unwrap().push_back(value);
    }

    /// Push (any thread). Never fails: a full ring spills to the overflow
    /// list. While the overflow is non-empty, new pushes also spill, which
    /// keeps the queue near-FIFO across a burst.
    pub fn push(&self, value: T) {
        if self.overflow_len.load(Ordering::Acquire) > 0 {
            self.spill(value);
            return;
        }
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let words = value.pack();
                        slot.w0.store(words[0], Ordering::Relaxed);
                        slot.w1.store(words[1], Ordering::Relaxed);
                        slot.seq.store(pos + 1, Ordering::Release);
                        return;
                    }
                    Err(seen) => pos = seen,
                }
            } else if dif < 0 {
                // Ring full (the slot is still occupied by the element one
                // lap behind): spill.
                self.spill(value);
                return;
            } else {
                // Another producer claimed this position; reload.
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop (any thread). `None` means nothing was available *right now*.
    pub fn pop(&self) -> Option<T> {
        if self.overflow_len.load(Ordering::Acquire) > 0 {
            let mut queue = self.overflow.lock().unwrap();
            if let Some(value) = queue.pop_front() {
                self.overflow_len.fetch_sub(1, Ordering::AcqRel);
                return Some(value);
            }
        }
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - (pos + 1) as isize;
            if dif == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let value = T::unpack([
                            slot.w0.load(Ordering::Relaxed),
                            slot.w1.load(Ordering::Relaxed),
                        ]);
                        // Release the slot for the next lap of producers.
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(value);
                    }
                    Err(seen) => pos = seen,
                }
            } else if dif < 0 {
                return None; // empty (or an in-flight push not yet published)
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Approximate occupancy (racy by nature; exact when quiescent).
    pub fn len(&self) -> usize {
        let e = self.enqueue_pos.load(Ordering::Relaxed);
        let d = self.dequeue_pos.load(Ordering::Relaxed);
        e.saturating_sub(d) + self.overflow_len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::super::Task;
    use super::*;
    use std::sync::atomic::AtomicU8;
    use std::sync::Arc;

    #[test]
    fn task_pack_roundtrip() {
        for t in [
            Task::new(0),
            Task::with_func(u32::MAX, 7, -3.5),
            Task::with_priority(42, f64::MAX),
            Task::with_func(1, u32::MAX, 0.0),
        ] {
            let back = Task::unpack(t.pack());
            assert_eq!(back.vertex, t.vertex);
            assert_eq!(back.func, t.func);
            assert_eq!(back.priority.to_bits(), t.priority.to_bits());
        }
        assert_eq!(u32::unpack(123u32.pack()), 123);
    }

    #[test]
    fn deque_owner_lifo_thief_fifo() {
        let d: WorkStealingDeque<Task> = WorkStealingDeque::new(8);
        for v in 0..4u32 {
            d.push(Task::new(v)).unwrap();
        }
        assert_eq!(d.len(), 4);
        // owner pops the hottest (most recently pushed) end
        assert_eq!(d.pop().unwrap().vertex, 3);
        // a thief steals the coldest end
        assert_eq!(d.steal().unwrap().vertex, 0);
        assert_eq!(d.steal().unwrap().vertex, 1);
        assert_eq!(d.pop().unwrap().vertex, 2);
        assert!(d.pop().is_none());
        assert!(d.steal().is_none());
        assert!(d.is_empty());
    }

    #[test]
    fn steal_half_takes_first_plus_half() {
        let d: WorkStealingDeque<Task> = WorkStealingDeque::new(16);
        for v in 0..9u32 {
            d.push(Task::new(v)).unwrap();
        }
        let mut batch = Vec::new();
        let (first, moved) = d.steal_half(32, |t| batch.push(t.vertex));
        // first element from the cold end, then half of the remaining 8
        assert_eq!(first.unwrap().vertex, 0);
        assert_eq!(moved, 4);
        assert_eq!(batch, vec![1, 2, 3, 4]);
        assert_eq!(d.len(), 4);
        // cap bounds the batch
        let (first, moved) = d.steal_half(1, |_| {});
        assert_eq!(first.unwrap().vertex, 5);
        assert_eq!(moved, 1);
        // empty deque yields nothing
        while d.steal().is_some() {}
        let (first, moved) = d.steal_half(8, |_| panic!("no sink on empty"));
        assert!(first.is_none());
        assert_eq!(moved, 0);
    }

    #[test]
    fn deque_full_returns_value() {
        let d: WorkStealingDeque<Task> = WorkStealingDeque::new(8);
        assert_eq!(d.capacity(), 8);
        for v in 0..8u32 {
            d.push(Task::new(v)).unwrap();
        }
        let spilled = d.push(Task::new(99)).unwrap_err();
        assert_eq!(spilled.vertex, 99);
        // after a pop there is room again
        assert_eq!(d.pop().unwrap().vertex, 7);
        d.push(spilled).unwrap();
        assert_eq!(d.pop().unwrap().vertex, 99);
    }

    #[test]
    fn deque_concurrent_exactly_once() {
        let n: u32 = 40_000;
        let deque: Arc<WorkStealingDeque<Task>> = Arc::new(WorkStealingDeque::new(256));
        let seen: Arc<Vec<AtomicU8>> =
            Arc::new((0..n).map(|_| AtomicU8::new(0)).collect());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let mut thieves = Vec::new();
        for _ in 0..3 {
            let deque = Arc::clone(&deque);
            let seen = Arc::clone(&seen);
            let done = Arc::clone(&done);
            thieves.push(std::thread::spawn(move || loop {
                match deque.steal() {
                    Some(t) => {
                        seen[t.vertex as usize].fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        if done.load(Ordering::Acquire) && deque.is_empty() {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            }));
        }

        // owner: push everything, popping locally whenever the deque fills
        for v in 0..n {
            let mut t = Task::new(v);
            loop {
                match deque.push(t) {
                    Ok(()) => break,
                    Err(back) => {
                        t = back;
                        if let Some(p) = deque.pop() {
                            seen[p.vertex as usize].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }
        }
        while let Some(p) = deque.pop() {
            seen[p.vertex as usize].fetch_add(1, Ordering::Relaxed);
        }
        done.store(true, Ordering::Release);
        for h in thieves {
            h.join().unwrap();
        }
        for (v, c) in seen.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {v} lost or duplicated");
        }
    }

    #[test]
    fn injector_fifo_and_overflow() {
        let q: Injector<Task> = Injector::new(64);
        assert_eq!(q.capacity(), 64);
        // 200 pushes: 64 fill the ring, 136 spill to the overflow list
        for v in 0..200u32 {
            q.push(Task::new(v));
        }
        assert_eq!(q.len(), 200);
        let mut got = Vec::new();
        while let Some(t) = q.pop() {
            got.push(t.vertex);
        }
        assert_eq!(got.len(), 200);
        // exactly-once delivery (order may interleave ring and overflow)
        got.sort_unstable();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn injector_ring_is_fifo_under_capacity() {
        let q: Injector<u32> = Injector::new(64);
        for v in 0..50u32 {
            q.push(v);
        }
        for v in 0..50u32 {
            assert_eq!(q.pop(), Some(v));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn injector_concurrent_exactly_once() {
        let producers: u32 = 4;
        let per: u32 = 20_000;
        let n = producers * per;
        let q: Arc<Injector<Task>> = Arc::new(Injector::new(1024));
        let seen: Arc<Vec<AtomicU8>> =
            Arc::new((0..n).map(|_| AtomicU8::new(0)).collect());
        let produced = Arc::new(AtomicUsize::new(0));
        let consumed = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = Arc::clone(&q);
            let produced = Arc::clone(&produced);
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push(Task::new(p * per + i));
                    produced.fetch_add(1, Ordering::Release);
                }
            }));
        }
        for _ in 0..4 {
            let q = Arc::clone(&q);
            let seen = Arc::clone(&seen);
            let produced = Arc::clone(&produced);
            let consumed = Arc::clone(&consumed);
            handles.push(std::thread::spawn(move || loop {
                match q.pop() {
                    Some(t) => {
                        seen[t.vertex as usize].fetch_add(1, Ordering::Relaxed);
                        consumed.fetch_add(1, Ordering::AcqRel);
                    }
                    None => {
                        if produced.load(Ordering::Acquire) == n as usize
                            && consumed.load(Ordering::Acquire) >= n as usize
                        {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), n as usize);
        for (v, c) in seen.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {v} lost or duplicated");
        }
    }
}
