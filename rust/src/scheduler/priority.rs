//! Prioritized task schedulers (paper §3.4): the strict global priority
//! queue and the relaxed bucketed approximation. Both support *priority
//! promotion*: re-adding a pending task with a higher priority raises it —
//! the mechanism behind Residual BP (Elidan et al. 2006).
//!
//! [`PriorityScheduler`] is the paper's strict variant: one heap, one
//! mutex, exact order ("at the cost of increased overhead" — Fig 4a
//! measures exactly that). [`ApproxPriorityScheduler`] quantizes priorities
//! into log-spaced buckets of lock-free [`Injector`] shards and keeps the
//! per-vertex live-priority table in plain atomics, so adds and pops never
//! take a lock; [`super::by_name_for_graph`] hands it out for
//! `--scheduler priority` by default (the serial heap stays available as
//! `priority-strict`).
//!
//! De-duplication granularity: unlike the FIFO family's per-(vertex, func)
//! pending flags, both priority schedulers deduplicate **per vertex** — a
//! vertex has one live priority, and scheduling a second `FuncId` for a
//! pending vertex merges into (at most promotes) the pending entry. Programs
//! multiplexing several update functions through one priority scheduler
//! should use distinct vertices or a FIFO-family scheduler.

use super::{Injector, Scheduler, Task};
use crate::graph::PartitionMap;
use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy)]
struct HeapEntry {
    priority: f64,
    seq: u64,
    task: Task,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // max-heap on priority; FIFO (lower seq first) among equals
        self.priority
            .partial_cmp(&other.priority)
            .unwrap_or(CmpOrdering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct PriorityState {
    heap: BinaryHeap<HeapEntry>,
    /// Current live priority per (vertex, func-0) task; NAN = not pending.
    /// Lazy deletion: heap entries whose priority no longer matches are stale.
    live: Vec<f64>,
    seq: u64,
}

/// Strict priority scheduler: one global heap under a mutex ("at the cost of
/// increased overhead" — the paper's words; Fig 4a measures exactly that).
pub struct PriorityScheduler {
    state: Mutex<PriorityState>,
    len: AtomicUsize,
    num_vertices: usize,
}

impl PriorityScheduler {
    pub fn new(num_vertices: usize) -> PriorityScheduler {
        PriorityScheduler {
            state: Mutex::new(PriorityState {
                heap: BinaryHeap::new(),
                live: vec![f64::NAN; num_vertices],
                seq: 0,
            }),
            len: AtomicUsize::new(0),
            num_vertices,
        }
    }
}

impl Scheduler for PriorityScheduler {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn add_task(&self, t: Task) {
        debug_assert!((t.vertex as usize) < self.num_vertices);
        let mut s = self.state.lock().unwrap();
        let cur = s.live[t.vertex as usize];
        if cur.is_nan() {
            // newly pending
            s.live[t.vertex as usize] = t.priority;
            let seq = s.seq;
            s.seq += 1;
            s.heap.push(HeapEntry { priority: t.priority, seq, task: t });
            self.len.fetch_add(1, Ordering::Relaxed);
        } else if t.priority > cur {
            // promote: push a higher entry; the lower one becomes stale
            s.live[t.vertex as usize] = t.priority;
            let seq = s.seq;
            s.seq += 1;
            s.heap.push(HeapEntry { priority: t.priority, seq, task: t });
        }
    }

    fn next_task(&self, _worker: usize) -> Option<Task> {
        let mut s = self.state.lock().unwrap();
        while let Some(entry) = s.heap.pop() {
            let live = s.live[entry.task.vertex as usize];
            if !live.is_nan() && live == entry.priority {
                s.live[entry.task.vertex as usize] = f64::NAN;
                self.len.fetch_sub(1, Ordering::Relaxed);
                let mut t = entry.task;
                t.priority = entry.priority;
                return Some(t);
            }
            // stale promotion leftover — skip
        }
        None
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

/// Relaxed ("approximate") priority scheduler: priorities are quantized into
/// log-spaced buckets; each bucket is sharded into one lock-free
/// [`Injector`] per worker, with owner-affine insertion (the shard of the
/// worker owning the vertex). Pops scan from the hottest bucket down, own
/// shard first. The per-vertex live-priority table is a plain `AtomicU64`
/// of f64 bits ([`EMPTY_PRI`] = not pending), so the whole add/pop path is
/// lock-free — the global `Mutex<Vec<f64>>` this replaces serialized every
/// operation of every worker.
pub struct ApproxPriorityScheduler {
    /// buckets[b][s] — bucket-major, one shard per worker.
    buckets: Vec<Vec<Injector<Task>>>,
    /// Live priority bits per vertex; [`EMPTY_PRI`] = not pending.
    live: Vec<AtomicU64>,
    part: PartitionMap,
    len: AtomicUsize,
    nshards: usize,
}

const NUM_BUCKETS: usize = 24;
/// Bucket 0 holds the highest priorities. Priorities are assumed positive
/// residual-like magnitudes; bucket = clamp(-log2(p / PMAX)).
const PMAX: f64 = 16.0;

/// "Not pending" sentinel for the live table. `u64::MAX` is one specific
/// NaN bit pattern; stored priorities are sanitized to finite values so the
/// sentinel can never collide with a real entry.
const EMPTY_PRI: u64 = u64::MAX;

fn bucket_of(p: f64) -> usize {
    if !(p > 0.0) {
        return NUM_BUCKETS - 1;
    }
    let b = -(p / PMAX).log2();
    b.max(0.0).min((NUM_BUCKETS - 1) as f64) as usize
}

/// Clamp non-finite priorities so their bit patterns are storable (see
/// [`EMPTY_PRI`]); NaN/±inf priorities are meaningless to bucketing anyway.
fn sanitize(p: f64) -> f64 {
    if p.is_finite() {
        p
    } else if p == f64::INFINITY {
        f64::MAX
    } else {
        0.0
    }
}

impl ApproxPriorityScheduler {
    pub fn new(num_vertices: usize, workers: usize) -> ApproxPriorityScheduler {
        let nshards = workers.max(1);
        // Per-ring capacity: the load spreads over NUM_BUCKETS x nshards
        // rings, so size each ring for its slice of the vertices (the
        // overflow lists absorb skewed bucket distributions).
        let cap = (num_vertices / (nshards * NUM_BUCKETS)).clamp(64, 1 << 13);
        ApproxPriorityScheduler {
            buckets: (0..NUM_BUCKETS)
                .map(|_| (0..nshards).map(|_| Injector::new(cap)).collect())
                .collect(),
            live: (0..num_vertices).map(|_| AtomicU64::new(EMPTY_PRI)).collect(),
            part: PartitionMap::new(num_vertices, nshards),
            len: AtomicUsize::new(0),
            nshards,
        }
    }
}

impl Scheduler for ApproxPriorityScheduler {
    fn name(&self) -> &'static str {
        "approx-priority"
    }

    fn owner_of(&self, v: u32) -> Option<usize> {
        Some(self.part.owner_of(v))
    }

    fn add_task(&self, t: Task) {
        let p = sanitize(t.priority);
        let cell = &self.live[t.vertex as usize];
        let mut cur = cell.load(Ordering::Acquire);
        loop {
            if cur == EMPTY_PRI {
                match cell.compare_exchange_weak(
                    cur,
                    p.to_bits(),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        // Newly pending. Count *before* the ring push: a
                        // concurrent pop may claim the vertex through a
                        // stale older entry the moment the CAS lands, and
                        // its decrement must never precede our increment
                        // at quiescence.
                        self.len.fetch_add(1, Ordering::Relaxed);
                        let b = bucket_of(p);
                        let s = self.part.owner_of(t.vertex);
                        self.buckets[b][s].push(Task { priority: p, ..t });
                        return;
                    }
                    Err(seen) => cur = seen,
                }
            } else {
                let curf = f64::from_bits(cur);
                if p <= curf {
                    return; // lower-priority re-add of a pending task: no-op
                }
                match cell.compare_exchange_weak(
                    cur,
                    p.to_bits(),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        // promotion: if it crosses into a hotter bucket,
                        // insert a forwarding entry (the stale one is
                        // skipped on pop via the live check).
                        let (b_old, b_new) = (bucket_of(curf), bucket_of(p));
                        if b_new < b_old {
                            let s = self.part.owner_of(t.vertex);
                            self.buckets[b_new][s].push(Task { priority: p, ..t });
                        }
                        return;
                    }
                    Err(seen) => cur = seen,
                }
            }
        }
    }

    fn next_task(&self, worker: usize) -> Option<Task> {
        for b in 0..NUM_BUCKETS {
            for i in 0..self.nshards {
                let s = (worker + i) % self.nshards;
                while let Some(t) = self.buckets[b][s].pop() {
                    // Claim the vertex against concurrent pops/promotions.
                    let cell = &self.live[t.vertex as usize];
                    let mut cur = cell.load(Ordering::Acquire);
                    loop {
                        if cur == EMPTY_PRI {
                            break; // stale duplicate of an already-popped task
                        }
                        let curf = f64::from_bits(cur);
                        if bucket_of(curf) < b {
                            break; // promoted entry lives in a hotter bucket
                        }
                        match cell.compare_exchange_weak(
                            cur,
                            EMPTY_PRI,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        ) {
                            Ok(_) => {
                                self.len.fetch_sub(1, Ordering::Relaxed);
                                return Some(Task { priority: curf, ..t });
                            }
                            Err(seen) => cur = seen,
                        }
                    }
                    // stale entry — keep draining this shard
                }
            }
        }
        None
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_priority_order() {
        let s = PriorityScheduler::new(10);
        s.add_task(Task::with_priority(1, 1.0));
        s.add_task(Task::with_priority(2, 5.0));
        s.add_task(Task::with_priority(3, 3.0));
        assert_eq!(s.next_task(0).unwrap().vertex, 2);
        assert_eq!(s.next_task(0).unwrap().vertex, 3);
        assert_eq!(s.next_task(0).unwrap().vertex, 1);
        assert!(s.next_task(0).is_none());
    }

    #[test]
    fn fifo_among_equal_priorities() {
        let s = PriorityScheduler::new(10);
        s.add_task(Task::with_priority(4, 1.0));
        s.add_task(Task::with_priority(7, 1.0));
        assert_eq!(s.next_task(0).unwrap().vertex, 4);
        assert_eq!(s.next_task(0).unwrap().vertex, 7);
    }

    #[test]
    fn promotion_raises_pending_task() {
        let s = PriorityScheduler::new(10);
        s.add_task(Task::with_priority(1, 1.0));
        s.add_task(Task::with_priority(2, 2.0));
        s.add_task(Task::with_priority(1, 9.0)); // promote vertex 1 above 2
        assert_eq!(s.next_task(0).unwrap().vertex, 1);
        assert_eq!(s.next_task(0).unwrap().vertex, 2);
        assert!(s.next_task(0).is_none(), "stale entry must not resurface");
        assert!(s.is_done());
    }

    #[test]
    fn lower_priority_readd_is_ignored() {
        let s = PriorityScheduler::new(10);
        s.add_task(Task::with_priority(1, 5.0));
        s.add_task(Task::with_priority(1, 0.5));
        assert_eq!(s.approx_len(), 1);
        let t = s.next_task(0).unwrap();
        assert_eq!(t.priority, 5.0);
    }

    #[test]
    fn bucket_mapping_monotone() {
        assert!(bucket_of(16.0) <= bucket_of(1.0));
        assert!(bucket_of(1.0) <= bucket_of(1e-3));
        assert_eq!(bucket_of(0.0), NUM_BUCKETS - 1);
        assert_eq!(bucket_of(f64::NAN.abs().min(0.0)), NUM_BUCKETS - 1);
    }

    #[test]
    fn sanitize_keeps_sentinel_unreachable() {
        assert!(sanitize(f64::NAN).to_bits() != EMPTY_PRI);
        assert!(sanitize(f64::INFINITY).is_finite());
        assert!(sanitize(f64::NEG_INFINITY) == 0.0);
        assert_eq!(sanitize(2.5), 2.5);
    }

    #[test]
    fn approx_priority_prefers_hot_tasks() {
        let s = ApproxPriorityScheduler::new(100, 2);
        for v in 0..50u32 {
            s.add_task(Task::with_priority(v, 1e-4));
        }
        s.add_task(Task::with_priority(99, 8.0));
        assert_eq!(s.next_task(0).unwrap().vertex, 99, "hot task first");
    }

    #[test]
    fn approx_priority_promotion() {
        let s = ApproxPriorityScheduler::new(10, 1);
        s.add_task(Task::with_priority(1, 1e-4));
        s.add_task(Task::with_priority(2, 1e-4));
        s.add_task(Task::with_priority(2, 8.0)); // promote 2 to hot bucket
        assert_eq!(s.next_task(0).unwrap().vertex, 2);
        assert_eq!(s.next_task(0).unwrap().vertex, 1);
        assert!(s.next_task(0).is_none());
        assert!(s.is_done());
    }

    #[test]
    fn approx_drains_exactly_once_each() {
        let s = ApproxPriorityScheduler::new(200, 3);
        for v in 0..200u32 {
            s.add_task(Task::with_priority(v, (v as f64 + 1.0) / 10.0));
            // duplicate re-add with lower priority: ignored
            s.add_task(Task::with_priority(v, 1e-6));
        }
        let mut seen = std::collections::HashSet::new();
        for w in 0..3 {
            while let Some(t) = s.next_task(w) {
                assert!(seen.insert(t.vertex), "vertex {} delivered twice", t.vertex);
            }
        }
        assert_eq!(seen.len(), 200);
    }

    #[test]
    fn approx_concurrent_adds_dedup_exactly_once() {
        use std::sync::Arc;
        let n: u32 = 400;
        let s = Arc::new(ApproxPriorityScheduler::new(n as usize, 4));
        // 4 threads race to add every vertex (with different priorities);
        // dedup + promotion must leave exactly one live entry per vertex.
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for v in 0..n {
                    s.add_task(Task::with_priority(v, 0.1 + t as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for w in 0..4 {
            while let Some(t) = s.next_task(w) {
                assert!(seen.insert(t.vertex), "vertex {} delivered twice", t.vertex);
            }
        }
        assert_eq!(seen.len(), n as usize);
        assert!(s.is_done());
    }
}
