//! The **set scheduler** (paper §3.4.1, Fig. 2).
//!
//! The user specifies a sequence of (vertex set, update function) pairs
//! `((S_1, f_1), ..., (S_k, f_k))` with the semantics
//!
//! ```text
//! for i = 1..k: execute f_i on all v in S_i in parallel; barrier
//! ```
//!
//! Executing literally (the **barrier** mode) leaves processors idle at each
//! set boundary. The **planned** mode rewrites the sequence into an execution
//! plan: a DAG whose edges are the *consistency-model data dependencies*
//! between tasks in consecutive sets — a task only waits for the earlier
//! tasks whose scopes overlap its own footprint (Fig. 2: `v4` runs right
//! after `v5` without waiting for `v1, v2`). The DAG's partial order is then
//! executed greedily (Graham 1966 list scheduling): any task whose
//! dependencies are satisfied may start on any free processor.
//!
//! This is the machinery behind the chromatic parallel Gibbs sampler
//! (§4.2, Fig. 5a/c): sets = color classes, plan = cross-color dependencies.

use super::{FuncId, Injector, Scheduler, Task};
use crate::consistency::ConsistencyModel;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A compiled execution plan (the DAG of Fig. 2).
pub struct ExecutionPlan {
    /// Plan tasks in sequence order: (vertex, func, set index).
    pub tasks: Vec<(u32, FuncId, u32)>,
    /// Dependency edges, CSR over plan-task indices: children of task i.
    child_offsets: Vec<u32>,
    child_items: Vec<u32>,
    /// In-degree of each plan task.
    pub indegree: Vec<u32>,
    /// Total dependency edges.
    pub num_edges: usize,
}

impl ExecutionPlan {
    /// Compile the plan. `sets` is the (S_i, f_i) sequence; `neighbors(v)`
    /// yields each vertex's (sorted) neighbor list; `model` determines each
    /// task's read/write sets over the *entities* of the data graph
    /// (vertex data blocks and undirected edge-data slots):
    ///
    /// * Vertex model — R = W = `{v}`.
    /// * Edge model — W = `{v} ∪ adjacent edge slots`, R = W ∪ `N(v)`
    ///   (Prop. 3.1 cond. 2: neighbors are read, not written).
    /// * Full model — R = W = `{v} ∪ N(v) ∪ adjacent edge slots`.
    ///
    /// A dependency edge `A -> B` (A in an earlier set) is added iff
    /// `W(A) ∩ R(B)`, `R(A) ∩ W(B)`, or `W(A) ∩ W(B)` is non-empty, pruned
    /// by transitivity through per-entity writer chains. This reproduces
    /// Fig. 2 exactly: a set-2 task waits only for the set-1 tasks whose
    /// state it actually observes.
    pub fn compile<'a>(
        sets: &[(Vec<u32>, FuncId)],
        num_vertices: usize,
        neighbors: impl Fn(u32) -> &'a [u32],
        model: ConsistencyModel,
    ) -> ExecutionPlan {
        let total: usize = sets.iter().map(|(s, _)| s.len()).sum();
        let mut tasks = Vec::with_capacity(total);
        let mut deps: Vec<Vec<u32>> = Vec::with_capacity(total);

        // Entity table: vertices are 0..n; undirected edge slots are interned
        // on demand as n, n+1, ...
        let mut edge_entities: std::collections::HashMap<(u32, u32), u32> =
            std::collections::HashMap::new();
        let mut num_entities = num_vertices as u32;
        let mut entity_of_edge = |u: u32, v: u32| -> u32 {
            let key = (u.min(v), u.max(v));
            *edge_entities.entry(key).or_insert_with(|| {
                let id = num_entities;
                num_entities += 1;
                id
            })
        };

        // R/W sets of a task at `v` under `model` (entity ids).
        let rw_sets = |v: u32,
                       entity_of_edge: &mut dyn FnMut(u32, u32) -> u32|
         -> (Vec<u32>, Vec<u32>) {
            match model {
                ConsistencyModel::Vertex => (vec![v], vec![v]),
                ConsistencyModel::Edge => {
                    let mut w = vec![v];
                    let mut r = vec![v];
                    for &u in neighbors(v) {
                        let e = entity_of_edge(v, u);
                        w.push(e);
                        r.push(e);
                        r.push(u);
                    }
                    (r, w)
                }
                ConsistencyModel::Full => {
                    let mut w = vec![v];
                    for &u in neighbors(v) {
                        w.push(entity_of_edge(v, u));
                        w.push(u);
                    }
                    (w.clone(), w)
                }
            }
        };

        // Per entity: writers in the most recent set that wrote it, and
        // readers accumulated since that write (possibly spanning sets —
        // read chains are not transitive, so all of them gate a new write).
        let mut writers_last: Vec<Vec<u32>> = Vec::new();
        let mut readers_since: Vec<Vec<u32>> = Vec::new();
        let ensure = |tables: &mut Vec<Vec<u32>>, id: u32| {
            if tables.len() <= id as usize {
                tables.resize(id as usize + 1, Vec::new());
            }
        };

        for (set_idx, (set, func)) in sets.iter().enumerate() {
            // accesses made by this set (committed at the set boundary)
            let mut cur_writes: Vec<(u32, u32)> = Vec::new(); // (entity, task)
            let mut cur_reads: Vec<(u32, u32)> = Vec::new();
            for &v in set {
                let ti = tasks.len() as u32;
                tasks.push((v, *func, set_idx as u32));
                let (r_set, w_set) = rw_sets(v, &mut entity_of_edge);
                let mut my_deps: Vec<u32> = Vec::new();
                for &e in &r_set {
                    ensure(&mut writers_last, e);
                    my_deps.extend_from_slice(&writers_last[e as usize]); // RAW
                }
                for &e in &w_set {
                    ensure(&mut writers_last, e);
                    ensure(&mut readers_since, e);
                    my_deps.extend_from_slice(&writers_last[e as usize]); // WAW
                    my_deps.extend_from_slice(&readers_since[e as usize]); // WAR
                }
                my_deps.sort_unstable();
                my_deps.dedup();
                deps.push(my_deps);
                for &e in &w_set {
                    cur_writes.push((e, ti));
                }
                for &e in &r_set {
                    cur_reads.push((e, ti));
                }
            }
            // Commit this set's accesses: a write resets the entity's reader
            // list and replaces its writer set; reads accumulate.
            let mut written_now = std::collections::HashSet::new();
            for &(e, _) in &cur_writes {
                if written_now.insert(e) {
                    ensure(&mut writers_last, e);
                    ensure(&mut readers_since, e);
                    writers_last[e as usize].clear();
                    readers_since[e as usize].clear();
                }
            }
            for &(e, t) in &cur_writes {
                writers_last[e as usize].push(t);
            }
            for &(e, t) in &cur_reads {
                ensure(&mut readers_since, e);
                // a task that also wrote e is already in writers_last
                if !written_now.contains(&e) || !writers_last[e as usize].contains(&t) {
                    readers_since[e as usize].push(t);
                }
            }
        }

        // Invert deps into child CSR + indegrees.
        let mut indegree = vec![0u32; total];
        let mut child_counts = vec![0u32; total + 1];
        for (ti, ds) in deps.iter().enumerate() {
            indegree[ti] = ds.len() as u32;
            for &d in ds {
                child_counts[d as usize + 1] += 1;
            }
        }
        for i in 0..total {
            child_counts[i + 1] += child_counts[i];
        }
        let child_offsets = child_counts.clone();
        let mut cursor = child_offsets.clone();
        let num_edges: usize = deps.iter().map(|d| d.len()).sum();
        let mut child_items = vec![0u32; num_edges];
        for (ti, ds) in deps.iter().enumerate() {
            for &d in ds {
                let c = &mut cursor[d as usize];
                child_items[*c as usize] = ti as u32;
                *c += 1;
            }
        }

        ExecutionPlan { tasks, child_offsets, child_items, indegree, num_edges }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn children(&self, task: u32) -> &[u32] {
        &self.child_items
            [self.child_offsets[task as usize] as usize..self.child_offsets[task as usize + 1] as usize]
    }

    /// Length (in tasks) of the longest dependency chain — a lower bound on
    /// parallel makespan in units of one task (used by Fig 5 analysis).
    pub fn critical_path_len(&self) -> usize {
        let n = self.tasks.len();
        let mut depth = vec![1u32; n];
        // tasks are in topological order by construction (deps point backward)
        let mut longest = 0u32;
        for i in 0..n {
            let d = depth[i];
            longest = longest.max(d);
            for &c in self.children(i as u32) {
                depth[c as usize] = depth[c as usize].max(d + 1);
            }
        }
        longest as usize
    }
}

enum Mode {
    /// Execute the compiled DAG greedily (Graham list scheduling).
    Planned,
    /// Literal semantics: full barrier between consecutive sets.
    Barrier { set_sizes: Vec<usize> },
}

/// Runtime scheduler executing a compiled [`ExecutionPlan`].
///
/// Implementation note: the plan-task index is carried in `Task::priority`
/// so `task_done` can resolve which DAG node completed even when the same
/// vertex appears in several sets. The ready list — the hot path of the
/// planned mode, touched once per issue and once per dependency release —
/// is a lock-free [`Injector`] of plan-task indices.
pub struct SetScheduler {
    plan: ExecutionPlan,
    remaining: Vec<AtomicUsize>,
    ready: Injector<u32>,
    issued: AtomicUsize,
    completed: AtomicUsize,
    mode: Mode,
    /// Barrier mode: completed count within the current set.
    set_cursor: Mutex<(usize, usize, usize)>, // (set_idx, served_in_set, done_in_set)
}

impl SetScheduler {
    /// Planned execution of the (S_i, f_i) sequence (the paper's optimized
    /// set scheduler).
    pub fn planned<'a>(
        sets: &[(Vec<u32>, FuncId)],
        num_vertices: usize,
        neighbors: impl Fn(u32) -> &'a [u32],
        model: ConsistencyModel,
    ) -> SetScheduler {
        let plan = ExecutionPlan::compile(sets, num_vertices, neighbors, model);
        let ready = Injector::new(plan.len());
        for t in 0..plan.len() as u32 {
            if plan.indegree[t as usize] == 0 {
                ready.push(t);
            }
        }
        let remaining =
            plan.indegree.iter().map(|&d| AtomicUsize::new(d as usize)).collect();
        SetScheduler {
            plan,
            remaining,
            ready,
            issued: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            mode: Mode::Planned,
            set_cursor: Mutex::new((0, 0, 0)),
        }
    }

    /// Literal barrier execution (the "plan set scheduler without
    /// optimization" baseline in Fig 5a/c).
    pub fn barrier(sets: &[(Vec<u32>, FuncId)], num_vertices: usize) -> SetScheduler {
        let plan = ExecutionPlan::compile(
            sets,
            num_vertices,
            |_| &[][..],
            ConsistencyModel::Vertex,
        );
        let set_sizes: Vec<usize> = sets.iter().map(|(s, _)| s.len()).collect();
        let remaining = plan.indegree.iter().map(|_| AtomicUsize::new(0)).collect();
        SetScheduler {
            plan,
            remaining,
            ready: Injector::new(64),
            issued: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            mode: Mode::Barrier { set_sizes },
            set_cursor: Mutex::new((0, 0, 0)),
        }
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    fn total(&self) -> usize {
        self.plan.len()
    }
}

impl Scheduler for SetScheduler {
    fn name(&self) -> &'static str {
        match self.mode {
            Mode::Planned => "set-planned",
            Mode::Barrier { .. } => "set-barrier",
        }
    }

    /// The set scheduler's task list is fixed at compile time; dynamic task
    /// additions are ignored (the paper's set scheduler has the same
    /// semantics — schedules are composed of vertex *sets*).
    fn add_task(&self, _t: Task) {}

    fn next_task(&self, _worker: usize) -> Option<Task> {
        match &self.mode {
            Mode::Planned => {
                let ti = self.ready.pop()?;
                self.issued.fetch_add(1, Ordering::Relaxed);
                let (v, f, _set) = self.plan.tasks[ti as usize];
                Some(Task { vertex: v, func: f, priority: ti as f64 })
            }
            Mode::Barrier { set_sizes } => {
                let mut cur = self.set_cursor.lock().unwrap();
                let (set_idx, served, done) = *cur;
                if set_idx >= set_sizes.len() {
                    return None;
                }
                if served == set_sizes[set_idx] {
                    // barrier: wait for all completions, then advance
                    if done == set_sizes[set_idx] {
                        *cur = (set_idx + 1, 0, 0);
                        drop(cur);
                        return self.next_task(_worker);
                    }
                    return None;
                }
                // plan.tasks is ordered set-by-set; compute global index
                let base: usize = set_sizes[..set_idx].iter().sum();
                let ti = (base + served) as u32;
                cur.1 += 1;
                drop(cur);
                self.issued.fetch_add(1, Ordering::Relaxed);
                let (v, f, _s) = self.plan.tasks[ti as usize];
                Some(Task { vertex: v, func: f, priority: ti as f64 })
            }
        }
    }

    fn task_done(&self, t: Task, _worker: usize) {
        self.completed.fetch_add(1, Ordering::AcqRel);
        match &self.mode {
            Mode::Planned => {
                let ti = t.priority as u32;
                for &c in self.plan.children(ti) {
                    if self.remaining[c as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                        self.ready.push(c);
                    }
                }
            }
            Mode::Barrier { .. } => {
                let mut cur = self.set_cursor.lock().unwrap();
                cur.2 += 1;
            }
        }
    }

    fn is_done(&self) -> bool {
        self.completed.load(Ordering::Acquire) == self.total()
    }

    fn approx_len(&self) -> usize {
        self.total() - self.issued.load(Ordering::Relaxed).min(self.total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 2's example (0-indexed: paper's v_k = k-1): the schedule runs
    /// S1 = {v1, v2, v5} in parallel, then S2 = {v3, v4}. The data graph has
    /// v3 adjacent to v1, v2, v5 and v4 adjacent to v5 only, so — under edge
    /// consistency — "the execution of v3 depends on the state of v1, v2 and
    /// v5, but v4 only depends on the state of v5".
    fn paper_example() -> (Vec<(Vec<u32>, FuncId)>, Vec<Vec<u32>>) {
        // edges: 0-2, 1-2, 4-2, 4-3
        let adj: Vec<Vec<u32>> = vec![vec![2], vec![2], vec![0, 1, 4], vec![4], vec![2, 3]];
        let sets = vec![(vec![0, 1, 4], 0), (vec![2, 3], 0)];
        (sets, adj)
    }

    #[test]
    fn plan_matches_fig2_dependencies() {
        let (sets, adj) = paper_example();
        let plan = ExecutionPlan::compile(&sets, 5, |v| &adj[v as usize], ConsistencyModel::Edge);
        assert_eq!(plan.len(), 5);
        // task indices: 0->v1, 1->v2, 2->v5, 3->v3, 4->v4 (paper names)
        assert_eq!(plan.indegree[3], 3, "v3 waits on v1, v2 and v5 (Fig. 2)");
        assert_eq!(plan.indegree[4], 1, "v4 waits only on v5 (Fig. 2)");
        // first set has no deps
        assert_eq!(plan.indegree[0], 0);
        assert_eq!(plan.indegree[1], 0);
        assert_eq!(plan.indegree[2], 0);
        // and v4's single dependency is precisely v5 (task 2)
        assert_eq!(plan.children(2).contains(&4), true);
    }

    #[test]
    fn planned_execution_respects_dependencies() {
        let (sets, adj) = paper_example();
        let s = SetScheduler::planned(&sets, 5, |v| &adj[v as usize], ConsistencyModel::Edge);
        let mut completed_order = Vec::new();
        let mut in_flight: Vec<Task> = Vec::new();
        // Greedy: issue everything available, complete in FIFO order.
        loop {
            while let Some(t) = s.next_task(0) {
                in_flight.push(t);
            }
            if in_flight.is_empty() {
                break;
            }
            let t = in_flight.remove(0);
            completed_order.push(t.vertex);
            s.task_done(t, 0);
        }
        assert_eq!(completed_order.len(), 5);
        assert!(s.is_done());
        // v2 and v3 (set 2) must come after all their set-1 dependencies:
        let pos = |v: u32| completed_order.iter().position(|&x| x == v).unwrap();
        assert!(pos(2) > pos(1), "v2 after v1");
        assert!(pos(3) > pos(4), "v3 after v4 (its only real dependency chain)");
    }

    #[test]
    fn vertex_model_plan_has_fewer_edges() {
        let (sets, adj) = paper_example();
        let edge_plan =
            ExecutionPlan::compile(&sets, 5, |v| &adj[v as usize], ConsistencyModel::Edge);
        let vertex_plan =
            ExecutionPlan::compile(&sets, 5, |v| &adj[v as usize], ConsistencyModel::Vertex);
        assert!(vertex_plan.num_edges < edge_plan.num_edges);
        // Under vertex consistency, sets are disjoint => no deps at all.
        assert_eq!(vertex_plan.num_edges, 0);
    }

    #[test]
    fn barrier_mode_enforces_set_order() {
        let (sets, _) = paper_example();
        let s = SetScheduler::barrier(&sets, 5);
        // serve all of set 1
        let t1 = s.next_task(0).unwrap();
        let t2 = s.next_task(0).unwrap();
        let t3 = s.next_task(0).unwrap();
        // set 2 is blocked until every set-1 task completes
        assert!(s.next_task(0).is_none());
        s.task_done(t1, 0);
        s.task_done(t2, 0);
        assert!(s.next_task(0).is_none());
        s.task_done(t3, 0);
        let t4 = s.next_task(0).unwrap();
        assert!(matches!(t4.vertex, 2 | 3));
    }

    #[test]
    fn critical_path_reflects_chains() {
        // 3 sets over a path graph, same vertex each time => chain of 3
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![0]];
        let sets = vec![(vec![0], 0), (vec![0], 0), (vec![0], 0)];
        let plan = ExecutionPlan::compile(&sets, 2, |v| &adj[v as usize], ConsistencyModel::Edge);
        assert_eq!(plan.critical_path_len(), 3);
    }

    #[test]
    fn independent_sets_have_unit_critical_path() {
        let adj: Vec<Vec<u32>> = vec![vec![], vec![], vec![], vec![]];
        let sets = vec![(vec![0, 1], 0), (vec![2, 3], 0)];
        let plan = ExecutionPlan::compile(&sets, 4, |v| &adj[v as usize], ConsistencyModel::Edge);
        assert_eq!(plan.num_edges, 0);
        assert_eq!(plan.critical_path_len(), 1);
    }
}
