//! Sweep schedulers (paper §3.4):
//!
//! * [`RoundRobinScheduler`] — Gauss–Seidel: updates all vertices
//!   *sequentially in a fixed order*, always using the most recently
//!   available data (Gibbs sampling, coordinate descent).
//! * [`SynchronousScheduler`] — Jacobi: all vertices are updated in sweeps
//!   with a barrier between sweeps (classical synchronous BP).

use super::{Scheduler, Task};
use crate::util::BitSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Gauss–Seidel round-robin: vertex `order[k % n]` is the k-th task, for
/// `max_sweeps` full sweeps (or until the engine's termination functions
/// stop the run). `add_task` requests *additional* sweeps (bounded by
/// `max_sweeps`), which is how convergence-driven round-robin programs keep
/// the schedule alive while progress continues.
pub struct RoundRobinScheduler {
    order: Vec<u32>,
    cursor: AtomicU64,
    /// Total tasks permitted = n * sweeps_allowed (grows up to max via add_task).
    allowed: AtomicU64,
    max_tasks: u64,
    stopped: AtomicBool,
}

impl RoundRobinScheduler {
    pub fn new(num_vertices: usize, max_sweeps: usize) -> RoundRobinScheduler {
        Self::with_order((0..num_vertices as u32).collect(), max_sweeps)
    }

    /// Custom visit order (e.g. a permutation for randomized Gauss–Seidel).
    pub fn with_order(order: Vec<u32>, max_sweeps: usize) -> RoundRobinScheduler {
        let n = order.len() as u64;
        RoundRobinScheduler {
            order,
            cursor: AtomicU64::new(0),
            allowed: AtomicU64::new(n), // first sweep always allowed
            max_tasks: n * max_sweeps.max(1) as u64,
            stopped: AtomicBool::new(false),
        }
    }

    /// Stop handing out tasks (engine termination functions call this).
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::Release);
    }

    pub fn sweeps_completed(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed) / self.order.len().max(1) as u64
    }
}

impl Scheduler for RoundRobinScheduler {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn add_task(&self, _t: Task) {
        // A task request extends the schedule by (up to) one sweep beyond
        // the sweep of the most recently issued task.
        let n = self.order.len() as u64;
        let cur = self.cursor.load(Ordering::Relaxed);
        let issued_sweep = cur.saturating_sub(1) / n;
        let want = ((issued_sweep) + 2) * n;
        let want = want.min(self.max_tasks);
        self.allowed.fetch_max(want, Ordering::Relaxed);
    }

    fn next_task(&self, _worker: usize) -> Option<Task> {
        if self.stopped.load(Ordering::Acquire) {
            return None;
        }
        loop {
            let k = self.cursor.load(Ordering::Relaxed);
            if k >= self.allowed.load(Ordering::Relaxed).min(self.max_tasks) {
                return None;
            }
            if self
                .cursor
                .compare_exchange_weak(k, k + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                let v = self.order[(k % self.order.len() as u64) as usize];
                return Some(Task::new(v));
            }
        }
    }

    fn is_done(&self) -> bool {
        self.stopped.load(Ordering::Acquire)
            || self.cursor.load(Ordering::Relaxed)
                >= self.allowed.load(Ordering::Relaxed).min(self.max_tasks)
    }

    fn approx_len(&self) -> usize {
        let cur = self.cursor.load(Ordering::Relaxed);
        let allowed = self.allowed.load(Ordering::Relaxed).min(self.max_tasks);
        allowed.saturating_sub(cur) as usize
    }
}

/// Jacobi synchronous sweeps: every vertex in sweep `i` completes before any
/// vertex of sweep `i+1` begins (barrier). Tasks added during sweep `i` form
/// the vertex set of sweep `i+1` (de-duplicated); the initial sweep is all
/// tasks added before the first pop. Runs at most `max_sweeps` sweeps.
pub struct SynchronousScheduler {
    state: Mutex<SyncState>,
    /// tasks completed in the current sweep
    completed: AtomicUsize,
    max_sweeps: usize,
}

struct SyncState {
    current: Vec<u32>,
    served: usize,
    in_sweep: usize, // size of current sweep
    next: BitSet,
    next_count: usize,
    sweep_index: usize,
}

impl SynchronousScheduler {
    pub fn new(num_vertices: usize, max_sweeps: usize) -> SynchronousScheduler {
        SynchronousScheduler {
            state: Mutex::new(SyncState {
                current: Vec::new(),
                served: 0,
                in_sweep: 0,
                next: BitSet::new(num_vertices),
                next_count: 0,
                sweep_index: 0,
            }),
            completed: AtomicUsize::new(0),
            max_sweeps: max_sweeps.max(1),
        }
    }

    pub fn sweeps_completed(&self) -> usize {
        self.state.lock().unwrap().sweep_index
    }
}

impl Scheduler for SynchronousScheduler {
    fn name(&self) -> &'static str {
        "synchronous"
    }

    fn add_task(&self, t: Task) {
        let mut s = self.state.lock().unwrap();
        if s.sweep_index == 0 && s.in_sweep == 0 {
            // seeding before the first pop: goes into the first sweep
            if s.next.insert(t.vertex as usize) {
                s.next_count += 1;
            }
        } else if s.next.insert(t.vertex as usize) {
            s.next_count += 1;
        }
    }

    fn next_task(&self, _worker: usize) -> Option<Task> {
        let mut s = self.state.lock().unwrap();
        // Promote the seeded/next set into the current sweep at a barrier:
        // only when every served task of the current sweep has completed.
        if s.served == s.in_sweep {
            let all_done = self.completed.load(Ordering::Acquire) == s.in_sweep;
            if all_done && s.next_count > 0 && s.sweep_index < self.max_sweeps {
                let verts: Vec<u32> = s.next.iter().map(|v| v as u32).collect();
                s.next.clear_all();
                s.next_count = 0;
                s.current = verts;
                s.served = 0;
                s.in_sweep = s.current.len();
                s.sweep_index += 1;
                self.completed.store(0, Ordering::Release);
            } else {
                return None; // barrier open or nothing left
            }
        }
        let v = s.current[s.served];
        s.served += 1;
        Some(Task::new(v))
    }

    fn task_done(&self, _t: Task, _worker: usize) {
        self.completed.fetch_add(1, Ordering::AcqRel);
    }

    fn is_done(&self) -> bool {
        let s = self.state.lock().unwrap();
        let sweep_exhausted =
            s.served == s.in_sweep && self.completed.load(Ordering::Acquire) == s.in_sweep;
        sweep_exhausted && (s.next_count == 0 || s.sweep_index >= self.max_sweeps)
    }

    fn approx_len(&self) -> usize {
        let s = self.state.lock().unwrap();
        (s.in_sweep - s.served) + s.next_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_fixed_order() {
        let s = RoundRobinScheduler::new(4, 1);
        let seq: Vec<u32> = std::iter::from_fn(|| s.next_task(0)).map(|t| t.vertex).collect();
        assert_eq!(seq, vec![0, 1, 2, 3]);
        assert!(s.is_done());
    }

    #[test]
    fn round_robin_add_task_extends_sweeps() {
        let s = RoundRobinScheduler::new(3, 3);
        // consume sweep 1, requesting more work as we go
        for _ in 0..3 {
            let t = s.next_task(0).unwrap();
            s.add_task(t);
        }
        // second sweep available
        let mut count = 0;
        while s.next_task(0).is_some() {
            count += 1;
        }
        assert_eq!(count, 3, "exactly one extra sweep granted");
        assert!(s.is_done());
    }

    #[test]
    fn round_robin_respects_max_sweeps() {
        let s = RoundRobinScheduler::new(2, 2);
        let mut total = 0;
        loop {
            match s.next_task(0) {
                Some(t) => {
                    total += 1;
                    s.add_task(t); // always request more
                }
                None => break,
            }
        }
        assert_eq!(total, 4, "2 vertices x max 2 sweeps");
    }

    #[test]
    fn round_robin_stop() {
        let s = RoundRobinScheduler::new(10, 100);
        assert!(s.next_task(0).is_some());
        s.stop();
        assert!(s.next_task(0).is_none());
        assert!(s.is_done());
    }

    #[test]
    fn round_robin_custom_order() {
        let s = RoundRobinScheduler::with_order(vec![5, 3, 1], 1);
        let seq: Vec<u32> = std::iter::from_fn(|| s.next_task(0)).map(|t| t.vertex).collect();
        assert_eq!(seq, vec![5, 3, 1]);
    }

    #[test]
    fn synchronous_barrier_between_sweeps() {
        let s = SynchronousScheduler::new(4, 10);
        for v in 0..4 {
            s.add_task(Task::new(v));
        }
        // sweep 1
        let mut sweep1 = Vec::new();
        while let Some(t) = s.next_task(0) {
            sweep1.push(t);
            s.add_task(Task::new(t.vertex)); // reschedule for next sweep
        }
        assert_eq!(sweep1.len(), 4);
        // barrier: nothing until all 4 complete
        assert!(s.next_task(0).is_none());
        for &t in &sweep1[..3] {
            s.task_done(t, 0);
        }
        assert!(s.next_task(0).is_none(), "barrier must hold until last completion");
        s.task_done(sweep1[3], 0);
        // sweep 2 opens
        let t = s.next_task(0);
        assert!(t.is_some());
    }

    #[test]
    fn synchronous_dedups_within_sweep() {
        let s = SynchronousScheduler::new(4, 10);
        s.add_task(Task::new(1));
        s.add_task(Task::new(1));
        s.add_task(Task::new(2));
        let mut got = Vec::new();
        while let Some(t) = s.next_task(0) {
            got.push(t.vertex);
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn synchronous_max_sweeps_terminates() {
        let s = SynchronousScheduler::new(2, 3);
        s.add_task(Task::new(0));
        s.add_task(Task::new(1));
        let mut sweeps = 0;
        loop {
            let mut batch = Vec::new();
            while let Some(t) = s.next_task(0) {
                batch.push(t);
                s.add_task(Task::new(t.vertex));
            }
            if batch.is_empty() {
                break;
            }
            sweeps += 1;
            for t in batch {
                s.task_done(t, 0);
            }
        }
        assert_eq!(sweeps, 3);
        assert!(s.is_done());
    }
}
