//! The **Splash scheduler** (paper §3.4; Gonzalez et al. 2009a).
//!
//! Tasks are executed along spanning trees ("splashes"): the highest-residual
//! vertex is popped as a root, a bounded BFS tree is grown around it, and the
//! tree is updated leaves → root → leaves, which moves information across the
//! graph in O(tree-depth) updates instead of O(1)-hop diffusion. This is the
//! schedule that makes Loopy BP scale in Fig 4a / Fig 5d.

use super::{Injector, Scheduler, Task};
use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy)]
struct RootEntry {
    priority: f64,
    seq: u64,
    vertex: u32,
}

impl PartialEq for RootEntry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for RootEntry {}
impl PartialOrd for RootEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for RootEntry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.priority
            .partial_cmp(&other.priority)
            .unwrap_or(CmpOrdering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct RootHeap {
    heap: BinaryHeap<RootEntry>,
    live: Vec<f64>, // NAN = not pending
    seq: u64,
}

/// Splash scheduler over a static adjacency structure (cloned from the data
/// graph at construction so the scheduler is self-contained). The root heap
/// stays a strict mutex-guarded priority queue (hottest residual first, per
/// the paper); the per-worker splash *buffers* — the hot pop path, hit once
/// per update — are lock-free [`Injector`] queues.
pub struct SplashScheduler {
    roots: Mutex<RootHeap>,
    buffers: Vec<Injector<Task>>,
    /// CSR adjacency copy: neighbors of v = items[offsets[v]..offsets[v+1]].
    offsets: Vec<u32>,
    items: Vec<u32>,
    splash_size: usize,
    len: AtomicUsize,
}

impl SplashScheduler {
    /// `neighbors(v)` must yield each vertex's neighbor list; `splash_size`
    /// bounds the spanning-tree size (paper-typical: tens of vertices).
    pub fn new<'a>(
        num_vertices: usize,
        neighbors: impl Fn(u32) -> &'a [u32],
        splash_size: usize,
        workers: usize,
    ) -> SplashScheduler {
        let mut offsets = Vec::with_capacity(num_vertices + 1);
        let mut items = Vec::new();
        offsets.push(0u32);
        for v in 0..num_vertices as u32 {
            items.extend_from_slice(neighbors(v));
            offsets.push(items.len() as u32);
        }
        SplashScheduler {
            roots: Mutex::new(RootHeap {
                heap: BinaryHeap::new(),
                live: vec![f64::NAN; num_vertices],
                seq: 0,
            }),
            buffers: (0..workers.max(1))
                .map(|_| Injector::new(splash_size.max(1) * 4))
                .collect(),
            offsets,
            items,
            splash_size: splash_size.max(1),
            len: AtomicUsize::new(0),
        }
    }

    fn nbrs(&self, v: u32) -> &[u32] {
        &self.items[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Grow a BFS spanning tree from `root` (bounded by `splash_size`),
    /// consuming pending root entries it covers, and return the splash
    /// update order: leaves → root → leaves.
    fn build_splash(&self, root: u32, heap: &mut RootHeap) -> Vec<Task> {
        let mut tree = Vec::with_capacity(self.splash_size);
        let mut frontier = VecDeque::new();
        let mut visited = std::collections::HashSet::with_capacity(self.splash_size * 2);
        frontier.push_back(root);
        visited.insert(root);
        while let Some(v) = frontier.pop_front() {
            tree.push(v);
            if tree.len() >= self.splash_size {
                break;
            }
            for &u in self.nbrs(v) {
                if visited.insert(u) {
                    frontier.push_back(u);
                    if visited.len() >= self.splash_size * 4 {
                        break;
                    }
                }
            }
        }
        // Vertices covered by this splash no longer need their own root entry.
        let mut consumed = 0usize;
        for &v in &tree {
            if !heap.live[v as usize].is_nan() {
                heap.live[v as usize] = f64::NAN;
                consumed += 1;
            }
        }
        // (root was already consumed by the caller; `consumed` counts others)
        if consumed > 0 {
            self.len.fetch_sub(consumed, Ordering::Relaxed);
        }
        // leaves -> root (reverse BFS), then root -> leaves (forward BFS)
        let mut order: Vec<Task> = tree.iter().rev().map(|&v| Task::new(v)).collect();
        order.extend(tree.iter().map(|&v| Task::new(v)));
        order
    }
}

impl Scheduler for SplashScheduler {
    fn name(&self) -> &'static str {
        "splash"
    }

    fn add_task(&self, t: Task) {
        let mut heap = self.roots.lock().unwrap();
        let cur = heap.live[t.vertex as usize];
        if cur.is_nan() {
            heap.live[t.vertex as usize] = t.priority;
            let seq = heap.seq;
            heap.seq += 1;
            heap.heap.push(RootEntry { priority: t.priority, seq, vertex: t.vertex });
            self.len.fetch_add(1, Ordering::Relaxed);
        } else if t.priority > cur {
            heap.live[t.vertex as usize] = t.priority;
            let seq = heap.seq;
            heap.seq += 1;
            heap.heap.push(RootEntry { priority: t.priority, seq, vertex: t.vertex });
        }
    }

    fn next_task(&self, worker: usize) -> Option<Task> {
        let w = worker % self.buffers.len();
        if let Some(t) = self.buffers[w].pop() {
            return Some(t);
        }
        // Build a new splash from the hottest pending root.
        let mut heap = self.roots.lock().unwrap();
        let root = loop {
            let entry = heap.heap.pop()?;
            let live = heap.live[entry.vertex as usize];
            if !live.is_nan() && live == entry.priority {
                heap.live[entry.vertex as usize] = f64::NAN;
                self.len.fetch_sub(1, Ordering::Relaxed);
                break entry.vertex;
            }
        };
        let order = self.build_splash(root, &mut heap);
        drop(heap);
        let buf = &self.buffers[w];
        let mut order = order.into_iter();
        let first = order.next();
        for t in order {
            buf.push(t);
        }
        first
    }

    fn is_done(&self) -> bool {
        self.len.load(Ordering::Relaxed) == 0
            && self.buffers.iter().all(|b| b.is_empty())
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
            + self.buffers.iter().map(|b| b.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0-1-2-3-4 path adjacency.
    fn path_scheduler(splash_size: usize) -> SplashScheduler {
        let adj: Vec<Vec<u32>> = vec![vec![1], vec![0, 2], vec![1, 3], vec![2, 4], vec![3]];
        SplashScheduler::new(5, |v| &adj[v as usize], splash_size, 1)
    }

    #[test]
    fn splash_covers_tree_leaves_root_leaves() {
        let s = path_scheduler(3);
        s.add_task(Task::with_priority(2, 1.0));
        let mut order = Vec::new();
        while let Some(t) = s.next_task(0) {
            order.push(t.vertex);
        }
        // BFS from 2 with size 3: tree = [2, 1, 3]; order = rev ++ fwd
        assert_eq!(order, vec![3, 1, 2, 2, 1, 3]);
        assert!(s.is_done());
    }

    #[test]
    fn splash_consumes_covered_roots() {
        let s = path_scheduler(5);
        for v in 0..5 {
            s.add_task(Task::with_priority(v, 1.0 + v as f64));
        }
        // First splash roots at hottest (4) and covers the whole path,
        // consuming all pending entries.
        let mut updates = 0;
        while s.next_task(0).is_some() {
            updates += 1;
        }
        assert_eq!(updates, 10, "one splash of 5 vertices = 10 updates");
        assert!(s.is_done());
    }

    #[test]
    fn hottest_root_first() {
        let s = path_scheduler(1); // splash of a single vertex
        s.add_task(Task::with_priority(0, 0.5));
        s.add_task(Task::with_priority(4, 9.0));
        // size-1 splash => order = [v, v]
        assert_eq!(s.next_task(0).unwrap().vertex, 4);
        assert_eq!(s.next_task(0).unwrap().vertex, 4);
        assert_eq!(s.next_task(0).unwrap().vertex, 0);
    }

    #[test]
    fn promotion_on_pending_root() {
        let s = path_scheduler(1);
        s.add_task(Task::with_priority(0, 1.0));
        s.add_task(Task::with_priority(4, 2.0));
        s.add_task(Task::with_priority(0, 10.0)); // promote
        assert_eq!(s.next_task(0).unwrap().vertex, 0);
    }
}
