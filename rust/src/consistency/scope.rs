//! The **scope** object (paper §3.2.1, Fig. 1a): the window `S_v` — vertex
//! `v`, its adjacent edges, and its neighboring vertices — handed to an
//! update function, with the consistency-model locks held for its lifetime.

use super::{Conflict, ConsistencyModel, LockTable, ScopeGuard};
use crate::graph::{DataGraph, Edge, EdgeId, LocalRef, Shard, ShardedGraph, VertexId};
use crate::transport::{GhostTransport, PullRequest};

/// Locked neighborhood view passed to update functions:
/// `D_{S_v} <- f(D_{S_v}, T)`.
///
/// Access outside `S_v` panics. What is actually *protected* depends on the
/// model the scope was locked with (see [`ConsistencyModel`]); in particular
/// under [`ConsistencyModel::Vertex`] neighbor reads/writes are permitted but
/// racy — the paper's documented trade-off for maximum parallelism.
pub struct Scope<'a, V, E> {
    graph: &'a DataGraph<V, E>,
    center: VertexId,
    model: ConsistencyModel,
    _guards: Option<ScopeGuard<'a>>,
}

impl<'a, V, E> Scope<'a, V, E> {
    /// Try to acquire the scope of `v` under `model` without blocking: the
    /// whole exclusion set is taken all-or-nothing (most-contended locks
    /// first, per [`DataGraph::lock_neighbors`]) and the first conflict
    /// rolls back and reports the vertex that was busy. The threaded engine
    /// turns an `Err` into a deferral instead of parking the worker.
    pub fn try_lock(
        graph: &'a DataGraph<V, E>,
        locks: &'a LockTable,
        v: VertexId,
        model: ConsistencyModel,
    ) -> Result<Scope<'a, V, E>, Conflict> {
        let guards = locks.try_lock_scope(v, graph.lock_neighbors(v), model)?;
        Ok(Scope { graph, center: v, model, _guards: Some(guards) })
    }

    /// Acquire the scope of `v` under `model`, blocking (bounded-backoff
    /// retry of [`Scope::try_lock`]) until the exclusion set is free.
    pub fn lock(
        graph: &'a DataGraph<V, E>,
        locks: &'a LockTable,
        v: VertexId,
        model: ConsistencyModel,
    ) -> Scope<'a, V, E> {
        let guards = locks.lock_scope(v, graph.lock_neighbors(v), model);
        Scope { graph, center: v, model, _guards: Some(guards) }
    }

    /// Assemble a scope from an already-held guard — the completion of a
    /// pipelined split acquisition (see
    /// [`LockTable::try_lock_split`] and [`super::SplitScope`]).
    pub(crate) fn from_guard(
        graph: &'a DataGraph<V, E>,
        v: VertexId,
        model: ConsistencyModel,
        guards: ScopeGuard<'a>,
    ) -> Scope<'a, V, E> {
        debug_assert_eq!(guards.center, v, "guard does not cover this center");
        Scope { graph, center: v, model, _guards: Some(guards) }
    }

    /// Construct without taking locks — for the sequential engine and
    /// single-threaded contexts that are externally synchronized.
    pub(crate) fn unlocked(
        graph: &'a DataGraph<V, E>,
        v: VertexId,
        model: ConsistencyModel,
    ) -> Scope<'a, V, E> {
        Scope { graph, center: v, model, _guards: None }
    }

    #[inline]
    pub fn center(&self) -> VertexId {
        self.center
    }

    #[inline]
    pub fn model(&self) -> ConsistencyModel {
        self.model
    }

    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices()
    }

    // ---- structure -------------------------------------------------------

    /// Sorted unique neighbors of the center.
    #[inline]
    pub fn neighbors(&self) -> &'a [VertexId] {
        self.graph.neighbors(self.center)
    }

    /// In-edge ids `(* -> v)`.
    #[inline]
    pub fn in_edges(&self) -> &'a [EdgeId] {
        self.graph.in_edges(self.center)
    }

    /// Out-edge ids `(v -> *)`.
    #[inline]
    pub fn out_edges(&self) -> &'a [EdgeId] {
        self.graph.out_edges(self.center)
    }

    #[inline]
    pub fn edge(&self, e: EdgeId) -> Edge {
        self.graph.edge(e)
    }

    /// Reverse edge id of `e` if present.
    #[inline]
    pub fn reverse_edge(&self, e: EdgeId) -> Option<EdgeId> {
        self.graph.reverse_edge(e)
    }

    /// The directed edge `u -> v` within the scope, if present.
    pub fn find_edge(&self, u: VertexId, v: VertexId) -> Option<EdgeId> {
        self.graph.find_edge(u, v)
    }

    /// Neighbor list of an arbitrary vertex — **structure only** (graph
    /// structure is immutable, so this is always safe). Needed by programs
    /// that schedule two-hop vertices, e.g. the Shooting algorithm's
    /// "schedule all w's connected to neighboring y's" (Alg. 4).
    #[inline]
    pub fn neighbors_of(&self, u: VertexId) -> &'a [VertexId] {
        self.graph.neighbors(u)
    }

    #[inline]
    fn assert_in_scope_vertex(&self, u: VertexId) {
        debug_assert!(
            u == self.center || self.neighbors().binary_search(&u).is_ok(),
            "vertex {u} is outside the scope of {}",
            self.center
        );
    }

    #[inline]
    fn assert_in_scope_edge(&self, e: EdgeId) {
        let edge = self.graph.edge(e);
        debug_assert!(
            edge.src == self.center || edge.dst == self.center,
            "edge {e} ({}->{}) is not adjacent to scope center {}",
            edge.src,
            edge.dst,
            self.center
        );
    }

    // ---- data ------------------------------------------------------------

    /// Center vertex data `D_v` (read).
    #[inline]
    pub fn vertex(&self) -> &V {
        // SAFETY: scope holds (at least) the center write lock; sequential
        // contexts are externally synchronized.
        unsafe { self.graph.vertex_data_unchecked(self.center) }
    }

    /// Center vertex data `D_v` (write).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub fn vertex_mut(&self) -> &mut V {
        // SAFETY: as above — the center is write-locked in every model.
        unsafe { self.graph.vertex_data_mut_unchecked(self.center) }
    }

    /// Neighbor vertex data (read). Protected under Edge/Full; racy under
    /// Vertex (paper semantics).
    #[inline]
    pub fn neighbor(&self, u: VertexId) -> &V {
        self.assert_in_scope_vertex(u);
        // SAFETY: Edge/Full hold a read lock on `u`; Vertex-model racy access
        // is the documented contract of that model.
        unsafe { self.graph.vertex_data_unchecked(u) }
    }

    /// Neighbor vertex data (write). Sequentially consistent only under
    /// Full (Prop. 3.1 condition 1); racy otherwise.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub fn neighbor_mut(&self, u: VertexId) -> &mut V {
        self.assert_in_scope_vertex(u);
        debug_assert!(
            u == self.center || self.model == ConsistencyModel::Full
                || self.model == ConsistencyModel::Vertex,
            "writing neighbor {u} under the edge model violates Prop 3.1 cond. 2"
        );
        // SAFETY: Full holds write locks on neighbors; Vertex-model racy
        // writes are the application's documented responsibility.
        unsafe { self.graph.vertex_data_mut_unchecked(u) }
    }

    /// Adjacent edge data (read).
    #[inline]
    pub fn edge_data(&self, e: EdgeId) -> &E {
        self.assert_in_scope_edge(e);
        // SAFETY: adjacent edges are covered by the center's write lock plus
        // the neighbor's read lock under Edge/Full.
        unsafe { self.graph.edge_data_unchecked(e) }
    }

    /// Adjacent edge data (write). Protected under Edge/Full.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub fn edge_data_mut(&self, e: EdgeId) -> &mut E {
        self.assert_in_scope_edge(e);
        // SAFETY: as above.
        unsafe { self.graph.edge_data_mut_unchecked(e) }
    }
}

/// Outcome of one [`Scope::refresh_stale_ghosts`] admission pass.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct GhostRefresh {
    /// Pull-on-demand refreshes forced past the staleness bound.
    pub pulls: u64,
    /// Pulls whose request and reply crossed the transport's byte path
    /// (always equals `pulls` on a serializing backend, 0 on direct).
    pub served: u64,
    /// Request + reply wire bytes the pulls moved.
    pub bytes: u64,
    /// Max staleness actually observed by this reader, post-pull.
    pub max_lag: u64,
    /// Pulls re-issued because a prior attempt failed to bring the
    /// replica inside the bound (lossy or severed transport).
    pub retries: u64,
    /// Refreshes abandoned after exhausting the retry budget: the reader
    /// admitted the stale replica rather than hang on a dead peer.
    pub timeouts: u64,
}

impl<'a, V: Clone, E> Scope<'a, V, E> {
    /// Bounded-staleness admission check (sharded engine): for every ghost
    /// replica this scope would read on `shard`, force a pull-on-demand if
    /// the replica lags the master by more than `bound` versions — so an
    /// update function never observes a replica older than `bound`
    /// versions, regardless of how lazily the transport flushes. `bound =
    /// 0` forces replicas exactly current at every admission (the
    /// synchronous semantics of the per-update flush).
    ///
    /// The pulls are issued through `transport`'s **request/reply path**:
    /// all stale ghosts of the scope are collected first and refreshed
    /// with one batched [`GhostTransport::pull_many`] call, so pipelining
    /// backends (shm, socket) overlap the request/reply round-trips
    /// instead of lock-stepping one frame per exchange. [`PullRequest`]
    /// frames cross to the owner and the encoded-vertex replies cross
    /// back, so on a serializing backend scope admission never touches
    /// peer master data directly — the owner-side service closure this
    /// method supplies is the single place the master is read, and it
    /// runs under the locks described below.
    ///
    /// Must run with the scope's neighbor locks held (Edge/Full models):
    /// the held read locks both make the master read safe and freeze the
    /// master version, so the post-check staleness really is what the
    /// update function reads.
    ///
    /// On a faulty wire a pull can fail (severed exchange, dead peer) and
    /// leave the replica past the bound. The refresh then **retries** the
    /// pull under exponential spin backoff, up to `retry_limit` times per
    /// ghost, before giving up and admitting the stale read (a counted
    /// timeout). A dead peer therefore delays admission by a bounded
    /// amount, never hangs it — and on a perfect wire the first pull
    /// always lands, so the retry loop never runs.
    ///
    /// With `sync_rows` (resident mode, one shard per process) the
    /// refresh finishes by copying every ghost neighbor's replica into
    /// the process-local [`DataGraph`] row of that vertex — the rows
    /// update functions actually read, which in one address space are
    /// the shared masters but in a resident process are stale snapshots
    /// from partition time. Requires the Full model: the held neighbor
    /// **write** locks make the row overwrite invisible to concurrent
    /// readers.
    pub(crate) fn refresh_stale_ghosts(
        &self,
        sharded: &ShardedGraph<V>,
        shard: usize,
        bound: u64,
        retry_limit: u32,
        transport: &dyn GhostTransport<V>,
        sync_rows: bool,
    ) -> GhostRefresh {
        debug_assert!(
            self.model.excludes_neighbors(),
            "staleness admission requires neighbor locks (Edge/Full)"
        );
        let sh = sharded.shard(shard);
        let graph = self.graph;
        let mut out = GhostRefresh::default();
        // Phase 1: measure every ghost neighbor, collecting the ones past
        // the bound. Fresh replicas are observed and admitted immediately.
        let mut stale: Vec<(usize, VertexId, u64)> = Vec::new();
        for &code in sh.local_neighbors(self.center) {
            let LocalRef::Ghost(gi) = sh.resolve(code) else { continue };
            let entry = sh.ghost(gi as usize);
            let u = entry.global();
            // Version source: the local master table, upgraded by whatever
            // the transport has *heard* from remote owners — in one address
            // space the hook is the identity, but a resident (one shard per
            // process) backend folds in peer version announcements, the
            // only signal that a remote master moved.
            let lag = transport
                .known_master_version(u, sharded.master_version(u))
                .saturating_sub(entry.version());
            if lag > bound {
                stale.push((gi as usize, u, lag));
            } else {
                crate::telemetry::observe_lag(lag);
                if lag > out.max_lag {
                    out.max_lag = lag;
                }
            }
        }
        if stale.is_empty() {
            if sync_rows {
                self.sync_ghost_rows(sh);
            }
            return out;
        }
        // The owner-side pull service: the single place peer master data
        // is read, shared by the batched pull and the retry fallback.
        let master = |v: VertexId| {
            // SAFETY: Edge/Full scopes hold (at least) a read lock on
            // every neighbor, and only this scope's ghost neighbors are
            // ever requested.
            let data = unsafe { graph.vertex_data_unchecked(v) };
            (data, sharded.master_version(v))
        };
        // Phase 2: one batched pull for the whole stale set — pipelining
        // backends put every request on the wire before collecting the
        // replies, overlapping the round-trips.
        let reqs: Vec<PullRequest> = stale
            .iter()
            .map(|&(_, u, _)| PullRequest {
                vertex: u,
                min_version: transport.known_master_version(u, sharded.master_version(u)),
            })
            .collect();
        let receipts = transport.pull_many(shard, &reqs, &master);
        for (i, &(gi, u, lag)) in stale.iter().enumerate() {
            let receipt = &receipts[i];
            out.pulls += 1;
            out.served += receipt.served as u64;
            out.bytes += receipt.bytes;
            crate::telemetry::instant(
                crate::telemetry::EventKind::StalePull,
                u as u64,
                lag,
            );
            let entry = sh.ghost(gi);
            // Re-measure after the pull: this is the staleness the update
            // function actually reads. In one address space the held read
            // lock freezes the master version, so anything above `bound`
            // here means the pull itself failed (lossy or severed
            // transport); cross-process the remote master can also have
            // moved again meanwhile — either way: retry with backoff,
            // then give up rather than hang on a dead peer.
            let mut now = transport
                .known_master_version(u, sharded.master_version(u))
                .saturating_sub(entry.version());
            let mut attempts = 0u32;
            while now > bound {
                attempts += 1;
                if attempts > retry_limit {
                    out.timeouts += 1;
                    break;
                }
                out.retries += 1;
                crate::telemetry::instant(
                    crate::telemetry::EventKind::PullRetry,
                    u as u64,
                    attempts as u64,
                );
                // Exponential spin backoff: deterministic (no sleeps,
                // no clocks), bounded at ~32k spins per attempt.
                for _ in 0..(32u32 << attempts.min(10)) {
                    std::hint::spin_loop();
                }
                let receipt = transport.pull(
                    shard,
                    PullRequest {
                        vertex: u,
                        min_version: transport
                            .known_master_version(u, sharded.master_version(u)),
                    },
                    &master,
                );
                out.pulls += 1;
                out.served += receipt.served as u64;
                out.bytes += receipt.bytes;
                crate::telemetry::instant(
                    crate::telemetry::EventKind::StalePull,
                    u as u64,
                    now,
                );
                now = transport
                    .known_master_version(u, sharded.master_version(u))
                    .saturating_sub(entry.version());
            }
            crate::telemetry::observe_lag(now);
            if now > out.max_lag {
                out.max_lag = now;
            }
        }
        if sync_rows {
            self.sync_ghost_rows(sh);
        }
        out
    }

    /// Resident-mode write-back: bring the process-local [`DataGraph`]
    /// rows of this scope's ghost neighbors up to their replicas, so the
    /// update function reads what the pull (or a drained delta) just
    /// delivered instead of the row's partition-time snapshot. No-op for
    /// rows already at the replica's version.
    fn sync_ghost_rows(&self, sh: &Shard<V>) {
        let graph = self.graph;
        for &code in sh.local_neighbors(self.center) {
            let LocalRef::Ghost(gi) = sh.resolve(code) else { continue };
            let entry = sh.ghost(gi as usize);
            let u = entry.global();
            // SAFETY: Full-model scopes hold a write lock on every
            // neighbor, so no concurrent reader (or writer) can observe
            // the row while it is overwritten.
            entry.sync_row(|data| unsafe {
                graph.vertex_data_mut_unchecked(u).clone_from(data);
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path3() -> (DataGraph<i64, i64>, LockTable) {
        // 0 <-> 1 <-> 2
        let mut b = GraphBuilder::new();
        for i in 0..3 {
            b.add_vertex(i as i64 * 10);
        }
        b.add_undirected(0, 1, 1, -1);
        b.add_undirected(1, 2, 2, -2);
        let g = b.build();
        let n = g.num_vertices();
        (g, LockTable::new(n))
    }

    #[test]
    fn center_read_write() {
        let (g, locks) = path3();
        {
            let s = Scope::lock(&g, &locks, 1, ConsistencyModel::Edge);
            assert_eq!(*s.vertex(), 10);
            *s.vertex_mut() = 99;
        }
        let s = Scope::lock(&g, &locks, 1, ConsistencyModel::Vertex);
        assert_eq!(*s.vertex(), 99);
    }

    #[test]
    fn neighbor_read_and_edges() {
        let (g, locks) = path3();
        let s = Scope::lock(&g, &locks, 1, ConsistencyModel::Edge);
        assert_eq!(s.neighbors(), &[0, 2]);
        assert_eq!(*s.neighbor(0), 0);
        assert_eq!(*s.neighbor(2), 20);
        assert_eq!(s.in_edges().len(), 2);
        assert_eq!(s.out_edges().len(), 2);
        let e01 = s.find_edge(1, 0).unwrap();
        assert_eq!(*s.edge_data(e01), -1);
        *s.edge_data_mut(e01) = 7;
        assert_eq!(*s.edge_data(e01), 7);
        // reverse edge wiring
        let e10 = s.find_edge(0, 1).unwrap();
        assert_eq!(s.reverse_edge(e01), Some(e10));
    }

    #[test]
    fn full_model_neighbor_write() {
        let (g, locks) = path3();
        {
            let s = Scope::lock(&g, &locks, 1, ConsistencyModel::Full);
            *s.neighbor_mut(0) += 5;
        }
        let s = Scope::lock(&g, &locks, 0, ConsistencyModel::Vertex);
        assert_eq!(*s.vertex(), 5);
    }

    #[test]
    fn try_lock_defers_instead_of_blocking() {
        let (g, locks) = path3();
        let held = Scope::try_lock(&g, &locks, 1, ConsistencyModel::Full).unwrap();
        // Any scope overlapping {0,1,2} must conflict rather than block.
        let c =
            Scope::try_lock(&g, &locks, 0, ConsistencyModel::Edge).err().expect("must conflict");
        assert_eq!(c.vertex, 0);
        drop(held);
        let s = Scope::try_lock(&g, &locks, 0, ConsistencyModel::Edge).unwrap();
        assert_eq!(*s.vertex(), 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "outside the scope")]
    fn out_of_scope_vertex_panics() {
        let (g, locks) = path3();
        let s = Scope::lock(&g, &locks, 0, ConsistencyModel::Edge);
        let _ = s.neighbor(2); // 2 is not adjacent to 0
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "not adjacent")]
    fn out_of_scope_edge_panics() {
        let (g, locks) = path3();
        let e12 = g.find_edge(1, 2).unwrap();
        let s = Scope::lock(&g, &locks, 0, ConsistencyModel::Edge);
        let _ = s.edge_data(e12);
    }

    /// Two threads incrementing a shared neighbor through Full scopes must
    /// never lose an update (write locks serialize them).
    #[test]
    fn full_consistency_serializes_neighbor_writes() {
        use std::sync::Arc;
        let mut b = GraphBuilder::new();
        let hub = b.add_vertex(0i64);
        let a = b.add_vertex(0);
        let c = b.add_vertex(0);
        b.add_undirected(a, hub, 0, 0);
        b.add_undirected(c, hub, 0, 0);
        let g = Arc::new(b.build());
        let locks = Arc::new(LockTable::new(3));
        let mut handles = Vec::new();
        for center in [a, c] {
            let g = Arc::clone(&g);
            let locks = Arc::clone(&locks);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    let s = Scope::lock(&g, &locks, center, ConsistencyModel::Full);
                    *s.neighbor_mut(hub) += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = Scope::lock(&g, &locks, hub, ConsistencyModel::Vertex);
        assert_eq!(*s.vertex(), 20_000);
    }
}
