//! The **word-per-vertex atomic reader–writer lock** behind the scope lock
//! table.
//!
//! One `AtomicU32` per vertex: the high bit is the writer flag, the low 31
//! bits count readers. Compared to the `std::sync::RwLock<()>` the seed
//! engine used this is ~8× smaller (4 bytes vs a pointer-sized poison-state
//! machine), has no poisoning, and — crucially — exposes *non-blocking*
//! `try_read`/`try_write`, which is what lets the engine turn a scope
//! conflict into a deferral instead of a parked worker thread
//! (Distributed GraphLab, Low et al. 2012, non-blocking lock pipelining).

use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

const WRITER: u32 = 1 << 31;
const MAX_READERS: u32 = WRITER - 1;

/// A single vertex lock word. All acquisition paths are non-blocking; the
/// `*_spin` variants layer a bounded spin/yield/sleep backoff on top for
/// callers that must eventually succeed (the background sync thread, the
/// compatibility blocking scope path).
#[derive(Debug, Default)]
pub struct ScopeLock(AtomicU32);

impl ScopeLock {
    pub const fn new() -> ScopeLock {
        ScopeLock(AtomicU32::new(0))
    }

    /// Take a shared (read) lock if no writer holds the word.
    #[inline]
    pub fn try_read(&self) -> bool {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            if cur & WRITER != 0 {
                return false;
            }
            debug_assert!(cur < MAX_READERS, "reader count overflow");
            match self.0.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Take the exclusive (write) lock if the word is completely free.
    #[inline]
    pub fn try_write(&self) -> bool {
        self.0
            .compare_exchange(0, WRITER, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    #[inline]
    pub fn unlock_read(&self) {
        let prev = self.0.fetch_sub(1, Ordering::Release);
        debug_assert!(prev & WRITER == 0 && prev > 0, "unlock_read without a read lock");
    }

    #[inline]
    pub fn unlock_write(&self) {
        debug_assert!(
            self.0.load(Ordering::Relaxed) == WRITER,
            "unlock_write without the write lock"
        );
        self.0.store(0, Ordering::Release);
    }

    /// Blocking read acquire (spin + backoff). Used by the sync thread's
    /// fold, which must make progress but only holds each lock briefly.
    pub fn read_spin(&self) {
        let mut backoff = Backoff::new();
        while !self.try_read() {
            backoff.snooze();
        }
    }

    /// Blocking write acquire (spin + backoff).
    pub fn write_spin(&self) {
        let mut backoff = Backoff::new();
        while !self.try_write() {
            backoff.snooze();
        }
    }

    /// Nobody holds the word (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn is_free(&self) -> bool {
        self.0.load(Ordering::Relaxed) == 0
    }

    /// Current reader count (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn readers(&self) -> u32 {
        self.0.load(Ordering::Relaxed) & MAX_READERS
    }

    /// A writer holds the word (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn has_writer(&self) -> bool {
        self.0.load(Ordering::Relaxed) & WRITER != 0
    }
}

/// Bounded exponential backoff: spin-hint, then yield, then micro-sleep.
/// The progression caps so a long wait never turns into an unbounded spin.
pub struct Backoff {
    step: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff::new()
    }
}

impl Backoff {
    pub fn new() -> Backoff {
        Backoff { step: 0 }
    }

    /// Is the next snooze still in the cheap spin-hint phase?
    #[inline]
    pub fn is_spinning(&self) -> bool {
        self.step < 6
    }

    pub fn snooze(&mut self) {
        if self.step < 6 {
            for _ in 0..(1u32 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < 12 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(20));
        }
        if self.step < 13 {
            self.step += 1;
        }
    }

    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn word_is_four_bytes() {
        assert_eq!(std::mem::size_of::<ScopeLock>(), 4);
    }

    #[test]
    fn readers_share_writers_exclude() {
        let l = ScopeLock::new();
        assert!(l.try_read());
        assert!(l.try_read());
        assert_eq!(l.readers(), 2);
        assert!(!l.try_write(), "writer must not enter with readers present");
        l.unlock_read();
        assert!(!l.try_write());
        l.unlock_read();
        assert!(l.try_write());
        assert!(l.has_writer());
        assert!(!l.try_read(), "reader must not enter with a writer present");
        assert!(!l.try_write(), "write lock is exclusive");
        l.unlock_write();
        assert!(l.is_free());
    }

    #[test]
    fn spin_variants_eventually_acquire() {
        let l = Arc::new(ScopeLock::new());
        assert!(l.try_write());
        let l2 = Arc::clone(&l);
        let h = std::thread::spawn(move || {
            l2.read_spin();
            l2.unlock_read();
            l2.write_spin();
            l2.unlock_write();
        });
        std::thread::sleep(Duration::from_millis(2));
        l.unlock_write();
        h.join().unwrap();
        assert!(l.is_free());
    }

    /// Two writers incrementing a counter through the lock never race.
    #[test]
    fn write_lock_serializes() {
        let l = Arc::new(ScopeLock::new());
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let l = Arc::clone(&l);
            let counter = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    l.write_spin();
                    // non-atomic read-modify-write protected by the lock
                    let v = counter.load(Ordering::Relaxed);
                    counter.store(v + 1, Ordering::Relaxed);
                    l.unlock_write();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 40_000);
    }
}
