//! The **word-per-vertex atomic reader–writer lock** behind the scope lock
//! table.
//!
//! One `AtomicU32` per vertex: the high bit is the writer flag, the low 31
//! bits count readers. Compared to the `std::sync::RwLock<()>` the seed
//! engine used this is ~8× smaller (4 bytes vs a pointer-sized poison-state
//! machine), has no poisoning, and — crucially — exposes *non-blocking*
//! `try_read`/`try_write`, which is what lets the engine turn a scope
//! conflict into a deferral instead of a parked worker thread
//! (Distributed GraphLab, Low et al. 2012, non-blocking lock pipelining).

use super::{Conflict, ConsistencyModel, LockTable, ScopeGuard};
use crate::graph::VertexId;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

const WRITER: u32 = 1 << 31;
const MAX_READERS: u32 = WRITER - 1;

/// A single vertex lock word. All acquisition paths are non-blocking; the
/// `*_spin` variants layer a bounded spin/yield/sleep backoff on top for
/// callers that must eventually succeed (the background sync thread, the
/// compatibility blocking scope path).
#[derive(Debug, Default)]
pub struct ScopeLock(AtomicU32);

impl ScopeLock {
    pub const fn new() -> ScopeLock {
        ScopeLock(AtomicU32::new(0))
    }

    /// Take a shared (read) lock if no writer holds the word.
    #[inline]
    pub fn try_read(&self) -> bool {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            if cur & WRITER != 0 {
                return false;
            }
            debug_assert!(cur < MAX_READERS, "reader count overflow");
            match self.0.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Take the exclusive (write) lock if the word is completely free.
    #[inline]
    pub fn try_write(&self) -> bool {
        self.0
            .compare_exchange(0, WRITER, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    #[inline]
    pub fn unlock_read(&self) {
        let prev = self.0.fetch_sub(1, Ordering::Release);
        debug_assert!(prev & WRITER == 0 && prev > 0, "unlock_read without a read lock");
    }

    #[inline]
    pub fn unlock_write(&self) {
        debug_assert!(
            self.0.load(Ordering::Relaxed) == WRITER,
            "unlock_write without the write lock"
        );
        self.0.store(0, Ordering::Release);
    }

    /// Blocking read acquire (spin + backoff). Used by the sync thread's
    /// fold, which must make progress but only holds each lock briefly.
    pub fn read_spin(&self) {
        let mut backoff = Backoff::new();
        while !self.try_read() {
            backoff.snooze();
        }
    }

    /// Blocking write acquire (spin + backoff).
    pub fn write_spin(&self) {
        let mut backoff = Backoff::new();
        while !self.try_write() {
            backoff.snooze();
        }
    }

    /// Nobody holds the word (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn is_free(&self) -> bool {
        self.0.load(Ordering::Relaxed) == 0
    }

    /// Current reader count (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn readers(&self) -> u32 {
        self.0.load(Ordering::Relaxed) & MAX_READERS
    }

    /// A writer holds the word (test/diagnostic helper; racy by nature).
    #[inline]
    pub fn has_writer(&self) -> bool {
        self.0.load(Ordering::Relaxed) & WRITER != 0
    }
}

/// Bounded exponential backoff: spin-hint, then yield, then micro-sleep.
/// The progression caps so a long wait never turns into an unbounded spin.
pub struct Backoff {
    step: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff::new()
    }
}

impl Backoff {
    pub fn new() -> Backoff {
        Backoff { step: 0 }
    }

    /// Is the next snooze still in the cheap spin-hint phase?
    #[inline]
    pub fn is_spinning(&self) -> bool {
        self.step < 6
    }

    pub fn snooze(&mut self) {
        if self.step < 6 {
            for _ in 0..(1u32 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < 12 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(20));
        }
        if self.step < 13 {
            self.step += 1;
        }
    }

    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

/// The held **remote half** of a pipelined (split) scope acquisition — the
/// Distributed GraphLab Locking-Engine pattern (Low et al. 2012, §Locking
/// Engine) emulated over threads: a scope that crosses a shard boundary
/// first "requests" the locks owned by *remote* shards non-blocking and
/// all-or-nothing; if they are granted the worker keeps the remote half
/// held while it continues doing other local work, retrying the cheap
/// *local* half ([`SplitScope::try_complete`]) until the full scope is
/// assembled.
///
/// Deadlock discipline: the holder never *waits* while holding — it keeps
/// executing other non-blocking work between completion attempts, and the
/// engine bounds the number of attempts before abandoning (dropping this
/// guard releases the remote half). A holder must never enter a *blocking*
/// acquisition (`lock_scope`) while a `SplitScope` is live — that would
/// reintroduce hold-and-wait.
pub struct SplitScope<'a> {
    table: &'a LockTable,
    center: VertexId,
    model: ConsistencyModel,
    /// Remote-shard neighbors — locked (write under Full, read under Edge).
    remote: Vec<VertexId>,
    /// Local-shard neighbors — still unlocked.
    local: Vec<VertexId>,
    completed: bool,
}

impl LockTable {
    /// Pipelined/split scope acquisition, phase 1: partition `neighbors`
    /// by `is_remote` and lock only the **remote** subset, non-blocking and
    /// all-or-nothing (the first busy word rolls the subset back and
    /// reports the conflict — nothing stays held). On success the returned
    /// [`SplitScope`] holds the remote half; complete it with
    /// [`SplitScope::try_complete`].
    ///
    /// Under [`ConsistencyModel::Vertex`] the scope is the center alone, so
    /// both halves are empty and completion only needs the center lock.
    pub fn try_lock_split<'a>(
        &'a self,
        center: VertexId,
        neighbors: &[VertexId],
        model: ConsistencyModel,
        mut is_remote: impl FnMut(VertexId) -> bool,
    ) -> Result<SplitScope<'a>, Conflict> {
        let mut remote = Vec::new();
        let mut local = Vec::new();
        if model.excludes_neighbors() {
            for &u in neighbors {
                if is_remote(u) {
                    remote.push(u);
                } else {
                    local.push(u);
                }
            }
        }
        for (i, &u) in remote.iter().enumerate() {
            let ok = match model {
                ConsistencyModel::Full => self.locks[u as usize].try_write(),
                _ => self.locks[u as usize].try_read(),
            };
            if !ok {
                for &w in &remote[..i] {
                    match model {
                        ConsistencyModel::Full => self.locks[w as usize].unlock_write(),
                        _ => self.locks[w as usize].unlock_read(),
                    }
                }
                return Err(Conflict { vertex: u });
            }
        }
        Ok(SplitScope { table: self, center, model, remote, local, completed: false })
    }
}

impl<'a> SplitScope<'a> {
    pub fn center(&self) -> VertexId {
        self.center
    }

    /// Number of remote locks currently held.
    pub fn remote_held(&self) -> usize {
        self.remote.len()
    }

    /// Phase 2: try the **local** half (center write lock, then the
    /// locally-owned neighbors), non-blocking and all-or-nothing over that
    /// half. On success every lock of the full scope is held and a
    /// [`ScopeGuard`] over `full_neighbors` — the graph's lock-order slice,
    /// i.e. the union of both halves — is returned (dropping it releases
    /// the whole scope, remote locks included). On conflict the local half
    /// is rolled back, the remote half **stays held**, and `self` is handed
    /// back for another attempt.
    pub fn try_complete(
        mut self,
        full_neighbors: &'a [VertexId],
    ) -> Result<ScopeGuard<'a>, (SplitScope<'a>, Conflict)> {
        debug_assert!(
            !self.model.excludes_neighbors()
                || full_neighbors.len() == self.remote.len() + self.local.len(),
            "full_neighbors must be the union of the split halves"
        );
        let table = self.table;
        if !table.locks[self.center as usize].try_write() {
            let c = Conflict { vertex: self.center };
            return Err((self, c));
        }
        // Indexed loop: the conflict path moves `self` back to the caller,
        // which an iterator borrow of `self.local` would forbid.
        for i in 0..self.local.len() {
            let u = self.local[i];
            let ok = match self.model {
                ConsistencyModel::Full => table.locks[u as usize].try_write(),
                _ => table.locks[u as usize].try_read(),
            };
            if !ok {
                for &w in &self.local[..i] {
                    match self.model {
                        ConsistencyModel::Full => table.locks[w as usize].unlock_write(),
                        _ => table.locks[w as usize].unlock_read(),
                    }
                }
                table.locks[self.center as usize].unlock_write();
                let c = Conflict { vertex: u };
                return Err((self, c));
            }
        }
        self.completed = true;
        Ok(ScopeGuard {
            table,
            center: self.center,
            neighbors: full_neighbors,
            model: self.model,
        })
    }
}

impl Drop for SplitScope<'_> {
    fn drop(&mut self) {
        if self.completed {
            return; // locks transferred into the ScopeGuard
        }
        for &u in &self.remote {
            match self.model {
                ConsistencyModel::Full => self.table.locks[u as usize].unlock_write(),
                _ => self.table.locks[u as usize].unlock_read(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn word_is_four_bytes() {
        assert_eq!(std::mem::size_of::<ScopeLock>(), 4);
    }

    #[test]
    fn readers_share_writers_exclude() {
        let l = ScopeLock::new();
        assert!(l.try_read());
        assert!(l.try_read());
        assert_eq!(l.readers(), 2);
        assert!(!l.try_write(), "writer must not enter with readers present");
        l.unlock_read();
        assert!(!l.try_write());
        l.unlock_read();
        assert!(l.try_write());
        assert!(l.has_writer());
        assert!(!l.try_read(), "reader must not enter with a writer present");
        assert!(!l.try_write(), "write lock is exclusive");
        l.unlock_write();
        assert!(l.is_free());
    }

    #[test]
    fn spin_variants_eventually_acquire() {
        let l = Arc::new(ScopeLock::new());
        assert!(l.try_write());
        let l2 = Arc::clone(&l);
        let h = std::thread::spawn(move || {
            l2.read_spin();
            l2.unlock_read();
            l2.write_spin();
            l2.unlock_write();
        });
        std::thread::sleep(Duration::from_millis(2));
        l.unlock_write();
        h.join().unwrap();
        assert!(l.is_free());
    }

    #[test]
    fn split_acquisition_completes_and_releases() {
        let table = LockTable::new(6);
        let neighbors = [1u32, 2, 3, 4];
        // 3 and 4 are "remote"
        let split = table
            .try_lock_split(0, &neighbors, ConsistencyModel::Full, |u| u >= 3)
            .unwrap();
        assert_eq!(split.remote_held(), 2);
        assert_eq!(split.center(), 0);
        // remote half is actually held
        assert!(table.try_lock_scope(3, &[], ConsistencyModel::Vertex).is_err());
        let guard = split.try_complete(&neighbors).expect("free local half");
        assert_eq!(guard.len(), 5);
        assert_eq!(guard.writes(), 5);
        drop(guard);
        // everything released, full scope reacquirable
        let all = table.try_lock_scope(0, &neighbors, ConsistencyModel::Full).unwrap();
        assert_eq!(all.len(), 5);
    }

    #[test]
    fn split_remote_conflict_holds_nothing() {
        let table = LockTable::new(4);
        let held = table.try_lock_scope(3, &[], ConsistencyModel::Vertex).unwrap();
        let neighbors = [1u32, 2, 3];
        let c = table
            .try_lock_split(0, &neighbors, ConsistencyModel::Full, |u| u >= 2)
            .err()
            .expect("remote half must conflict on 3");
        assert_eq!(c.vertex, 3);
        drop(held);
        // nothing leaked: the whole scope is free
        assert!(table.try_lock_scope(0, &neighbors, ConsistencyModel::Full).is_ok());
    }

    #[test]
    fn split_local_conflict_keeps_remote_until_drop() {
        let table = LockTable::new(4);
        let neighbors = [1u32, 2, 3];
        let held = table.try_lock_scope(1, &[], ConsistencyModel::Vertex).unwrap();
        let split = table
            .try_lock_split(0, &neighbors, ConsistencyModel::Edge, |u| u == 3)
            .unwrap();
        assert_eq!(split.remote_held(), 1);
        let (split, c) = split.try_complete(&neighbors).err().expect("local 1 busy");
        assert_eq!(c.vertex, 1);
        // remote read lock on 3 still held after the failed completion
        assert_eq!(table.locks[3].readers(), 1);
        // local rollback left center + local neighbors free
        assert!(table.locks[0].is_free());
        assert!(table.locks[2].is_free());
        drop(split);
        assert!(table.locks[3].is_free(), "drop releases the remote half");
        drop(held);
        assert!(table.try_lock_scope(0, &neighbors, ConsistencyModel::Full).is_ok());
    }

    /// Two writers incrementing a counter through the lock never race.
    #[test]
    fn write_lock_serializes() {
        let l = Arc::new(ScopeLock::new());
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let l = Arc::clone(&l);
            let counter = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    l.write_spin();
                    // non-atomic read-modify-write protected by the lock
                    let v = counter.load(Ordering::Relaxed);
                    counter.store(v + 1, Ordering::Relaxed);
                    l.unlock_write();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 40_000);
    }
}
