//! **Data consistency models** (paper §3.3, Fig. 1b, Prop. 3.1).
//!
//! GraphLab guarantees that update functions never simultaneously share
//! overlapping *exclusion sets*:
//!
//! * [`ConsistencyModel::Full`] — while `f(v)` runs, no other function may
//!   read or modify anything in `S_v`: exclusion set = `{v} ∪ N(v)`, all
//!   write-locked. Parallelism only between vertices ≥ 2 hops apart.
//! * [`ConsistencyModel::Edge`] — no other function may read or modify `v`
//!   or its adjacent edges: write-lock `v`, read-lock `N(v)`. Parallelism
//!   between non-adjacent vertices.
//! * [`ConsistencyModel::Vertex`] — only `v` itself is protected
//!   (write-lock `v`). Neighboring updates may run simultaneously; adjacent
//!   reads/writes are the application's responsibility (the paper's stated
//!   caveat — used by CoEM and the relaxed Lasso experiment).
//!
//! Locks are compact word-per-vertex atomic reader–writer locks
//! ([`lock::ScopeLock`]). A scope is acquired **all-or-nothing**: the center
//! is write-locked first, then the neighbors in the caller-supplied order;
//! the first conflict rolls everything back and returns a [`Conflict`]
//! instead of blocking. Because no acquisition ever *holds-and-waits*,
//! deadlock is impossible regardless of lock order — which frees the caller
//! to pick a conflict-locality order (most-contended locks first, see
//! [`crate::graph::DataGraph::lock_neighbors`]) instead of the global
//! ascending-id order the old blocking protocol needed. Edge data `u -> v`
//! is guarded by its endpoint vertex locks.

pub mod lock;
mod scope;

pub use lock::{Backoff, ScopeLock, SplitScope};
pub use scope::Scope;

use crate::graph::VertexId;

/// Which consistency model the engine enforces (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConsistencyModel {
    /// Exclusion set `{v}` — maximum parallelism, racy neighborhood access.
    Vertex,
    /// Exclusion set `{v} ∪ adjacent edges` — the Loopy BP default.
    Edge,
    /// Exclusion set `S_v` — full sequential consistency for any update fn.
    Full,
}

impl ConsistencyModel {
    pub fn parse(s: &str) -> Option<ConsistencyModel> {
        match s {
            "vertex" => Some(ConsistencyModel::Vertex),
            "edge" => Some(ConsistencyModel::Edge),
            "full" => Some(ConsistencyModel::Full),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ConsistencyModel::Vertex => "vertex",
            ConsistencyModel::Edge => "edge",
            ConsistencyModel::Full => "full",
        }
    }

    /// Does the exclusion set of a scope at `v` extend to its neighbors?
    /// (Used by the discrete-event simulator's conflict model.)
    pub fn excludes_neighbors(&self) -> bool {
        !matches!(self, ConsistencyModel::Vertex)
    }
}

/// A failed scope try-acquire: `vertex` is the lock that could not be taken.
/// Everything acquired before it has already been rolled back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conflict {
    pub vertex: VertexId,
}

/// The locks held by one successfully acquired scope. Dropping the guard
/// releases every lock. No allocation: the guard only records the center,
/// the neighbor slice it locked, and the model (which determines the lock
/// kind per vertex).
pub struct ScopeGuard<'a> {
    table: &'a LockTable,
    center: VertexId,
    neighbors: &'a [VertexId],
    model: ConsistencyModel,
}

impl<'a> ScopeGuard<'a> {
    /// Number of locks held.
    pub fn len(&self) -> usize {
        match self.model {
            ConsistencyModel::Vertex => 1,
            _ => self.neighbors.len() + 1,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of write locks held (test helper).
    pub fn writes(&self) -> usize {
        match self.model {
            ConsistencyModel::Vertex | ConsistencyModel::Edge => 1,
            ConsistencyModel::Full => self.neighbors.len() + 1,
        }
    }
}

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        match self.model {
            ConsistencyModel::Vertex => {}
            ConsistencyModel::Edge => {
                for &u in self.neighbors {
                    self.table.locks[u as usize].unlock_read();
                }
            }
            ConsistencyModel::Full => {
                for &u in self.neighbors {
                    self.table.locks[u as usize].unlock_write();
                }
            }
        }
        self.table.locks[self.center as usize].unlock_write();
    }
}

/// A held single-vertex read lock (RAII), used by the sync fold.
pub struct ReadGuard<'a> {
    lock: &'a ScopeLock,
}

impl Drop for ReadGuard<'_> {
    fn drop(&mut self) {
        self.lock.unlock_read();
    }
}

/// A held single-vertex write lock (RAII).
pub struct WriteGuard<'a> {
    lock: &'a ScopeLock,
}

impl Drop for WriteGuard<'_> {
    fn drop(&mut self) {
        self.lock.unlock_write();
    }
}

/// Per-vertex atomic reader–writer lock table: 4 bytes per vertex.
pub struct LockTable {
    locks: Vec<ScopeLock>,
}

impl LockTable {
    pub fn new(num_vertices: usize) -> LockTable {
        LockTable { locks: (0..num_vertices).map(|_| ScopeLock::new()).collect() }
    }

    pub fn len(&self) -> usize {
        self.locks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locks.is_empty()
    }

    /// Bytes of lock state per vertex (for footprint reporting).
    pub const fn bytes_per_vertex() -> usize {
        std::mem::size_of::<ScopeLock>()
    }

    /// Blocking shared lock on a single vertex (spin + backoff). The sync
    /// thread's per-vertex fold uses this; scope acquisition never does.
    #[inline]
    pub fn read(&self, v: VertexId) -> ReadGuard<'_> {
        let lock = &self.locks[v as usize];
        lock.read_spin();
        ReadGuard { lock }
    }

    /// Blocking exclusive lock on a single vertex (spin + backoff).
    #[inline]
    pub fn write(&self, v: VertexId) -> WriteGuard<'_> {
        let lock = &self.locks[v as usize];
        lock.write_spin();
        WriteGuard { lock }
    }

    /// All-or-nothing scope try-acquire for center `v` with (unique,
    /// self-free) neighbor list `neighbors`, per `model`. On the first lock
    /// that cannot be taken, everything acquired so far is released and the
    /// conflicting vertex is returned — the caller never blocks and never
    /// holds a partial scope.
    ///
    /// `neighbors` may be in any order (rollback makes every order
    /// deadlock-free); passing [`crate::graph::DataGraph::lock_neighbors`]
    /// (descending degree) makes contended acquisitions fail fast.
    pub fn try_lock_scope<'a>(
        &'a self,
        v: VertexId,
        neighbors: &'a [VertexId],
        model: ConsistencyModel,
    ) -> Result<ScopeGuard<'a>, Conflict> {
        debug_assert!(!neighbors.contains(&v), "neighbors must exclude center");
        if !self.locks[v as usize].try_write() {
            return Err(Conflict { vertex: v });
        }
        if model.excludes_neighbors() {
            for (i, &u) in neighbors.iter().enumerate() {
                let ok = match model {
                    ConsistencyModel::Full => self.locks[u as usize].try_write(),
                    _ => self.locks[u as usize].try_read(),
                };
                if !ok {
                    // Roll back: release the neighbors taken so far + center.
                    for &w in &neighbors[..i] {
                        match model {
                            ConsistencyModel::Full => self.locks[w as usize].unlock_write(),
                            _ => self.locks[w as usize].unlock_read(),
                        }
                    }
                    self.locks[v as usize].unlock_write();
                    return Err(Conflict { vertex: u });
                }
            }
        }
        Ok(ScopeGuard { table: self, center: v, neighbors, model })
    }

    /// Blocking scope acquisition: retry [`Self::try_lock_scope`] under a
    /// bounded backoff. Because every round is still all-or-nothing with
    /// rollback (no hold-and-wait), concurrent blocking acquisitions cannot
    /// deadlock in any interleaving.
    ///
    /// This is the threaded engine's **deferral-fairness escalation path**:
    /// once a task's vertex has accumulated `EngineConfig::escalate_after`
    /// deferrals, its next dispatch comes through this call so it
    /// eventually wins against a saturated neighborhood, instead of
    /// bouncing through the retry deques forever.
    /// It is also the compatibility path for externally-driven callers
    /// (tests, micro-benchmarks).
    pub fn lock_scope<'a>(
        &'a self,
        v: VertexId,
        neighbors: &'a [VertexId],
        model: ConsistencyModel,
    ) -> ScopeGuard<'a> {
        let mut backoff = Backoff::new();
        loop {
            match self.try_lock_scope(v, neighbors, model) {
                Ok(guard) => return guard,
                Err(_) => backoff.snooze(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::forall;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn model_parse_roundtrip() {
        for m in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
            assert_eq!(ConsistencyModel::parse(m.name()), Some(m));
        }
        assert_eq!(ConsistencyModel::parse("bogus"), None);
    }

    #[test]
    fn vertex_model_allows_adjacent_scopes() {
        let table = LockTable::new(3);
        let g0 = table.lock_scope(0, &[1], ConsistencyModel::Vertex);
        // Under vertex consistency, a neighboring scope can be held at once.
        let g1 = table.lock_scope(1, &[0, 2], ConsistencyModel::Vertex);
        drop(g0);
        drop(g1);
    }

    #[test]
    fn edge_model_lock_kinds() {
        let table = LockTable::new(4);
        let guards = table.lock_scope(2, &[0, 3], ConsistencyModel::Edge);
        // center write + 2 neighbor reads
        assert_eq!(guards.len(), 3);
        assert_eq!(guards.writes(), 1);
        // Another edge scope centered on a non-adjacent vertex can coexist:
        // center 1, neighbors {0, 3} — read locks on 0,3 are shared.
        let g2 = table.lock_scope(1, &[0, 3], ConsistencyModel::Edge);
        drop(guards);
        drop(g2);
    }

    #[test]
    fn full_model_write_locks_everything() {
        let table = LockTable::new(4);
        let guards = table.lock_scope(1, &[0, 2, 3], ConsistencyModel::Full);
        assert_eq!(guards.writes(), 4);
        assert_eq!(guards.len(), 4);
    }

    #[test]
    fn try_lock_conflicts_and_rolls_back() {
        let table = LockTable::new(4);
        let held = table.try_lock_scope(2, &[1, 3], ConsistencyModel::Edge).unwrap();
        // Adjacent center under the edge model: 1 is read-locked by `held`,
        // so the write lock on center 1 must conflict.
        let c = table.try_lock_scope(1, &[0, 2], ConsistencyModel::Edge).err().expect("must conflict");
        assert_eq!(c.vertex, 1);
        // Full-model scope overlapping a read-locked neighbor: center 0 is
        // free, neighbor 1 conflicts — the rollback must leave 0 free again.
        let c = table.try_lock_scope(0, &[1], ConsistencyModel::Full).err().expect("must conflict");
        assert_eq!(c.vertex, 1);
        drop(held);
        // After rollback + release, the whole table is free.
        let all = table.try_lock_scope(1, &[0, 2, 3], ConsistencyModel::Full).unwrap();
        assert_eq!(all.writes(), 4);
    }

    #[test]
    fn try_lock_vertex_model_ignores_neighbors() {
        let table = LockTable::new(3);
        let _r = table.read(1); // reader on a neighbor
        let g = table.try_lock_scope(0, &[1, 2], ConsistencyModel::Vertex).unwrap();
        assert_eq!(g.len(), 1);
        // but an edge scope centered at 0 conflicts on the read-locked 1
        drop(g);
        let c = table.try_lock_scope(0, &[1, 2], ConsistencyModel::Full).err().expect("must conflict");
        assert_eq!(c.vertex, 1);
    }

    /// Hammer random overlapping scopes from several threads; all-or-nothing
    /// acquisition with rollback must terminate (no deadlock possible) and
    /// under Edge/Full no two adjacent centers may be active simultaneously.
    #[test]
    fn concurrent_scope_stress_no_deadlock_no_adjacent_centers() {
        let n = 32;
        let table = Arc::new(LockTable::new(n));
        // ring adjacency
        let neighbors: Arc<Vec<Vec<u32>>> = Arc::new(
            (0..n as u32)
                .map(|v| {
                    let mut ns =
                        vec![(v + 1) % n as u32, (v + n as u32 - 1) % n as u32];
                    ns.sort_unstable();
                    ns
                })
                .collect(),
        );
        let active: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let violations = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::new();
        for t in 0..4u64 {
            let table = Arc::clone(&table);
            let neighbors = Arc::clone(&neighbors);
            let active = Arc::clone(&active);
            let violations = Arc::clone(&violations);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::Pcg32::seed_from_u64(t);
                for _ in 0..2000 {
                    let v = rng.gen_range(n as u32);
                    let ns = &neighbors[v as usize];
                    let _guards = table.lock_scope(v, ns, ConsistencyModel::Edge);
                    active[v as usize].store(1, Ordering::SeqCst);
                    // Adjacent center active at the same time => edge-model violation.
                    for &u in ns {
                        if active[u as usize].load(Ordering::SeqCst) == 1 {
                            violations.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    active[v as usize].store(0, Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(violations.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn prop_guard_count_and_release() {
        forall(60, |g| {
            let n = g.usize_in(2..40);
            let table = LockTable::new(n);
            let v = g.usize_in(0..n) as u32;
            let nbrs: Vec<u32> = (0..n as u32).filter(|&u| u != v && g.bool()).collect();
            for model in
                [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full]
            {
                let guards = table.lock_scope(v, &nbrs, model);
                let want = match model {
                    ConsistencyModel::Vertex => 1,
                    _ => nbrs.len() + 1,
                };
                prop_assert!(
                    guards.len() == want,
                    "model {model:?}: {} guards, want {want}",
                    guards.len()
                );
                drop(guards);
                // every lock must be free again after release
                let refree = table.try_lock_scope(v, &nbrs, ConsistencyModel::Full);
                prop_assert!(refree.is_ok(), "locks leaked after {model:?} release");
            }
            Ok(())
        });
    }
}
