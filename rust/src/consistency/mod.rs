//! **Data consistency models** (paper §3.3, Fig. 1b, Prop. 3.1).
//!
//! GraphLab guarantees that update functions never simultaneously share
//! overlapping *exclusion sets*:
//!
//! * [`ConsistencyModel::Full`] — while `f(v)` runs, no other function may
//!   read or modify anything in `S_v`: exclusion set = `{v} ∪ N(v)`, all
//!   write-locked. Parallelism only between vertices ≥ 2 hops apart.
//! * [`ConsistencyModel::Edge`] — no other function may read or modify `v`
//!   or its adjacent edges: write-lock `v`, read-lock `N(v)`. Parallelism
//!   between non-adjacent vertices.
//! * [`ConsistencyModel::Vertex`] — only `v` itself is protected
//!   (write-lock `v`). Neighboring updates may run simultaneously; adjacent
//!   reads/writes are the application's responsibility (the paper's stated
//!   caveat — used by CoEM and the relaxed Lasso experiment).
//!
//! Locks are per-vertex reader–writer locks; a scope acquires the locks of
//! `{v} ∪ N(v)` in **ascending vertex-id order**, which makes the protocol
//! deadlock-free (all lock orders are consistent with one global total
//! order). Edge data `u -> v` is guarded by its endpoint vertex locks.

mod scope;

pub use scope::Scope;

use crate::graph::VertexId;
use std::sync::{RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Which consistency model the engine enforces (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConsistencyModel {
    /// Exclusion set `{v}` — maximum parallelism, racy neighborhood access.
    Vertex,
    /// Exclusion set `{v} ∪ adjacent edges` — the Loopy BP default.
    Edge,
    /// Exclusion set `S_v` — full sequential consistency for any update fn.
    Full,
}

impl ConsistencyModel {
    pub fn parse(s: &str) -> Option<ConsistencyModel> {
        match s {
            "vertex" => Some(ConsistencyModel::Vertex),
            "edge" => Some(ConsistencyModel::Edge),
            "full" => Some(ConsistencyModel::Full),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ConsistencyModel::Vertex => "vertex",
            ConsistencyModel::Edge => "edge",
            ConsistencyModel::Full => "full",
        }
    }

    /// Does the exclusion set of a scope at `v` extend to its neighbors?
    /// (Used by the discrete-event simulator's conflict model.)
    pub fn excludes_neighbors(&self) -> bool {
        !matches!(self, ConsistencyModel::Vertex)
    }
}

/// A held per-vertex lock (read or write).
pub enum Guard<'a> {
    Read(RwLockReadGuard<'a, ()>),
    Write(RwLockWriteGuard<'a, ()>),
}

/// The set of locks held by one scope. The vertex model holds exactly one
/// write guard — stored inline to keep the engine hot path allocation-free.
pub enum ScopeGuards<'a> {
    Single(Guard<'a>),
    Many(Vec<Guard<'a>>),
}

impl<'a> ScopeGuards<'a> {
    pub fn len(&self) -> usize {
        match self {
            ScopeGuards::Single(_) => 1,
            ScopeGuards::Many(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of write guards (test helper).
    pub fn writes(&self) -> usize {
        let count = |g: &Guard<'_>| matches!(g, Guard::Write(_)) as usize;
        match self {
            ScopeGuards::Single(g) => count(g),
            ScopeGuards::Many(v) => v.iter().map(count).sum(),
        }
    }
}

/// Per-vertex reader–writer lock table.
pub struct LockTable {
    locks: Vec<RwLock<()>>,
}

impl LockTable {
    pub fn new(num_vertices: usize) -> LockTable {
        LockTable { locks: (0..num_vertices).map(|_| RwLock::new(())).collect() }
    }

    pub fn len(&self) -> usize {
        self.locks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locks.is_empty()
    }

    #[inline]
    pub fn read(&self, v: VertexId) -> RwLockReadGuard<'_, ()> {
        self.locks[v as usize].read().unwrap()
    }

    #[inline]
    pub fn write(&self, v: VertexId) -> RwLockWriteGuard<'_, ()> {
        self.locks[v as usize].write().unwrap()
    }

    /// Acquire the scope locks for center `v` with (sorted, unique, self-free)
    /// neighbor list `neighbors`, per `model`. Guards are returned in
    /// acquisition order; dropping the vector releases every lock.
    ///
    /// Deadlock freedom: `{v} ∪ neighbors` is traversed in ascending id
    /// order, so all concurrent acquisitions agree on a global lock order.
    pub fn lock_scope<'a>(
        &'a self,
        v: VertexId,
        neighbors: &[VertexId],
        model: ConsistencyModel,
    ) -> ScopeGuards<'a> {
        debug_assert!(neighbors.windows(2).all(|w| w[0] < w[1]), "neighbors must be sorted");
        debug_assert!(!neighbors.contains(&v), "neighbors must exclude center");
        match model {
            ConsistencyModel::Vertex => ScopeGuards::Single(Guard::Write(self.write(v))),
            ConsistencyModel::Edge | ConsistencyModel::Full => {
                let mut guards = Vec::with_capacity(neighbors.len() + 1);
                let mut center_taken = false;
                for &u in neighbors {
                    if !center_taken && v < u {
                        guards.push(Guard::Write(self.write(v)));
                        center_taken = true;
                    }
                    guards.push(match model {
                        ConsistencyModel::Full => Guard::Write(self.write(u)),
                        _ => Guard::Read(self.read(u)),
                    });
                }
                if !center_taken {
                    guards.push(Guard::Write(self.write(v)));
                }
                ScopeGuards::Many(guards)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::prop_assert;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn model_parse_roundtrip() {
        for m in [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full] {
            assert_eq!(ConsistencyModel::parse(m.name()), Some(m));
        }
        assert_eq!(ConsistencyModel::parse("bogus"), None);
    }

    #[test]
    fn vertex_model_allows_adjacent_scopes() {
        let table = LockTable::new(3);
        let g0 = table.lock_scope(0, &[1], ConsistencyModel::Vertex);
        // Under vertex consistency, a neighboring scope can be held at once.
        let g1 = table.lock_scope(1, &[0, 2], ConsistencyModel::Vertex);
        drop(g0);
        drop(g1);
    }

    #[test]
    fn edge_model_lock_kinds() {
        let table = LockTable::new(4);
        let guards = table.lock_scope(2, &[0, 3], ConsistencyModel::Edge);
        // center write + 2 neighbor reads
        assert_eq!(guards.len(), 3);
        assert_eq!(guards.writes(), 1);
        // Another edge scope centered on a non-adjacent vertex can coexist:
        // center 1, neighbors {0, 3} — read locks on 0,3 are shared.
        let g2 = table.lock_scope(1, &[0, 3], ConsistencyModel::Edge);
        drop(guards);
        drop(g2);
    }

    #[test]
    fn full_model_write_locks_everything() {
        let table = LockTable::new(4);
        let guards = table.lock_scope(1, &[0, 2, 3], ConsistencyModel::Full);
        assert_eq!(guards.writes(), 4);
        assert_eq!(guards.len(), 4);
    }

    /// Hammer random overlapping scopes from several threads; with ordered
    /// acquisition this must terminate (deadlock would hang the test) and
    /// under Edge/Full no two adjacent centers may be active simultaneously.
    #[test]
    fn concurrent_scope_stress_no_deadlock_no_adjacent_centers() {
        let n = 32;
        let table = Arc::new(LockTable::new(n));
        // ring adjacency
        let neighbors: Arc<Vec<Vec<u32>>> = Arc::new(
            (0..n as u32)
                .map(|v| {
                    let mut ns =
                        vec![(v + 1) % n as u32, (v + n as u32 - 1) % n as u32];
                    ns.sort_unstable();
                    ns
                })
                .collect(),
        );
        let active: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let violations = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::new();
        for t in 0..4u64 {
            let table = Arc::clone(&table);
            let neighbors = Arc::clone(&neighbors);
            let active = Arc::clone(&active);
            let violations = Arc::clone(&violations);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::Pcg32::seed_from_u64(t);
                for _ in 0..2000 {
                    let v = rng.gen_range(n as u32);
                    let ns = &neighbors[v as usize];
                    let _guards = table.lock_scope(v, ns, ConsistencyModel::Edge);
                    active[v as usize].store(1, Ordering::SeqCst);
                    // Adjacent center active at the same time => edge-model violation.
                    for &u in ns {
                        if active[u as usize].load(Ordering::SeqCst) == 1 {
                            violations.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    active[v as usize].store(0, Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(violations.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn prop_guard_count_and_order() {
        forall(60, |g| {
            let n = g.usize_in(2..40);
            let table = LockTable::new(n);
            let v = g.usize_in(0..n) as u32;
            let mut nbrs: Vec<u32> = (0..n as u32).filter(|&u| u != v && g.bool()).collect();
            nbrs.sort_unstable();
            for model in
                [ConsistencyModel::Vertex, ConsistencyModel::Edge, ConsistencyModel::Full]
            {
                let guards = table.lock_scope(v, &nbrs, model);
                let want = match model {
                    ConsistencyModel::Vertex => 1,
                    _ => nbrs.len() + 1,
                };
                prop_assert!(
                    guards.len() == want,
                    "model {model:?}: {} guards, want {want}",
                    guards.len()
                );
                drop(guards);
            }
            Ok(())
        });
    }
}
