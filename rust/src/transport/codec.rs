//! Vertex (de)serialization for the ghost-sync transport layer.
//!
//! A [`VertexCodec`] turns a vertex data block into a flat little-endian
//! byte payload and back — the unit a real wire transport (socket, shared
//! memory ring) would ship. The in-crate [`super::ChannelTransport`]
//! exercises exactly this round-trip so that a future multi-process
//! backend only has to move the bytes, not re-invent the encoding.
//!
//! Encodings are deliberately boring: fixed-width little-endian scalars,
//! `u32` length prefixes for vectors, no framing inside the payload (the
//! [`super::GhostDelta`] wire frame carries the length). `decode` must
//! consume the payload exactly; trailing bytes are a corruption signal and
//! yield `None`.

/// Byte-encode / decode a vertex data block. Implemented for the app
/// vertex types that run on the sharded engine plus the primitive types
/// the test workloads use.
pub trait VertexCodec: Sized {
    /// Append the encoding of `self` to `buf`.
    fn encode(&self, buf: &mut Vec<u8>);

    /// Decode from exactly `bytes` (the full payload). `None` on any
    /// truncation, trailing garbage, or malformed content.
    fn decode(bytes: &[u8]) -> Option<Self>;

    /// Encoded size in bytes (allocates; prefer [`VertexCodec::encode`]
    /// into a reused buffer on hot paths).
    fn encoded_len(&self) -> usize {
        let mut buf = Vec::new();
        self.encode(&mut buf);
        buf.len()
    }
}

// ---- little-endian put helpers ------------------------------------------

/// Append one raw byte.
pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

/// Append a `u32`, little-endian.
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u64`, little-endian.
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an `f32`, little-endian IEEE-754 bits.
pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an `f64`, little-endian IEEE-754 bits.
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed `f32` slice.
pub fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u32(buf, vs.len() as u32);
    for &v in vs {
        put_f32(buf, v);
    }
}

/// Length-prefixed `u32` slice.
pub fn put_u32s(buf: &mut Vec<u8>, vs: &[u32]) {
    put_u32(buf, vs.len() as u32);
    for &v in vs {
        put_u32(buf, v);
    }
}

/// Cursor over a byte slice with checked little-endian reads. Every reader
/// returns `None` past the end instead of panicking — decode paths treat
/// truncation as data corruption, not a programming error.
pub struct ByteReader<'a> {
    bytes: &'a [u8],
}

impl<'a> ByteReader<'a> {
    /// Start a cursor at the beginning of `bytes`.
    pub fn new(bytes: &'a [u8]) -> ByteReader<'a> {
        ByteReader { bytes }
    }

    /// Has every byte been consumed?
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.bytes.len()
    }

    /// Take the next `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.bytes.len() < n {
            return None;
        }
        let (head, tail) = self.bytes.split_at(n);
        self.bytes = tail;
        Some(head)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Read a little-endian `f32`.
    pub fn f32(&mut self) -> Option<f32> {
        self.take(4).map(|b| f32::from_le_bytes(b.try_into().unwrap()))
    }

    /// Read a little-endian `f64`.
    pub fn f64(&mut self) -> Option<f64> {
        self.take(8).map(|b| f64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Length-prefixed `f32` vector (see [`put_f32s`]).
    pub fn f32s(&mut self) -> Option<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 4));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Some(out)
    }

    /// Length-prefixed `u32` vector (see [`put_u32s`]).
    pub fn u32s(&mut self) -> Option<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(self.remaining() / 4));
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Some(out)
    }
}

// ---- primitive impls (test workloads + simple apps) ----------------------

impl VertexCodec for u32 {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u32(buf, *self);
    }
    fn decode(bytes: &[u8]) -> Option<u32> {
        let mut r = ByteReader::new(bytes);
        let v = r.u32()?;
        r.is_empty().then_some(v)
    }
}

impl VertexCodec for u64 {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u64(buf, *self);
    }
    fn decode(bytes: &[u8]) -> Option<u64> {
        let mut r = ByteReader::new(bytes);
        let v = r.u64()?;
        r.is_empty().then_some(v)
    }
}

impl VertexCodec for f32 {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_f32(buf, *self);
    }
    fn decode(bytes: &[u8]) -> Option<f32> {
        let mut r = ByteReader::new(bytes);
        let v = r.f32()?;
        r.is_empty().then_some(v)
    }
}

impl VertexCodec for f64 {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_f64(buf, *self);
    }
    fn decode(bytes: &[u8]) -> Option<f64> {
        let mut r = ByteReader::new(bytes);
        let v = r.f64()?;
        r.is_empty().then_some(v)
    }
}

/// The `(round counter, fold accumulator)` pair the engine stress
/// workloads use as vertex data.
impl VertexCodec for (u64, u64) {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.0);
        put_u64(buf, self.1);
    }
    fn decode(bytes: &[u8]) -> Option<(u64, u64)> {
        let mut r = ByteReader::new(bytes);
        let a = r.u64()?;
        let b = r.u64()?;
        r.is_empty().then_some((a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        fn rt<T: VertexCodec + PartialEq + std::fmt::Debug>(v: T) {
            let mut buf = Vec::new();
            v.encode(&mut buf);
            assert_eq!(buf.len(), v.encoded_len());
            assert_eq!(T::decode(&buf), Some(v));
        }
        rt(0u32);
        rt(u32::MAX);
        rt(u64::MAX - 7);
        rt(-1.25f32);
        rt(1e300f64);
        rt((3u64, u64::MAX));
    }

    #[test]
    fn truncation_and_trailing_bytes_rejected() {
        let mut buf = Vec::new();
        7u64.encode(&mut buf);
        assert!(u64::decode(&buf[..7]).is_none(), "truncated");
        buf.push(0);
        assert!(u64::decode(&buf).is_none(), "trailing byte");
    }

    #[test]
    fn vector_helpers_round_trip() {
        let mut buf = Vec::new();
        put_f32s(&mut buf, &[1.0, 2.5, -3.0]);
        put_u32s(&mut buf, &[9, 8]);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.f32s(), Some(vec![1.0, 2.5, -3.0]));
        assert_eq!(r.u32s(), Some(vec![9, 8]));
        assert!(r.is_empty());
    }

    #[test]
    fn corrupt_length_prefix_fails_cleanly() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 1_000_000); // claims 1M floats, provides none
        let mut r = ByteReader::new(&buf);
        assert!(r.f32s().is_none());
    }
}
