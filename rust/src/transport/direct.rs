//! The **direct-memory backend**: PR 3's in-place ghost write, now routed
//! through the [`GhostTransport`] trait. `send` applies the delta to every
//! remote replica immediately (a versioned, locked copy) and ships zero
//! wire bytes; `drain` is a no-op; `pull` reads the owner's master data
//! directly (the caller holds the read lock) and stores it versioned —
//! no frames, no bytes, `served = false`. This is the fastest backend in
//! one address space and the semantic baseline the serializing backends
//! are tested against.
//!
//! Wire format: none. Version rules are those of the ghost table itself —
//! every write goes through `GhostEntry::store_versioned`, so
//! **newest-wins** holds here exactly as it does on the byte-moving
//! backends.

use super::{DrainReceipt, GhostTransport, PullReceipt, PullRequest, SendReceipt};
use crate::graph::{ShardedGraph, VertexId};

/// Ghost transport that writes replicas in place. Borrows the shard view
/// for the duration of the run.
pub struct DirectTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
}

impl<'g, V> DirectTransport<'g, V> {
    /// Wrap the shard view; replicas are written in place on `send`.
    pub fn new(graph: &'g ShardedGraph<V>) -> DirectTransport<'g, V> {
        DirectTransport { graph }
    }
}

impl<V: Clone + Send + Sync> GhostTransport<V> for DirectTransport<'_, V> {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn send(&self, _src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        crate::telemetry::instant(
            crate::telemetry::EventKind::WireSend,
            vertex as u64,
            version,
        );
        SendReceipt {
            replicas_now: self.graph.sync_vertex_versioned(vertex, data, version),
            bytes: 0,
        }
    }

    fn drain(&self, _dst_shard: usize) -> DrainReceipt {
        DrainReceipt::default()
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let Some(entry) = self.graph.shard(dst_shard).ghost_of(req.vertex) else {
            return PullReceipt::default();
        };
        let (data, version) = master(req.vertex);
        PullReceipt { applied: entry.store_versioned(data, version), served: false, bytes: 0 }
    }

    fn applies_at_send(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    #[test]
    fn send_applies_immediately_and_versions_stick() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = DirectTransport::new(&sg);
        let replicated: Vec<u32> =
            (0..8u32).filter(|&v| !sg.replicas_of(v).is_empty()).collect();
        assert!(!replicated.is_empty());
        let v = replicated[0];
        let r = t.send(sg.owner_of(v), v, 5, &999u64);
        assert_eq!(r.replicas_now as usize, sg.replicas_of(v).len());
        assert_eq!(r.bytes, 0, "direct backend ships no wire bytes");
        for &(s, gi) in sg.replicas_of(v) {
            let e = sg.shard(s as usize).ghost(gi as usize);
            assert_eq!(e.read(), 999);
            assert_eq!(e.version(), 5);
            assert_eq!(e.pending_version(), 5);
        }
        // an older version is rejected, a newer one applies
        assert_eq!(t.send(sg.owner_of(v), v, 3, &111u64).replicas_now, 0);
        assert_eq!(
            t.send(sg.owner_of(v), v, 6, &1000u64).replicas_now as usize,
            sg.replicas_of(v).len()
        );
        assert_eq!(t.drain(0).applied, 0, "drain is a no-op");
    }
}
