//! The **shared-memory ring backend**: per-shard-pair lock-free SPSC byte
//! rings over process-shareable memory — the same-host fast lane between
//! the in-process [`ChannelTransport`](super::ChannelTransport) and the
//! kernel-socket [`SocketTransport`](super::SocketTransport).
//!
//! Each ordered shard pair `src → dst` owns one [`ShmRing`]: a
//! power-of-two byte ring whose backing region is a memory-mapped shared
//! file on Linux (`MAP_SHARED`, unlinked immediately after mapping — the
//! layout a forked-shard topology can adopt unchanged), with an aligned
//! heap allocation as the portable fallback. The region starts with two
//! cache-line-padded monotonic `u64` cursors:
//!
//! ```text
//! | head (consumer, 64 B line) | tail (producer, 64 B line) | data: 2^n bytes |
//! ```
//!
//! The producer copies a whole frame in (two-part copy across the wrap
//! seam) and only then advances `tail` with a release store — **batch
//! publication of whole frames**, so the consumer's acquire load of
//! `tail` can never observe a torn frame. The consumer copies every
//! published byte out and retires it with a release store of `head`.
//! Between the two sides the ring is lock-free; because several workers
//! of one shard share each side, the transport serializes *same-side*
//! access with a per-ring producer mutex and consumer mutex (never held
//! across the ring — producer and consumer still run concurrently).
//!
//! A full ring is **backpressure**: the sender spins, then yields, then
//! sleeps (counted in [`GhostTransport::backpressure_stalls`]), and
//! periodically drains its own shard's inbound rings while it waits so
//! two shards saturating each other's rings cannot deadlock. Staleness
//! pulls ride dedicated request/reply ring pairs per ordered shard pair,
//! and [`GhostTransport::pull_many`] pipelines a batch: every request
//! frame crosses the request ring before the first reply is served, so a
//! batch of stale ghosts costs one lane acquisition instead of N
//! round-trips ([`ShmTransport::pulls_pipelined`] counts the batched
//! requests).
//!
//! Delta frames are the raw wire format (`u32 vertex, u64 version, u32
//! len, payload`); pull frames are raw on every backend. Frames are
//! self-contained, so `drain` moves the published bytes out under the
//! consumer mutex and decodes outside it, exactly like the raw channel
//! and socket paths.

use super::{
    ByteReader, DrainReceipt, GhostDelta, GhostTransport, PullReceipt, PullRequest, SendReceipt,
    VertexCodec,
};
use crate::graph::{ShardedGraph, VertexId};
use crate::telemetry::{self, EventKind};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default per-pair delta-ring capacity (bytes, power of two). Small
/// enough that a `k × k` mesh stays modest, large enough that the
/// periodic drain tick — not ring exhaustion — is the normal consumer.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 18;

/// Pull request/reply rings are small: requests are fixed 12-byte frames
/// and replies are drained by the same thread that serves them.
const PULL_RING_CAPACITY: usize = 1 << 16;

/// Pull requests put in flight per pipelined wave — bounded so a wave of
/// encoded requests always fits the request ring with room to spare.
const PULL_PIPELINE_MAX: usize = 256;

/// Spin iterations in a backpressure stall before each sleep; every
/// [`STALL_SELF_DRAIN`] iterations the stalled sender drains its own
/// shard's inbound rings to break send/send cycles between shard pairs.
const STALL_SPINS: u32 = 64;
const STALL_SELF_DRAIN: u32 = 256;

/// Bytes reserved at the start of the shared region for the two
/// cache-line-padded cursors.
const HEADER_BYTES: usize = 128;
const CACHE_LINE: usize = 64;

#[cfg(target_os = "linux")]
mod mm {
    //! Minimal `mmap` shim over the libc the Rust runtime already links.
    //! No new dependency: just the two syscall wrappers and the three
    //! flag constants the ring needs.
    use std::fs::OpenOptions;
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicU64, Ordering};

    const PROT_READ: i32 = 0x1;
    const PROT_WRITE: i32 = 0x2;
    const MAP_SHARED: i32 = 0x01;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    static SEQ: AtomicU64 = AtomicU64::new(0);

    /// Map `len` zeroed, process-shareable bytes backed by an unlinked
    /// temp file. `None` on any failure (the caller falls back to heap).
    pub(super) fn map_shared(len: usize) -> Option<*mut u8> {
        let path = std::env::temp_dir().join(format!(
            "graphlab-shm-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .ok()?;
        let mapped = file.set_len(len as u64).ok().map(|()| unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        });
        // The path only exists to establish the mapping: unlink it now so
        // nothing leaks even on abort. The mapping survives both the
        // unlink and the fd close.
        let _ = std::fs::remove_file(&path);
        let ptr = mapped?;
        if ptr as isize == -1 {
            return None;
        }
        Some(ptr as *mut u8)
    }

    pub(super) fn unmap(ptr: *mut u8, len: usize) {
        unsafe { munmap(ptr as *mut core::ffi::c_void, len) };
    }
}

/// How the shared region is backed.
enum Backing {
    /// Memory-mapped shared file (Linux fast path).
    #[cfg(target_os = "linux")]
    Mapped { len: usize },
    /// Cache-line-aligned heap allocation (portable fallback).
    Heap { layout: std::alloc::Layout },
}

/// The region shared by one producer/consumer pair: two padded cursors
/// plus the data bytes. Only ever touched through the split handles.
struct RingShared {
    base: *mut u8,
    cap: usize,
    backing: Backing,
}

// SAFETY: the region is plain bytes plus two AtomicU64 cursors; all
// cross-thread publication goes through those atomics (release stores of
// `tail`/`head`, acquire loads on the opposite side), and the split
// handles guarantee a single producer and a single consumer (`&mut self`
// on every mutating method).
unsafe impl Send for RingShared {}
unsafe impl Sync for RingShared {}

impl RingShared {
    fn new(capacity: usize) -> RingShared {
        let cap = capacity.next_power_of_two().max(4096);
        let len = HEADER_BYTES + cap;
        #[cfg(target_os = "linux")]
        if let Some(base) = mm::map_shared(len) {
            return RingShared { base, cap, backing: Backing::Mapped { len } };
        }
        let layout = std::alloc::Layout::from_size_align(len, CACHE_LINE).unwrap();
        let base = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!base.is_null(), "shm ring allocation failed");
        RingShared { base, cap, backing: Backing::Heap { layout } }
    }

    fn head(&self) -> &AtomicU64 {
        // SAFETY: base is valid for the whole region, 64-byte aligned
        // (page-aligned mmap or CACHE_LINE-aligned alloc), and offset 0
        // holds the consumer cursor.
        unsafe { &*(self.base as *const AtomicU64) }
    }

    fn tail(&self) -> &AtomicU64 {
        // SAFETY: as `head`, one cache line in.
        unsafe { &*(self.base.add(CACHE_LINE) as *const AtomicU64) }
    }

    fn data(&self) -> *mut u8 {
        // SAFETY: the data region starts after the two cursor lines.
        unsafe { self.base.add(HEADER_BYTES) }
    }

    fn readable(&self) -> usize {
        let tail = self.tail().load(Ordering::Acquire);
        let head = self.head().load(Ordering::Acquire);
        (tail - head) as usize
    }
}

impl Drop for RingShared {
    fn drop(&mut self) {
        match self.backing {
            #[cfg(target_os = "linux")]
            Backing::Mapped { len } => mm::unmap(self.base, len),
            Backing::Heap { layout } => unsafe { std::alloc::dealloc(self.base, layout) },
        }
    }
}

/// Producer half of an SPSC [`ShmRing`]. `&mut self` on the mutating
/// method keeps the single-producer contract in the type system; clone-
/// free whole-frame publication means a reader never sees a torn frame.
pub struct ShmProducer {
    ring: Arc<RingShared>,
}

/// Consumer half of an SPSC [`ShmRing`].
pub struct ShmConsumer {
    ring: Arc<RingShared>,
}

/// Create one shared-memory SPSC byte ring of (at least) `capacity`
/// bytes — rounded up to a power of two — and split it into its producer
/// and consumer handles. The backing region is a memory-mapped shared
/// file on Linux, an aligned heap block elsewhere.
pub fn shm_ring(capacity: usize) -> (ShmProducer, ShmConsumer) {
    let ring = Arc::new(RingShared::new(capacity));
    (ShmProducer { ring: Arc::clone(&ring) }, ShmConsumer { ring })
}

impl ShmProducer {
    /// Data capacity in bytes (power of two).
    pub fn capacity(&self) -> usize {
        self.ring.cap
    }

    /// Bytes currently published and not yet consumed.
    pub fn readable_bytes(&self) -> usize {
        self.ring.readable()
    }

    /// Publish one whole frame. Returns `false` (publishing nothing) when
    /// the frame does not currently fit — the caller's backpressure path.
    /// A frame larger than the ring capacity can never fit.
    pub fn try_push(&mut self, frame: &[u8]) -> bool {
        let r = &*self.ring;
        if frame.len() > r.cap {
            return false;
        }
        let head = r.head().load(Ordering::Acquire);
        // Relaxed: this handle is the only writer of `tail`.
        let tail = r.tail().load(Ordering::Relaxed);
        let free = r.cap - (tail - head) as usize;
        if frame.len() > free {
            return false;
        }
        let at = tail as usize & (r.cap - 1);
        let first = frame.len().min(r.cap - at);
        // SAFETY: [at, at + first) and [0, len - first) are inside the
        // data region, and the occupancy check above proves the consumer
        // is not reading them.
        unsafe {
            std::ptr::copy_nonoverlapping(frame.as_ptr(), r.data().add(at), first);
            std::ptr::copy_nonoverlapping(
                frame.as_ptr().add(first),
                r.data(),
                frame.len() - first,
            );
        }
        // Whole-frame publication: the release store is the only point
        // the consumer can observe the new bytes.
        r.tail().store(tail + frame.len() as u64, Ordering::Release);
        true
    }
}

impl ShmConsumer {
    /// Data capacity in bytes (power of two).
    pub fn capacity(&self) -> usize {
        self.ring.cap
    }

    /// Bytes currently published and not yet consumed.
    pub fn readable_bytes(&self) -> usize {
        self.ring.readable()
    }

    /// Move every published byte into `out` (appending) and retire it.
    /// Returns the bytes moved. Because producers publish whole frames,
    /// the bytes always parse as a sequence of complete frames.
    pub fn pop_all(&mut self, out: &mut Vec<u8>) -> usize {
        let r = &*self.ring;
        let tail = r.tail().load(Ordering::Acquire);
        // Relaxed: this handle is the only writer of `head`.
        let head = r.head().load(Ordering::Relaxed);
        let avail = (tail - head) as usize;
        if avail == 0 {
            return 0;
        }
        let at = head as usize & (r.cap - 1);
        let first = avail.min(r.cap - at);
        // SAFETY: the published range is initialized and the producer
        // never overwrites bytes the consumer has not retired.
        unsafe {
            out.extend_from_slice(std::slice::from_raw_parts(r.data().add(at), first));
            out.extend_from_slice(std::slice::from_raw_parts(r.data(), avail - first));
        }
        // Retire: the release store lets the producer reuse the space.
        r.head().store(tail, Ordering::Release);
        avail
    }
}

/// One ordered-pair pull lane: a request ring and a reply ring plus the
/// scratch buffers both ends reuse. The lane mutex serializes whole
/// exchanges; the rings still move every byte through the shared region.
struct PullLane {
    req_tx: ShmProducer,
    req_rx: ShmConsumer,
    rep_tx: ShmProducer,
    rep_rx: ShmConsumer,
    req_buf: Vec<u8>,
    rep_buf: Vec<u8>,
}

impl PullLane {
    fn new() -> PullLane {
        let (req_tx, req_rx) = shm_ring(PULL_RING_CAPACITY);
        let (rep_tx, rep_rx) = shm_ring(PULL_RING_CAPACITY);
        PullLane { req_tx, req_rx, rep_tx, rep_rx, req_buf: Vec::new(), rep_buf: Vec::new() }
    }
}

/// Push a frame onto a pull-lane ring, spinning if it is momentarily
/// full. Pull lanes are drained by the same locked exchange that fills
/// them, so a full ring here is transient by construction.
fn lane_push(tx: &mut ShmProducer, frame: &[u8]) {
    while !tx.try_push(frame) {
        std::hint::spin_loop();
    }
}

/// Ghost transport over `k × k` shared-memory SPSC rings
/// (`ring[src * k + dst]`) plus per-ordered-pair pull lanes. See the
/// module docs for the ring layout and backpressure semantics.
pub struct ShmTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
    k: usize,
    /// Producer halves, indexed `src * k + dst`; the mutex serializes the
    /// sending shard's workers, not the ring's two sides.
    producers: Vec<Mutex<ShmProducer>>,
    /// Consumer halves, indexed `src * k + dst`; the mutex serializes the
    /// receiving shard's workers.
    consumers: Vec<Mutex<ShmConsumer>>,
    /// Pull lanes, indexed `requester * k + owner`.
    pulls: Vec<Mutex<PullLane>>,
    backpressure: AtomicU64,
    pipelined: AtomicU64,
}

impl<'g, V> ShmTransport<'g, V> {
    /// Set up the `k × k` delta rings and pull lanes for `graph` with the
    /// default ring capacity.
    pub fn new(graph: &'g ShardedGraph<V>) -> ShmTransport<'g, V> {
        ShmTransport::with_ring_capacity(graph, DEFAULT_RING_CAPACITY)
    }

    /// Like [`ShmTransport::new`] with an explicit per-pair delta-ring
    /// capacity (rounded up to a power of two). Small rings exercise the
    /// wraparound and backpressure paths; the capacity must exceed the
    /// largest delta frame.
    pub fn with_ring_capacity(graph: &'g ShardedGraph<V>, capacity: usize) -> ShmTransport<'g, V> {
        let k = graph.num_shards();
        let mut producers = Vec::with_capacity(k * k);
        let mut consumers = Vec::with_capacity(k * k);
        for _ in 0..k * k {
            let (tx, rx) = shm_ring(capacity);
            producers.push(Mutex::new(tx));
            consumers.push(Mutex::new(rx));
        }
        ShmTransport {
            graph,
            k,
            producers,
            consumers,
            pulls: (0..k * k).map(|_| Mutex::new(PullLane::new())).collect(),
            backpressure: AtomicU64::new(0),
            pipelined: AtomicU64::new(0),
        }
    }

    /// Pull requests that crossed a lane as part of a pipelined wave
    /// (more than one request in flight on the lane at once).
    pub fn pulls_pipelined(&self) -> u64 {
        self.pipelined.load(Ordering::Relaxed)
    }
}

impl<V: VertexCodec + Clone + Send + Sync> ShmTransport<'_, V> {
    /// Decode and apply one batch of raw delta frames to `dst_shard`'s
    /// ghost table (newest version wins).
    fn apply_frames(&self, dst_shard: usize, buf: &[u8], out: &mut DrainReceipt) {
        let shard = self.graph.shard(dst_shard);
        out.bytes += buf.len() as u64;
        let mut r = ByteReader::new(buf);
        while !r.is_empty() {
            let Some(delta) = GhostDelta::decode_from(&mut r) else {
                debug_assert!(false, "torn frame left the shm ring toward {dst_shard}");
                break;
            };
            let Some(value) = delta.decode_vertex::<V>() else {
                debug_assert!(false, "codec round-trip failed for vertex {}", delta.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(delta.vertex) {
                if entry.store_versioned(&value, delta.version) {
                    out.applied += 1;
                    telemetry::instant(EventKind::WireApply, delta.vertex as u64, delta.version);
                }
            }
        }
    }

    /// One owner-group pipelined pull wave: every request frame crosses
    /// the request ring before the first reply is served, then replies
    /// stream back through the reply ring and apply in request order.
    fn pull_wave<'m>(
        &self,
        dst_shard: usize,
        owner: usize,
        reqs: &[PullRequest],
        receipts: &mut [PullReceipt],
        idxs: &[usize],
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) {
        let mut lane = self.pulls[dst_shard * self.k + owner].lock().unwrap();
        for wave in idxs.chunks(PULL_PIPELINE_MAX) {
            // Phase 1 — requester side: put the whole wave in flight.
            let mut frame = Vec::with_capacity(PullRequest::WIRE_LEN);
            for &i in wave {
                frame.clear();
                reqs[i].encode_into(&mut frame);
                lane_push(&mut lane.req_tx, &frame);
                receipts[i].bytes += PullRequest::WIRE_LEN as u64;
            }
            if wave.len() > 1 {
                self.pipelined.fetch_add(wave.len() as u64, Ordering::Relaxed);
            }
            // Phase 2 — owner side: drain the request batch off the ring
            // and serve each fixed-size request in order.
            lane.req_buf.clear();
            let PullLane { req_tx: _, req_rx, rep_tx, rep_rx, req_buf, rep_buf } = &mut *lane;
            req_rx.pop_all(req_buf);
            debug_assert_eq!(req_buf.len(), wave.len() * PullRequest::WIRE_LEN);
            rep_buf.clear();
            for raw in req_buf.chunks_exact(PullRequest::WIRE_LEN) {
                let Some(reply) = super::serve_pull::<V>(raw, master) else {
                    debug_assert!(false, "corrupt pull request on {dst_shard}->{owner}");
                    continue;
                };
                lane_push(rep_tx, &reply);
                // Requester side drains eagerly (same thread plays both
                // ends), so the reply ring never fills mid-wave.
                rep_rx.pop_all(rep_buf);
            }
            // Phase 3 — requester side: apply the reply stream in order.
            let mut rest: &[u8] = rep_buf;
            for &i in wave {
                if rest.len() < 16 {
                    debug_assert!(rest.is_empty(), "truncated pull reply on {owner}->{dst_shard}");
                    break;
                }
                let payload_len =
                    u32::from_le_bytes([rest[12], rest[13], rest[14], rest[15]]) as usize;
                let frame_len = 16 + payload_len;
                let (raw, after) = rest.split_at(frame_len.min(rest.len()));
                rest = after;
                let Some(applied) = super::apply_pull_reply(self.graph, dst_shard, raw) else {
                    debug_assert!(false, "corrupt pull reply on {owner}->{dst_shard}");
                    continue;
                };
                receipts[i].applied = applied;
                receipts[i].served = true;
                receipts[i].bytes += raw.len() as u64;
            }
        }
    }
}

impl<V: VertexCodec + Clone + Send + Sync> GhostTransport<V> for ShmTransport<'_, V> {
    fn name(&self) -> &'static str {
        "shm"
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let sites = self.graph.replicas_of(vertex);
        if sites.is_empty() {
            return SendReceipt::default();
        }
        telemetry::instant(EventKind::WireSend, vertex as u64, version);
        let delta = GhostDelta::from_vertex(vertex, version, data);
        let mut frame = Vec::with_capacity(delta.wire_len());
        delta.encode_into(&mut frame);
        let mut bytes = 0u64;
        for &(s, gi) in sites {
            // Advance the pending slot before the bytes are published so
            // a staleness probe never sees an unaccounted in-flight
            // version.
            self.graph.shard(s as usize).ghost(gi as usize).note_pending(version);
            let mut tx = self.producers[src_shard * self.k + s as usize].lock().unwrap();
            assert!(
                frame.len() <= tx.capacity(),
                "delta frame ({} B) exceeds shm ring capacity ({} B)",
                frame.len(),
                tx.capacity()
            );
            if !tx.try_push(&frame) {
                // Backpressure: spin, then yield, then sleep; drain our
                // own inbound rings periodically so two shards saturating
                // each other's rings cannot deadlock.
                self.backpressure.fetch_add(1, Ordering::Relaxed);
                let span = telemetry::span_start();
                let mut iters = 0u32;
                while !tx.try_push(&frame) {
                    iters += 1;
                    if iters % STALL_SELF_DRAIN == 0 {
                        self.drain(src_shard);
                    }
                    if iters < STALL_SPINS {
                        std::hint::spin_loop();
                    } else if iters < STALL_SPINS * 2 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
                telemetry::span_end(
                    EventKind::Backpressure,
                    span,
                    vertex as u64,
                    frame.len() as u64,
                );
            }
            bytes += frame.len() as u64;
        }
        SendReceipt { replicas_now: 0, bytes }
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let mut out = DrainReceipt::default();
        let mut buf = Vec::new();
        for src in 0..self.k {
            buf.clear();
            {
                let mut rx = self.consumers[src * self.k + dst_shard].lock().unwrap();
                rx.pop_all(&mut buf);
            }
            if buf.is_empty() {
                continue;
            }
            // Raw frames are self-contained: decode outside the consumer
            // mutex (newest-wins makes cross-worker interleaving safe).
            self.apply_frames(dst_shard, &buf, &mut out);
        }
        out
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let owner = self.graph.owner_of(req.vertex);
        if owner == dst_shard {
            return PullReceipt::default();
        }
        let mut receipts = [PullReceipt::default()];
        self.pull_wave(dst_shard, owner, &[req], &mut receipts, &[0], master);
        receipts[0]
    }

    fn pull_many<'m>(
        &self,
        dst_shard: usize,
        reqs: &[PullRequest],
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> Vec<PullReceipt> {
        let mut receipts = vec![PullReceipt::default(); reqs.len()];
        let mut by_owner: Vec<Vec<usize>> = vec![Vec::new(); self.k];
        for (i, req) in reqs.iter().enumerate() {
            let owner = self.graph.owner_of(req.vertex);
            if owner != dst_shard {
                by_owner[owner].push(i);
            }
        }
        for (owner, idxs) in by_owner.iter().enumerate() {
            if !idxs.is_empty() {
                self.pull_wave(dst_shard, owner, reqs, &mut receipts, idxs, master);
            }
        }
        receipts
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        (0..self.k)
            .map(|src| {
                self.consumers[src * self.k + dst_shard].lock().unwrap().readable_bytes() as u64
            })
            .sum()
    }

    // Publication is synchronous — `send` returns only after the frame is
    // drainable — so the default no-op `finalize` is already a barrier.

    fn backpressure_stalls(&self) -> u64 {
        self.backpressure.load(Ordering::Relaxed)
    }

    fn drain_tick_bounds(&self) -> (u64, u64) {
        // Draining an shm ring is two atomic loads plus a memcpy — far
        // cheaper than the socket's inbox path — so the adaptive tick may
        // both start and stay much tighter without throttling senders.
        (4, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    #[test]
    fn ring_round_trips_across_the_wrap_seam() {
        let (mut tx, mut rx) = shm_ring(4096);
        assert_eq!(tx.capacity(), 4096);
        // Frames of a length coprime to the capacity force every wrap
        // offset over enough iterations.
        let frame: Vec<u8> = (0..96u8).map(|b| b ^ 0x5a).collect();
        let mut out = Vec::new();
        for round in 0..200 {
            for _ in 0..3 {
                assert!(tx.try_push(&frame));
            }
            out.clear();
            assert_eq!(rx.pop_all(&mut out), 3 * frame.len(), "round {round}");
            for got in out.chunks_exact(frame.len()) {
                assert_eq!(got, &frame[..]);
            }
        }
        assert_eq!(rx.readable_bytes(), 0);
    }

    #[test]
    fn full_ring_rejects_then_accepts_after_pop() {
        let (mut tx, mut rx) = shm_ring(4096);
        let frame = [7u8; 1024];
        assert!(tx.try_push(&frame));
        assert!(tx.try_push(&frame));
        assert!(tx.try_push(&frame));
        assert!(tx.try_push(&frame));
        assert!(!tx.try_push(&frame), "ring full");
        assert!(!tx.try_push(&[0u8; 8192]), "frame larger than capacity never fits");
        let mut out = Vec::new();
        assert_eq!(rx.pop_all(&mut out), 4096);
        assert!(tx.try_push(&frame), "space reclaimed after pop");
    }

    #[test]
    fn deltas_cross_the_ring_and_apply_on_drain() {
        let mut g = chain(8);
        let sg = crate::graph::ShardedGraph::new(&mut g, 2);
        let t = ShmTransport::new(&sg);
        assert_eq!(GhostTransport::name(&t), "shm");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        let r = t.send(owner, v, 4, &777u64);
        assert_eq!(r.replicas_now, 0, "shm applies at drain, not send");
        assert_eq!(r.bytes, 24);
        assert_eq!(entry.version(), 0, "not yet applied");
        assert_eq!(entry.pending_version(), 4, "in-flight version visible");
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 24);

        let d = t.drain(dst as usize);
        assert_eq!(d.applied, 1);
        assert_eq!(d.bytes, 24);
        assert_eq!(entry.read(), 777, "payload round-tripped through the codec");
        assert_eq!(entry.version(), 4);
        assert_eq!(GhostTransport::queued_bytes(&t, dst as usize), 0);
        assert_eq!(t.drain(dst as usize).applied, 0, "ring drained");
    }

    #[test]
    fn tiny_ring_backpressures_until_the_consumer_drains() {
        let mut g = chain(8);
        let sg = crate::graph::ShardedGraph::new(&mut g, 2);
        // 4096 B is the minimum ring; fill it so the next send stalls.
        let t = std::sync::Arc::new(ShmTransport::with_ring_capacity(&sg, 4096));
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let dst = sg.replicas_of(v)[0].0 as usize;
        for ver in 0..4096 / 24 {
            t.send(owner, v, ver + 1, &ver);
        }
        assert_eq!(t.backpressure_stalls(), 0, "ring exactly at capacity, no stall yet");
        std::thread::scope(|s| {
            let tt = std::sync::Arc::clone(&t);
            let h = s.spawn(move || tt.send(owner, v, 9999, &9999u64));
            while t.backpressure_stalls() == 0 {
                std::thread::yield_now();
            }
            let d = t.drain(dst);
            assert!(d.applied >= 1);
            h.join().unwrap();
        });
        assert!(t.backpressure_stalls() >= 1);
        t.drain(dst);
    }

    #[test]
    fn pull_round_trips_request_and_reply_frames() {
        let mut g = chain(8);
        let sg = crate::graph::ShardedGraph::new(&mut g, 2);
        let t = ShmTransport::new(&sg);
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        let master_val = 4242u64;
        let r = t.pull(dst as usize, PullRequest { vertex: v, min_version: 6 }, &|u| {
            assert_eq!(u, v);
            (&master_val, 6)
        });
        assert!(r.served, "request and reply crossed the rings");
        assert!(r.applied);
        assert_eq!(r.bytes, 12 + 24);
        assert_eq!(entry.read(), 4242);
        assert_eq!(entry.version(), 6);
        // same-shard pulls never touch a lane
        let r = t.pull(owner, PullRequest { vertex: v, min_version: 0 }, &|_| (&master_val, 0));
        assert!(!r.served);
    }

    #[test]
    fn shm_drain_tick_bounds_are_tighter_than_the_socket_default() {
        let mut g = chain(8);
        let sg = crate::graph::ShardedGraph::new(&mut g, 2);
        let t = ShmTransport::new(&sg);
        let (min, max) = GhostTransport::drain_tick_bounds(&t);
        assert!(max < 512, "shm must not inherit socket-era drain backoff");
        assert!(min <= 8);
    }
}
