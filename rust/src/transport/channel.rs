//! The **serialized channel backend**: per-shard-pair byte queues that
//! really encode and decode every delta — the in-process stand-in for a
//! socket or shared-memory ring. `send` frames the [`GhostDelta`] onto the
//! `src → dst` queue of every destination shard holding a replica
//! (wire format: `u32 vertex, u64 version, u32 len, payload`);
//! `drain(dst)` consumes the queues addressed to `dst`, decodes each
//! payload through the [`VertexCodec`], and applies it to the shard's
//! ghost table (**newest version wins**, so reordered flushes from
//! different workers are harmless). Every hop validates the codec
//! round-trip a real multi-process deployment would depend on.
//!
//! [`ChannelTransport::compressed`] switches the delta lanes to the
//! compressed frame format of [`super::encode_delta`]: each lane keeps a
//! *sender shadow* (last payload shipped per vertex) and a *receiver
//! shadow* (last payload decoded per vertex), and frames diff against the
//! shadow word-by-word with a varint header. The two shadows stay in
//! lockstep because a lane is strict FIFO and compressed frames are
//! encoded **and decoded under the lane lock** — decoding outside the
//! lock (as the raw path does for throughput) could interleave two
//! workers' drained chunks and desync the shadows. The receiver shadow is
//! updated on *every* frame, including deltas that lose the newest-wins
//! race, because the sender's shadow advanced when it shipped them. Pull
//! lanes stay raw in both modes.
//!
//! Staleness pulls ride dedicated **request/reply lanes** per ordered
//! shard pair: the requester frames a fixed-size [`PullRequest`] onto the
//! lane's request queue, the owner side decodes it, serves the master
//! data as an ordinary delta frame on the reply queue, and the requester
//! decodes and applies it — the same byte discipline a wire backend needs,
//! run synchronously on the requester's thread.

use super::{
    decode_header, decode_payload, encode_delta, ByteReader, DrainReceipt, GhostDelta,
    GhostTransport, PullReceipt, PullRequest, SendReceipt, VertexCodec,
};
use crate::graph::{ShardedGraph, VertexId};
use std::collections::HashMap;
use std::sync::Mutex;

/// One `src → dst` delta lane: the byte queue plus the per-vertex payload
/// shadows the compressed frame format diffs against (both empty and
/// unused in raw mode).
#[derive(Default)]
struct Lane {
    buf: Vec<u8>,
    /// Sender shadow: last payload shipped per vertex on this lane.
    sent: HashMap<VertexId, Vec<u8>>,
    /// Receiver shadow: last payload decoded per vertex on this lane.
    seen: HashMap<VertexId, Vec<u8>>,
}

/// Ghost transport over `k x k` in-memory byte queues (`queue[src * k +
/// dst]`). Queue contention is per shard pair, mirroring the per-peer
/// connection a cluster would hold.
pub struct ChannelTransport<'g, V> {
    graph: &'g ShardedGraph<V>,
    k: usize,
    queues: Vec<Mutex<Lane>>,
    /// Pull request/reply lanes, indexed `requester * k + owner`.
    pull_lanes: Vec<Mutex<(Vec<u8>, Vec<u8>)>>,
    /// Compressed delta frames (shadow-diff + varint header) vs raw.
    compress: bool,
}

impl<'g, V> ChannelTransport<'g, V> {
    /// Set up the `k x k` delta queues and pull lanes for `graph`.
    pub fn new(graph: &'g ShardedGraph<V>) -> ChannelTransport<'g, V> {
        let k = graph.num_shards();
        ChannelTransport {
            graph,
            k,
            queues: (0..k * k).map(|_| Mutex::new(Lane::default())).collect(),
            pull_lanes: (0..k * k).map(|_| Mutex::new((Vec::new(), Vec::new()))).collect(),
            compress: false,
        }
    }

    /// Like [`ChannelTransport::new`], but delta lanes carry compressed
    /// frames: varint headers plus word-run diffs against a per-lane
    /// shadow of the last payload shipped per vertex (raw fallback
    /// whenever the diff would not be strictly smaller). Cuts
    /// bytes-per-delta sharply for converging algorithms that re-ship
    /// nearly identical payloads, at the cost of the shadow maps (two
    /// payload copies per boundary vertex per lane) and decoding under
    /// the lane lock.
    pub fn compressed(graph: &'g ShardedGraph<V>) -> ChannelTransport<'g, V> {
        ChannelTransport { compress: true, ..ChannelTransport::new(graph) }
    }

    /// Bytes currently queued toward `dst_shard` (diagnostics/tests).
    pub fn queued_bytes(&self, dst_shard: usize) -> usize {
        (0..self.k)
            .map(|src| self.queues[src * self.k + dst_shard].lock().unwrap().buf.len())
            .sum()
    }
}

impl<V: VertexCodec + Clone + Send + Sync> ChannelTransport<'_, V> {
    /// Decode and apply every frame in `lane.buf` (compressed format),
    /// updating the receiver shadow per frame. Runs under the lane lock.
    fn drain_compressed_lane(
        &self,
        lane: &mut Lane,
        shard: &crate::graph::Shard<V>,
        src: usize,
        dst_shard: usize,
        out: &mut DrainReceipt,
    ) {
        let Lane { buf, seen, .. } = lane;
        out.bytes += buf.len() as u64;
        let mut rest: &[u8] = buf;
        let mut payload = Vec::new();
        while !rest.is_empty() {
            let Some((header, body)) = decode_header(rest) else {
                debug_assert!(false, "corrupt compressed header on {src}->{dst_shard}");
                break;
            };
            let shadow = seen.get(&header.vertex).map(Vec::as_slice);
            let Some(after) = decode_payload(&header, body, shadow, &mut payload) else {
                debug_assert!(false, "corrupt compressed body on {src}->{dst_shard}");
                break;
            };
            rest = after;
            // The shadow must advance on *every* frame — the sender's did —
            // even when the delta loses the newest-wins race below.
            seen.entry(header.vertex)
                .and_modify(|s| s.clone_from(&payload))
                .or_insert_with(|| payload.clone());
            let Some(value) = V::decode(&payload) else {
                debug_assert!(false, "codec round-trip failed for vertex {}", header.vertex);
                continue;
            };
            if let Some(entry) = shard.ghost_of(header.vertex) {
                if entry.store_versioned(&value, header.version) {
                    out.applied += 1;
                    crate::telemetry::instant(
                        crate::telemetry::EventKind::WireApply,
                        header.vertex as u64,
                        header.version,
                    );
                }
            }
        }
        buf.clear();
    }
}

impl<V: VertexCodec + Clone + Send + Sync> GhostTransport<V> for ChannelTransport<'_, V> {
    fn name(&self) -> &'static str {
        if self.compress {
            "channel-z"
        } else {
            "channel"
        }
    }

    fn send(&self, src_shard: usize, vertex: VertexId, version: u64, data: &V) -> SendReceipt {
        let sites = self.graph.replicas_of(vertex);
        if sites.is_empty() {
            return SendReceipt::default();
        }
        crate::telemetry::instant(
            crate::telemetry::EventKind::WireSend,
            vertex as u64,
            version,
        );
        let mut bytes = 0u64;
        if self.compress {
            let mut payload = Vec::new();
            data.encode(&mut payload);
            for &(s, gi) in sites {
                // Advance the pending slot before the bytes hit the queue
                // so a staleness probe never sees an in-flight version it
                // cannot account for.
                self.graph.shard(s as usize).ghost(gi as usize).note_pending(version);
                let mut q = self.queues[src_shard * self.k + s as usize].lock().unwrap();
                let Lane { buf, sent, .. } = &mut *q;
                let shadow = sent.get(&vertex).map(Vec::as_slice);
                bytes += encode_delta(vertex, version, &payload, shadow, buf) as u64;
                sent.entry(vertex)
                    .and_modify(|p| p.clone_from(&payload))
                    .or_insert_with(|| payload.clone());
            }
        } else {
            let delta = GhostDelta::from_vertex(vertex, version, data);
            for &(s, gi) in sites {
                self.graph.shard(s as usize).ghost(gi as usize).note_pending(version);
                let mut q = self.queues[src_shard * self.k + s as usize].lock().unwrap();
                delta.encode_into(&mut q.buf);
                bytes += delta.wire_len() as u64;
            }
        }
        SendReceipt { replicas_now: 0, bytes }
    }

    fn drain(&self, dst_shard: usize) -> DrainReceipt {
        let shard = self.graph.shard(dst_shard);
        let mut out = DrainReceipt::default();
        for src in 0..self.k {
            if self.compress {
                // Compressed frames diff against the receiver shadow, so
                // they must decode in lane order under the lane lock.
                let mut q = self.queues[src * self.k + dst_shard].lock().unwrap();
                if q.buf.is_empty() {
                    continue;
                }
                self.drain_compressed_lane(&mut q, shard, src, dst_shard, &mut out);
                continue;
            }
            // Raw frames are self-contained: take the buffer and decode
            // outside the lock.
            let buf = {
                let mut q = self.queues[src * self.k + dst_shard].lock().unwrap();
                std::mem::take(&mut q.buf)
            };
            if buf.is_empty() {
                continue;
            }
            out.bytes += buf.len() as u64;
            let mut r = ByteReader::new(&buf);
            while !r.is_empty() {
                let Some(delta) = GhostDelta::decode_from(&mut r) else {
                    debug_assert!(false, "corrupt frame on {src}->{dst_shard}");
                    break;
                };
                let Some(value) = delta.decode_vertex::<V>() else {
                    debug_assert!(false, "codec round-trip failed for vertex {}", delta.vertex);
                    continue;
                };
                if let Some(entry) = shard.ghost_of(delta.vertex) {
                    if entry.store_versioned(&value, delta.version) {
                        out.applied += 1;
                        crate::telemetry::instant(
                            crate::telemetry::EventKind::WireApply,
                            delta.vertex as u64,
                            delta.version,
                        );
                    }
                }
            }
        }
        out
    }

    fn pull<'m>(
        &self,
        dst_shard: usize,
        req: PullRequest,
        master: &dyn Fn(VertexId) -> (&'m V, u64),
    ) -> PullReceipt {
        let owner = self.graph.owner_of(req.vertex);
        if owner == dst_shard {
            return PullReceipt::default();
        }
        let mut bytes = 0u64;
        let mut lane = self.pull_lanes[dst_shard * self.k + owner].lock().unwrap();
        let (req_q, rep_q) = &mut *lane;
        // Requester -> owner: the request frame crosses the lane.
        req.encode_into(req_q);
        bytes += PullRequest::WIRE_LEN as u64;
        // Owner side: decode the request off the queue and serve it from
        // master data as an ordinary delta frame on the reply queue.
        let raw = std::mem::take(req_q);
        let Some(reply) = super::serve_pull(&raw, master) else {
            debug_assert!(false, "corrupt pull request on {dst_shard}->{owner}");
            return PullReceipt { applied: false, served: true, bytes };
        };
        rep_q.extend_from_slice(&reply);
        bytes += reply.len() as u64;
        // Requester side: decode the reply and apply it (newest wins).
        let raw = std::mem::take(rep_q);
        let Some(applied) = super::apply_pull_reply(self.graph, dst_shard, &raw) else {
            debug_assert!(false, "corrupt pull reply on {owner}->{dst_shard}");
            return PullReceipt { applied: false, served: true, bytes };
        };
        PullReceipt { applied, served: true, bytes }
    }

    fn queued_bytes(&self, dst_shard: usize) -> u64 {
        ChannelTransport::queued_bytes(self, dst_shard) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DataGraph, GraphBuilder};

    fn chain(n: usize) -> DataGraph<u64, ()> {
        let mut b = GraphBuilder::new();
        for i in 0..n {
            b.add_vertex(i as u64);
        }
        for i in 0..n - 1 {
            b.add_undirected(i as u32, i as u32 + 1, (), ());
        }
        b.build()
    }

    #[test]
    fn deltas_queue_then_apply_on_drain() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = ChannelTransport::new(&sg);
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        let r = t.send(owner, v, 4, &777u64);
        assert_eq!(r.replicas_now, 0, "channel applies at drain, not send");
        assert!(r.bytes > 0);
        assert_eq!(entry.version(), 0, "not yet applied");
        assert_eq!(entry.pending_version(), 4, "in-flight version visible");
        assert!(t.queued_bytes(dst as usize) > 0);

        let d = t.drain(dst as usize);
        assert_eq!(d.applied as usize, 1);
        assert_eq!(entry.read(), 777, "payload round-tripped through the codec");
        assert_eq!(entry.version(), 4);
        assert_eq!(t.queued_bytes(dst as usize), 0);
        assert_eq!(t.drain(dst as usize).applied, 0, "queue drained");
    }

    #[test]
    fn stale_delta_superseded_by_newer_version() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let t = ChannelTransport::new(&sg);
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        // out-of-order arrival: newer first, then an older duplicate
        t.send(owner, v, 9, &900u64);
        t.send(owner, v, 2, &200u64);
        let d = t.drain(dst as usize);
        assert_eq!(d.applied, 1, "the stale delta is dropped");
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        assert_eq!(entry.read(), 900);
        assert_eq!(entry.version(), 9);
    }

    #[test]
    fn compressed_lane_round_trips_and_ships_fewer_bytes() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let raw = ChannelTransport::new(&sg);
        let z = ChannelTransport::compressed(&sg);
        assert_eq!(GhostTransport::name(&z), "channel-z");
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        let entry = sg.shard(dst as usize).ghost(gi as usize);

        // Same three sends on both backends; the payload changes once.
        let ships: [(u64, u64); 3] = [(1, 777), (2, 777), (3, 778)];
        let mut raw_bytes = 0;
        let mut z_bytes = 0;
        for &(ver, val) in &ships {
            raw_bytes += raw.send(owner, v, ver, &val).bytes;
            z_bytes += z.send(owner, v, ver, &val).bytes;
        }
        assert!(
            z_bytes < raw_bytes,
            "compressed ({z_bytes} B) must beat raw ({raw_bytes} B)"
        );
        // Raw ships a flat 24 B/delta for a u64 payload; compressed repeats
        // collapse to a header plus one empty run.
        assert_eq!(raw_bytes, 3 * 24);
        assert!(z_bytes <= 12 + 6 + 12, "first ship + repeat + changed word");

        let d = z.drain(dst as usize);
        assert_eq!(d.applied, 3, "each newer version applies (newest-wins)");
        assert_eq!(entry.read(), 778, "latest payload reconstructed from diffs");
        assert_eq!(entry.version(), 3);
        assert_eq!(z.queued_bytes(dst as usize), 0);
        raw.drain(dst as usize);
    }

    #[test]
    fn compressed_shadow_survives_newest_wins_races() {
        let mut g = chain(8);
        let sg = ShardedGraph::new(&mut g, 2);
        let z = ChannelTransport::compressed(&sg);
        let v: u32 = (0..8u32).find(|&v| !sg.replicas_of(v).is_empty()).unwrap();
        let owner = sg.owner_of(v);
        let (dst, gi) = sg.replicas_of(v)[0];
        // Newer version first, then a stale duplicate: the stale frame is
        // rejected by newest-wins but still advances the receiver shadow.
        z.send(owner, v, 9, &900u64);
        z.send(owner, v, 2, &200u64);
        assert_eq!(z.drain(dst as usize).applied, 1);
        let entry = sg.shard(dst as usize).ghost(gi as usize);
        assert_eq!(entry.read(), 900);
        // The next send diffs against the sender shadow (200); if the
        // receiver shadow had not advanced on the rejected frame, this
        // diff would reconstruct garbage.
        z.send(owner, v, 10, &201u64);
        assert_eq!(z.drain(dst as usize).applied, 1);
        assert_eq!(entry.read(), 201);
        assert_eq!(entry.version(), 10);
    }
}
